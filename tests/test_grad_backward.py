"""Event-skipped Pallas backward (the training fast path).

Four layers of guarantees:

  * raw backward-kernel parity — ``spike_matmul_dx`` (surrogate factor
    fused in-kernel) and ``spike_matmul_dw`` (vld-gated transpose) match
    the jnp contractions bit-for-bit across the sparsity ladder
    {0, 50, 90, 99}%, every skip mode, and both spike formats;
  * custom_vjp executor parity — gradients through the differentiable
    ``ops.*`` entry points under ``force_pallas_backward`` (the kernel
    executor, interpret mode on CPU) match the surrogate-jnp autodiff:
    matmul per skip, fused_pe with bias/residual/QK mask per sparsity and
    format, dense_lif across MHA/GQA head configs;
  * KD-step end-to-end — one ``make_kd_train_step`` step under the fused
    policies produces the reference loss and gradients, with BN folding
    on and off (±BN-fold x dense/packed);
  * the backward byte model — event-gated backward HBM bytes strictly
    decrease with sparsity, and the "auto+grad" tuner prices the ladder
    (reference autodiff at dense, event-gated fused backward when sparse).

The CI junit guard runs this module under no-skip: every case executes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.kd import KDConfig
from repro.core.lif import LIFConfig
from repro.core.surrogate import surrogate_grad
from repro.kernels.packed import pack_spikes
from repro.kernels.spike_matmul import spike_matmul_dw, spike_matmul_dx
from repro.models import snn_cnn
from repro.ops.grad import force_pallas_backward
from repro.optim import sgd_init
from repro.optim.schedules import constant_lr
from repro.train import make_kd_train_step

SPARSITY = (0.0, 0.5, 0.9, 0.99)
SKIPS = ("dense", "gated", "two_level")
BLK = dict(block_m=64, block_n=64, block_k=64)


def _k_silent(m, k, frac_silent, seed=0, rate=0.3):
    """{0,1} spikes whose last ``frac_silent`` of the K axis is silent —
    whole metadata blocks over that range carry no events, the structure
    the vld-gated backward compacts away."""
    k_on = int(round(k * (1 - frac_silent)))
    x = jnp.zeros((m, k), jnp.float32)
    if k_on:
        x = x.at[:, :k_on].set(
            (jax.random.uniform(jax.random.PRNGKey(seed), (m, k_on))
             < rate).astype(jnp.float32))
    return x


def _assert_close(a, b, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=atol)


def _assert_grads_close(g, g_ref, atol=1e-5):
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        _assert_close(a, b, atol)


# ====================================================== raw backward kernels
@pytest.mark.parametrize("frac", SPARSITY)
@pytest.mark.parametrize("skip", SKIPS)
@pytest.mark.parametrize("packed", [False, True])
def test_dw_kernel_parity(frac, skip, packed):
    """dw = xᵀ @ g, event-skipped on the forward operand's vld map, equals
    the dense transpose at every sparsity x skip x format point."""
    m, k, n = 128, 192, 96
    x = _k_silent(m, k, frac, seed=1)
    g = jax.random.normal(jax.random.PRNGKey(2), (m, n))
    operand = pack_spikes(x.astype(jnp.int8), block_m=64, block_k=64) \
        if packed else x
    dw = spike_matmul_dw(operand, g, skip=skip, **BLK)
    _assert_close(dw, x.T @ g)


@pytest.mark.parametrize("with_v", [False, True])
def test_dx_kernel_fused_surrogate(with_v):
    """dx = (g ⊙ surr'(v - v_th)) @ wᵀ with the surrogate factor fused
    in-kernel; without v it degenerates to the plain transposed linear."""
    m, k, n = 128, 96, 192
    g = jax.random.normal(jax.random.PRNGKey(3), (m, n))
    w = jax.random.normal(jax.random.PRNGKey(4), (k, n)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(5), (m, n)) if with_v else None
    dx, dv = spike_matmul_dx(g, w, v, surrogate="atan", alpha=2.0,
                             v_th=0.7, **BLK)
    dv_ref = g if v is None else g * surrogate_grad(v - 0.7, "atan", 2.0)
    _assert_close(dv, dv_ref)
    _assert_close(dx, dv_ref @ w.T)


@pytest.mark.parametrize("frac", SPARSITY)
def test_fused_pe_emit_current_is_the_residual_cache(frac):
    """The kernel-emitted membrane current (the backward's residual cache)
    equals the post-bias/-residual pre-activation."""
    from repro.kernels.fused_pe import fused_pe

    m, k, n = 70, 130, 65
    x = _k_silent(m, k, frac, seed=6).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(7), (k, n)) * 0.3
    bias = jax.random.normal(jax.random.PRNGKey(8), (n,)) * 0.5
    res = (jax.random.uniform(jax.random.PRNGKey(9), (m, n)) < 0.3
           ).astype(jnp.int8)
    out = fused_pe(x, w, bias=bias, residual=res, emit_current=True)
    cur_ref = (x.astype(jnp.float32) @ w + bias.reshape(1, -1)
               + res.astype(jnp.float32))
    _assert_close(out.current, cur_ref)


# ============================================= custom_vjp, kernel executor
@pytest.mark.parametrize("frac", SPARSITY)
@pytest.mark.parametrize("skip", SKIPS)
def test_matmul_backward_pallas_matches_autodiff(frac, skip):
    m, k, n = 128, 192, 128
    x = _k_silent(m, k, frac, seed=10)
    w = jax.random.normal(jax.random.PRNGKey(11), (k, n)) * 0.3
    pol = ops.as_policy("fused_dense").for_training()

    def loss(x_, w_):
        return (ops.matmul(x_, w_, policy=pol, skip=skip, **BLK)
                * jnp.arange(n)).sum()

    with force_pallas_backward():
        g = jax.grad(loss, argnums=(0, 1))(x, w)
    g_ref = jax.grad(lambda a, b: ((a @ b) * jnp.arange(n)).sum(),
                     argnums=(0, 1))(x, w)
    # atol absorbs K-accumulation reorder noise on O(1e2) cotangents
    _assert_grads_close(g, g_ref, atol=1e-4)


@pytest.mark.parametrize("frac", SPARSITY)
@pytest.mark.parametrize("policy", ["fused_dense", "fused_packed"])
def test_fused_pe_backward_pallas_matches_autodiff(frac, policy):
    """The fully-fused stateless backward (surrogate factor inside the dx
    kernel, dw vld-gated, bias/residual grads off the shared dv) under the
    kernel executor, against the pure-jnp surrogate autodiff — with the QK
    write-back mask in the graph."""
    m, k, n = 70, 130, 65
    x = _k_silent(m, k, frac, seed=12)
    w = jax.random.normal(jax.random.PRNGKey(13), (k, n)) * 0.3
    bias = jax.random.normal(jax.random.PRNGKey(14), (n,)) * 0.5
    res = (jax.random.uniform(jax.random.PRNGKey(15), (m, n)) < 0.3
           ).astype(jnp.float32)
    q = (jax.random.uniform(jax.random.PRNGKey(16), (m, 16)) < 0.3
         ).astype(jnp.float32)
    cfg = LIFConfig(v_th=0.5)

    def loss(x_, w_, b_, r_, q_, pol):
        out = ops.fused_pe(x_, w_, bias=b_, residual=r_, q=q_, lif_cfg=cfg,
                           policy=pol)
        return (out.spikes.data * jnp.arange(n)).sum()

    args = (x, w, bias, res, q)
    with force_pallas_backward():
        g = jax.grad(loss, argnums=tuple(range(5)))(
            *args, ops.as_policy(policy).for_training())
    g_ref = jax.grad(loss, argnums=tuple(range(5)))(
        *args, ops.as_policy("reference").for_training())
    _assert_grads_close(g, g_ref, atol=1e-4)


@pytest.mark.parametrize("t", [1, 3])
def test_fused_pe_layer_backward_pallas_matches_autodiff(t):
    """The per-timestep residual-cached vjp chain (stateful for T>1) under
    the kernel executor."""
    m, k, n = 40, 70, 33
    x = jnp.stack([_k_silent(m, k, 0.5, seed=17 + ti) for ti in range(t)])
    w = jax.random.normal(jax.random.PRNGKey(20), (k, n)) * 0.3
    cfg = LIFConfig(v_th=0.5)
    ref = ops.as_policy("reference").for_training()
    fused = ops.as_policy("fused_dense").for_training()

    def loss(x_, w_, pol):
        out = ops.fused_pe_layer(x_, w_, lif_cfg=cfg, policy=pol)
        return (out.spikes.data * jnp.arange(n)).sum()

    with force_pallas_backward():
        g = jax.grad(loss, argnums=(0, 1))(x, w, fused)
    g_ref = jax.grad(loss, argnums=(0, 1))(x, w, ref)
    _assert_grads_close(g, g_ref, atol=1e-4)


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("forced", [False, True])
def test_dense_lif_backward_mha_gqa(h, hkv, forced):
    """Head-blocked QK write-back backward across MHA (hkv == h) and GQA
    (grouped-KV weight expansion) on both executors: the fused vjp's
    grouped-layout residual cache must sum group cotangents exactly like
    the reference broadcast."""
    m, k, dh = 48, 33, 8
    n = hkv * dh
    x = jax.random.normal(jax.random.PRNGKey(21), (m, k))
    p = {"w": jax.random.normal(jax.random.PRNGKey(22), (k, n)) * 0.3,
         "b": jnp.zeros((n,)) + 0.1}
    q = (jax.random.uniform(jax.random.PRNGKey(23), (m, h * dh)) < 0.3
         ).astype(jnp.float32)
    cfg = LIFConfig(v_th=0.5)

    def loss(x_, p_, pol):
        st = ops.dense_lif(p_, x_, cfg, q=q, heads=(h, dh), kv_heads=hkv,
                           policy=pol)
        return (st.data * jnp.arange(h * dh)).sum()

    ref = ops.as_policy("reference").for_training()
    fused = ops.as_policy("fused_dense").for_training()
    g_ref = jax.grad(loss, argnums=(0, 1))(x, p, ref)
    with force_pallas_backward(forced):
        g = jax.grad(loss, argnums=(0, 1))(x, p, fused)
    _assert_grads_close(g, g_ref, atol=1e-4)


# ==================================================== KD step, end to end
def _kd_cfg(**kw):
    return snn_cnn.SNNCNNConfig(arch="resnet11", num_classes=10,
                                image_size=16, width_mult=0.125, **kw)


def _kd_step_results(cfg, policy):
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    batch = {"images": imgs, "labels": jnp.array([0, 1, 2, 3])}

    def teacher_apply(_, x):
        return x.reshape(x.shape[0], -1)[:, :10] * 0.1

    def student(p, s, x, policy=None):
        logits, new_s, aux = snn_cnn.forward({"params": p, "state": s}, x,
                                             cfg, train=True, policy=policy)
        return logits, new_s, aux

    step = jax.jit(make_kd_train_step(
        student, teacher_apply, None, kd=KDConfig(alpha=0.5),
        schedule=constant_lr(0.1), policy=policy))
    carry = (var["params"], sgd_init(var["params"]), var["state"])
    carry, metrics = step(carry, batch)
    return carry[0], metrics


@pytest.mark.parametrize("bn_fold", [False, True])
@pytest.mark.parametrize("policy", ["fused_dense", "fused_packed"])
def test_kd_step_grad_equivalence(bn_fold, policy):
    """One KD train step under the fused policies == the reference
    autodiff step — loss and updated params — with BN folded into the
    training graph and not."""
    cfg = _kd_cfg(bn_fold=bn_fold)
    p_ref, m_ref = _kd_step_results(cfg, "reference")
    p, m = _kd_step_results(cfg, policy)
    np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    _assert_grads_close(p, p_ref, atol=1e-4)


def test_kd_step_surfaces_measured_sparsity():
    """The KD step's metrics carry the student's measured spike rate, and
    ``observe_train_sparsity`` feeds it to the autotuner hint — the loop
    that prices "auto+grad" backward plans at the REAL training sparsity."""
    from repro.ops.autotune import get_tuner
    from repro.train import observe_train_sparsity

    _, metrics = _kd_step_results(_kd_cfg(), "fused_dense")
    frac = float(metrics["active_frac"])
    assert 0.0 < frac < 1.0, frac
    tuner = get_tuner()
    tuner.reset()
    observe_train_sparsity({k: float(v) for k, v in metrics.items()})
    assert tuner._hint is not None
    assert abs(tuner._hint[0] - frac) < 1e-6
    tuner.reset()
    observe_train_sparsity({"loss": 1.0})      # no metric -> no-op
    assert tuner._hint is None


# ================================================= backward byte model
@pytest.mark.parametrize("skip", ["gated", "two_level"])
def test_backward_bytes_strictly_decrease_with_sparsity(skip):
    """The acceptance property: modeled event-gated backward HBM bytes
    strictly decrease as sparsity rises (dense streaming does not)."""
    from repro.launch import roofline

    series = [roofline.spike_matmul_grad_traffic(
        2048, 1024, 1024, active_frac=1.0 - f, skip=skip)["hbm_bytes"]
        for f in SPARSITY]
    assert all(a > b for a, b in zip(series, series[1:])), series
    dense = [roofline.spike_matmul_grad_traffic(
        2048, 1024, 1024, active_frac=1.0 - f, skip="dense")["hbm_bytes"]
        for f in SPARSITY]
    assert dense[0] == dense[-1]
    # the backward model prices MORE traffic than one forward sweep (two
    # contractions + the residual-cache read)
    fwd = roofline.spike_matmul_traffic(2048, 1024, 1024)["hbm_bytes"]
    assert series[0] > fwd


def test_auto_grad_tuner_prices_backward_ladder():
    """"auto+grad" planning: reference autodiff wins at dense, the
    event-gated fused backward wins once sparsity pays for the gating, and
    the cached plan drives dispatch to reference-matching gradients."""
    from repro.ops.autotune import AutoTuner

    tuner = AutoTuner()
    dense_plan = tuner.plan_grad_matmul(8192, 2048, 2048, active_frac=1.0)
    sparse_plan = tuner.plan_grad_matmul(8192, 2048, 2048, active_frac=0.05)
    assert dense_plan.kernels == "reference"
    assert sparse_plan.kernels == "fused" and sparse_plan.skip == "gated"
    assert sparse_plan.est_time_s < dense_plan.est_time_s
    # cache: same bucket -> same object
    assert tuner.plan_grad_matmul(8192, 2048, 2048,
                                  active_frac=0.05) is sparse_plan

    x = _k_silent(128, 192, 0.9, seed=30)
    w = jax.random.normal(jax.random.PRNGKey(31), (192, 64)) * 0.3
    auto = ops.as_policy("auto").for_training()
    ref = ops.as_policy("reference").for_training()

    def loss(x_, w_, pol):
        return (ops.matmul(x_, w_, policy=pol) * jnp.arange(64)).sum()

    g = jax.grad(loss, argnums=(0, 1))(x, w, auto)
    g_ref = jax.grad(loss, argnums=(0, 1))(x, w, ref)
    _assert_grads_close(g, g_ref)
