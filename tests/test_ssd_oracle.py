"""SSD (mamba2) chunked algorithm vs the naive per-token recurrence oracle.

The chunked form (intra-chunk attention-like matmuls + inter-chunk state
recurrence) must equal the definitionally-simple sequential SSM:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t B_t^T
    y_t = C_t h_t
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssm import _ssd_chunked


def naive_ssd(xs, dt, A, Bm, Cm):
    """Per-token recurrence. xs:[B,S,H,P], dt:[B,S,H], A:[H], B/C:[B,S,G,N]."""
    b, s, h, p = xs.shape
    g, n = Bm.shape[-2:]
    hg = h // g
    Bh = jnp.repeat(Bm, hg, axis=-2)          # [B,S,H,N]
    Ch = jnp.repeat(Cm, hg, axis=-2)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * A[None, :])[:, :, None, None]
        upd = (dt[:, t, :, None] * xs[:, t])[..., None] * Bh[:, t, :, None, :]
        state = state * decay + upd
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t]))
    return jnp.stack(ys, axis=1), state


def _inputs(seed, b=2, s=24, h=4, p=8, g=2, n=6):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xs = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(seed + 99), (b, s, g, n)) * 0.5
    return xs, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_chunked_equals_naive(chunk):
    xs, dt, A, Bm, Cm = _inputs(0)
    y_fast, st_fast = _ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    y_ref, st_ref = naive_ssd(xs, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_fast), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_init_state_continuation():
    """Splitting a sequence in two with state carry == one pass."""
    xs, dt, A, Bm, Cm = _inputs(1, s=16)
    y_full, st_full = _ssd_chunked(xs, dt, A, Bm, Cm, 8)
    y1, st1 = _ssd_chunked(xs[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 8)
    y2, st2 = _ssd_chunked(xs[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], 8,
                           init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 500), st.sampled_from([2, 4, 6, 12]))
@settings(max_examples=8)
def test_chunked_equals_naive_property(seed, chunk):
    xs, dt, A, Bm, Cm = _inputs(seed, b=1, s=12, h=2, p=4, g=1, n=4)
    y_fast, _ = _ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    y_ref, _ = naive_ssd(xs, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
