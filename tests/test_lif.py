"""LIF neuron dynamics (paper Fig 1: MP update + threshold + hard reset)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.lif import (LIFConfig, lif_forward, lif_multistep,
                            lif_single_step, spike_rate, total_spikes)


def test_single_timestep_degenerates_to_threshold():
    """Paper's T=1 mode: s = H(I - v_th), no temporal state."""
    cur = jnp.array([0.5, 1.0, 1.5])
    s = lif_forward(cur, LIFConfig(v_th=1.0))
    np.testing.assert_array_equal(np.asarray(s), [0, 1, 1])


def test_hard_reset_zeroes_fired_neurons():
    s, v = lif_single_step(jnp.array([2.0, 0.5]), LIFConfig(v_th=1.0))
    np.testing.assert_array_equal(np.asarray(s), [1, 0])
    np.testing.assert_allclose(np.asarray(v), [0.0, 0.5])


def test_multistep_membrane_accumulation():
    """Sub-threshold inputs accumulate over timesteps until firing."""
    cfg = LIFConfig(tau=1.0, v_th=1.0)           # no leak for exact math
    currents = jnp.full((4, 1), 0.4)
    spikes = lif_multistep(currents, cfg)
    # v: 0.4, 0.8, 1.2 -> fire at t=2, reset, 0.4
    np.testing.assert_array_equal(np.asarray(spikes)[:, 0], [0, 0, 1, 0])


def test_decay():
    cfg = LIFConfig(tau=0.5, v_th=10.0)
    currents = jnp.ones((3, 1))
    # v: 1, 1.5, 1.75 (geometric, no firing)
    v = 0.0
    for _ in range(3):
        v = 0.5 * v + 1.0
    spikes = lif_multistep(currents, cfg)
    assert int(total_spikes(spikes)) == 0


@given(st.integers(1, 8), st.floats(0.1, 2.0))
def test_rate_bounds(t, vth):
    cur = jax.random.normal(jax.random.PRNGKey(0), (t, 16))
    s = lif_multistep(cur, LIFConfig(v_th=vth))
    r = float(spike_rate(s))
    assert 0.0 <= r <= 1.0
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}
