"""Distributed execution on 8 virtual host devices — run in SUBPROCESSES so
the main pytest process keeps its single-device view (the brief's rule).

Covers: pjit train step on a (2,4) data x model mesh with the production
sharding rules, decode with sequence-sharded cache (context-parallel path),
int8+EF compressed DP training under shard_map, and the elastic runner's
failure -> re-mesh -> resume cycle on a real multi-device mesh.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str, timeout=420) -> subprocess.CompletedProcess:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(ROOT / "src"),
               JAX_PLATFORMS="cpu")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_sharded_train_step_runs_and_matches_single_device():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, build_model
        from repro.models import sharding as shd
        from repro.optim import adamw_init
        from repro.train import make_train_step, train_state_init
        from repro.optim.schedules import constant_lr

        cfg = reduced(get_config('qwen3-1.7b'))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                              0, cfg.vocab_size)}
        step = make_train_step(model, schedule=constant_lr(1e-2))
        # single-device reference
        s_ref, m_ref = jax.jit(step)(train_state_init(params), batch)
        loss_ref = float(m_ref['loss'])

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        shd.set_global_mesh(mesh)
        NS = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda s: isinstance(s, P))
        p_sh = NS(shd.param_specs(params, mesh))
        params_sharded = jax.device_put(params, p_sh)
        state = train_state_init(params_sharded)
        b_sh = NS(shd.batch_specs(batch, mesh))
        batch_sharded = jax.device_put(batch, b_sh)
        with mesh:
            s_out, m = jax.jit(step)(state, batch_sharded)
        loss_sharded = float(m['loss'])
        assert abs(loss_ref - loss_sharded) < 1e-2, (loss_ref, loss_sharded)
        # params moved identically (allclose across the two regimes)
        a = jax.tree_util.tree_leaves(s_ref.params)[0]
        b = jax.tree_util.tree_leaves(s_out.params)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)),
                                   rtol=2e-2, atol=2e-4)
        print('OK', loss_ref, loss_sharded)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_context_parallel_decode_matches_replicated():
    """long-context path: KV cache sharded over sequence on 'data' must give
    identical logits (GSPMD flash-decode combine is exact)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, build_model
        from repro.models import sharding as shd

        cfg = reduced(get_config('qwen3-1.7b'))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                  cfg.vocab_size)
        _, cache = model.prefill(params, {'tokens': toks}, max_len=65)
        nxt = jnp.ones((1, 1), jnp.int32)
        ref, _ = model.decode_step(params, nxt, cache)

        mesh = jax.make_mesh((8, 1), ('data', 'model'))
        shd.set_global_mesh(mesh)
        NS = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda s: isinstance(s, P))
        c_sh = NS(shd.cache_specs(cache, mesh, batch=1,
                                  context_parallel=True))
        cache_sharded = jax.device_put(cache, c_sh)
        with mesh:
            out, _ = jax.jit(model.decode_step)(params, nxt, cache_sharded)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-3, atol=1e-3)
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_compressed_dp_training_converges_like_uncompressed():
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced, build_model
        from repro.models import sharding as shd
        from repro.optim import error_feedback_init
        from repro.optim.schedules import constant_lr
        from repro.train import (make_train_step, make_compressed_train_step,
                                 train_state_init)

        cfg = reduced(get_config('qwen3-1.7b'))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((8,), ('data',))
        shd.set_global_mesh(None)
        batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 32), 0, cfg.vocab_size)}
        plain = make_train_step(model, schedule=constant_lr(5e-3))
        comp = make_compressed_train_step(model, mesh,
                                          schedule=constant_lr(5e-3))
        sp = train_state_init(params)
        sc = (train_state_init(params), error_feedback_init(params))
        with mesh:
            cjit = jax.jit(comp)
            pjit_ = jax.jit(plain)
            lp = lc = None
            for _ in range(6):
                sp, mp = pjit_(sp, batch)
                sc, mc = cjit(sc, batch)
                lp, lc = float(mp['loss']), float(mc['loss'])
        print('plain', lp, 'compressed', lc)
        assert lc < 6.0 and abs(lp - lc) < 0.5, (lp, lc)
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_elastic_failure_remesh_resume():
    """Full elastic cycle through the real driver: checkpoint -> injected
    failure -> degraded mesh -> restore -> finish."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--reduced", "--steps", "10", "--batch", "4", "--seq", "32",
         "--ckpt-every", "4", "--simulate-failure", "6",
         "--ckpt-dir", "/tmp/repro_ckpt_elastic_test"],
        capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    out = r.stdout
    assert "'kind': 'failure'" in out
    assert "'kind': 'remesh'" in out
    assert "'kind': 'restore'" in out
    assert "done: 10 steps" in out


def test_dryrun_cell_on_test_mesh():
    """A miniature of the dry-run itself: reduced arch, 8-device mesh,
    lower+compile+cost/memory analysis + collective extraction."""
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, build_model
        from repro.models import sharding as shd
        from repro.launch.hlo_analysis import analyze

        cfg = reduced(get_config('olmoe-1b-7b'))
        model = build_model(cfg)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        shd.set_global_mesh(mesh)
        NS = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda s: isinstance(s, P))
        pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        psh = NS(shd.param_specs(pshape, mesh))
        batch = {'tokens': jax.ShapeDtypeStruct((4, 64), jnp.int32)}
        bsh = NS(shd.batch_specs(batch, mesh))
        with mesh:
            lowered = jax.jit(lambda p, b: model.loss(p, b)[0],
                              in_shardings=(psh, bsh)).lower(pshape, batch)
            compiled = lowered.compile()
        assert compiled.memory_analysis() is not None
        r = analyze(compiled.as_text())
        assert r['flops'] > 0
        assert r['collective_wire_bytes'] > 0   # EP combine must exist
        print('OK', r['collectives'].keys())
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
