"""End-to-end behaviour of the paper's system: the full NEURAL pipeline
(Fig 7 design flow) from training to deployed spiking inference, plus the
framework glue (train -> checkpoint -> serve) on a reduced LM."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.core.kd import KDConfig
from repro.core.quant import QuantConfig
from repro.data import SyntheticImageDataset, SyntheticTokenDataset
from repro.models import snn_cnn
from repro.optim import sgd_init
from repro.optim.schedules import constant_lr
from repro.serve import Engine, EngineConfig
from repro.train import (make_kd_train_step, make_train_step,
                         restore_checkpoint, save_checkpoint,
                         latest_checkpoint, train_state_init)


def test_paper_pipeline_end_to_end(tmp_path):
    """KD-train a tiny single-timestep SNN, quantize+fuse it (F&Q), run it
    full-spike with the W2TTFS head — the complete deployment flow."""
    ds = SyntheticImageDataset(num_classes=4, image_size=16, seed=0,
                               noise=0.4)
    cfg = snn_cnn.SNNCNNConfig(arch="resnet11", num_classes=4,
                               image_size=16, width_mult=0.125, timesteps=1,
                               quant=QuantConfig(enabled=True, bits=8))
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)

    # teacher: the analytic class means give a perfect nearest-mean oracle
    means = jnp.asarray(ds.means.reshape(4, -1))

    def teacher_apply(_, imgs):
        flat = imgs.reshape(imgs.shape[0], -1)
        d = -jnp.sum((flat[:, None, :] - means[None]) ** 2, -1)
        return d / 100.0

    def student_apply(p, s, x):
        logits, new_s, _ = snn_cnn.forward({"params": p, "state": s}, x,
                                           cfg, train=True)
        return logits, new_s

    step = jax.jit(make_kd_train_step(
        student_apply, teacher_apply, None, kd=KDConfig(alpha=0.5),
        schedule=constant_lr(0.1)))
    carry = (var["params"], sgd_init(var["params"]), var["state"])
    for i in range(60):
        imgs, labels = ds.batch(i, 32)
        carry, metrics = step(carry, {"images": jnp.asarray(imgs),
                                      "labels": jnp.asarray(labels)})
    params, _, state = carry

    # deployment: fuse BN + quantize -> full-spike inference, W2TTFS head
    fused = snn_cnn.fuse_model({"params": params, "state": state}, cfg)
    imgs, labels = ds.batch(9999, 64)
    logits, _, aux = snn_cnn.forward(fused, jnp.asarray(imgs), cfg)
    acc = float((np.argmax(np.asarray(logits), -1) == labels).mean())
    assert acc > 0.5, f"deployed spiking model accuracy {acc}"
    assert float(aux["total_spikes"]) > 0


def test_lm_train_checkpoint_serve(tmp_path):
    """Train a reduced LM, checkpoint it, restore, serve through the
    continuous-batching engine — the whole framework path."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    ds = SyntheticTokenDataset(cfg.vocab_size, seq_len=33)
    step = jax.jit(make_train_step(model, schedule=constant_lr(3e-3)))
    state = train_state_init(model.init(jax.random.PRNGKey(0)))
    first = last = None
    for i in range(8):
        state, m = step(state, {"tokens": jnp.asarray(ds.batch(i, 8))})
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first

    save_checkpoint(tmp_path, int(state.step), state.params)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.params)
    params2, step_no = restore_checkpoint(latest_checkpoint(tmp_path), like)
    assert step_no == 8

    eng = Engine(model, params2, EngineConfig(max_slots=2, max_len=48,
                                              prefill_pad=8))
    eng.submit(np.arange(6), max_new=4)
    eng.submit(np.arange(9), max_new=4)
    done = eng.run_until_drained()
    assert len(done) == 2 and all(len(r.out) == 4 for r in done)
