"""Sparsity-adaptive execution: the vld-gated / two-level byte-skip
kernels, the two-level event compression metadata, and the roofline
autotuner behind ``ExecutionPolicy("auto")``.

Four contracts:

  * PARITY — every byte-skip strategy ("dense" | "gated" | "two_level")
    computes the same answer as the jnp oracle at every sparsity level,
    including clustered patterns (contiguous silent k-ranges, silent
    m-rows, checkerboards) and both spike formats. Spike outputs are
    exact; gated f32 accumulations are bit-identical to dense-skip
    (same summation order), two_level compares at tight tolerance (the
    stripe loop reorders the k-sum).
  * TWO-LEVEL METADATA — the pack kernel's word-occupancy bitmap matches
    the reference map, rides the pack/unpack round-trip, and the byte
    accounting shrinks with clustering.
  * AUTO — an "auto" policy's output is bit-identical to the concrete
    fixed policy its plan names, and its modeled time is never above any
    fixed candidate's (the "never slower than the best fixed policy"
    acceptance bar, in the model that defines the choice).
  * BYTE MODEL — modeled HBM bytes for the gated kernels strictly
    decrease as block sparsity rises (the CI regression guard for the
    "skip the bytes" claim; the ungated kernel's bytes stay flat).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.events import (compact_kmap, pack_spikes_ref,
                               unpack_spikes_ref, word_occupancy_map,
                               word_occupancy_map_dense)
from repro.kernels.packed import pack_spikes, unpack_spikes
from repro.kernels.spike_matmul import spike_matmul, spike_matmul_ref
from repro.kernels.spike_matmul.ops import check_block_contract
from repro.kernels.fused_pe import fused_pe, fused_pe_ref
from repro.launch import roofline
from repro.ops.autotune import AutoTuner, bucket

SKIPS = ["dense", "gated", "two_level"]
LEVELS = [0.0, 0.5, 0.9, 0.99]


def _pattern(m, k, kind, frac_silent, seed=0, rate=0.25):
    """Structured-sparsity spike maps: ``frac_silent`` of the map carries
    no events, arranged per ``kind``."""
    rng = np.random.default_rng(seed)
    x = (rng.random((m, k)) < rate).astype(np.int8)
    if kind == "k_tail":            # clustered: last k-range silent
        x[:, int(round(k * (1 - frac_silent))):] = 0
    elif kind == "m_rows":          # clustered: trailing rows silent
        x[int(round(m * (1 - frac_silent))):] = 0
    elif kind == "checker":         # alternating silent k-stripes
        w = 32
        keep = max(int(round((k // w) * (1 - frac_silent))), 0)
        on = rng.permutation(k // w)[:keep]
        mask = np.zeros(k, bool)
        for c in on:
            mask[c * w:(c + 1) * w] = True
        x[:, ~mask] = 0
    return jnp.asarray(x)


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("kind", ["k_tail", "m_rows", "checker"])
@pytest.mark.parametrize("frac", LEVELS)
def test_spike_matmul_skip_parity(kind, frac):
    m, k, n = 256, 256, 128
    bm = bn = bk = 64
    x = _pattern(m, k, kind, frac)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((k, n)),
                    jnp.float32)
    ref = spike_matmul_ref(x, w)
    dense_out = spike_matmul(x, w, skip="dense", block_m=bm, block_n=bn,
                             block_k=bk)
    for skip in ("gated", "two_level"):
        out = spike_matmul(x, w, skip=skip, block_m=bm, block_n=bn,
                           block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)
        if skip == "gated":       # same per-block dots, same order
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(dense_out))


@pytest.mark.parametrize("frac", [0.0, 0.9])
@pytest.mark.parametrize("skip", ["gated", "two_level"])
def test_spike_matmul_skip_parity_packed(frac, skip):
    m, k, n = 256, 256, 128
    bm = bn = bk = 64
    x = _pattern(m, k, "k_tail", frac, seed=2)
    w = jnp.asarray(np.random.default_rng(3).standard_normal((k, n)),
                    jnp.float32)
    ps = pack_spikes(x, block_m=bm, block_k=bk)
    out = spike_matmul(ps, w, skip=skip, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(spike_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("frac", LEVELS)
@pytest.mark.parametrize("fmt", ["dense", "packed"])
def test_fused_pe_skip_parity(frac, fmt):
    m, k, n = 192, 256, 64
    bm = bn = bk = 64
    x = _pattern(m, k, "k_tail", frac, seed=4)
    w = jnp.asarray(
        np.random.default_rng(5).standard_normal((k, n)) * 0.1, jnp.float32)
    xin = pack_spikes(x, block_m=bm, block_k=bk) if fmt == "packed" else x
    base = None
    for skip in SKIPS:
        out = fused_pe(xin, w, tau=0.9, v_th=0.5, block_m=bm, block_n=bn,
                       block_k=bk, skip=skip)
        spk = np.asarray(out.spikes)
        if base is None:
            base = spk
            ref, _, _ = fused_pe_ref(x, w, tau=0.9, v_th=0.5)
            np.testing.assert_array_equal(spk, np.asarray(ref))
        else:                     # all three strategies: identical spikes
            np.testing.assert_array_equal(spk, base)


def test_compact_kmap_contract():
    vld = jnp.asarray([[0, 3, 0, 1], [0, 0, 0, 0], [2, 2, 2, 2]],
                      jnp.int32)
    nact, kmap = compact_kmap(vld)
    np.testing.assert_array_equal(np.asarray(nact), [2, 0, 4])
    km = np.asarray(kmap)
    np.testing.assert_array_equal(km[0][:2], [1, 3])   # active, ascending
    assert set(km[0][2:]) == {3}                       # tail revisits last
    np.testing.assert_array_equal(km[2], [0, 1, 2, 3])


# -------------------------------------------------------- two-level metadata
@pytest.mark.parametrize("kind", ["k_tail", "checker"])
def test_pack_occ_matches_reference(kind):
    x = _pattern(192, 320, kind, 0.6, seed=6)
    ps = pack_spikes(x, block_m=64, block_k=64)
    assert ps.occ is not None
    ref = pack_spikes_ref(x, block_m=64, block_k=64, with_occ=True)
    np.testing.assert_array_equal(np.asarray(ps.occ), np.asarray(ref.occ))
    np.testing.assert_array_equal(
        np.asarray(ps.occ),
        np.asarray(word_occupancy_map(ps.words, 64, 64)))
    np.testing.assert_array_equal(
        np.asarray(ps.occ),
        np.asarray(word_occupancy_map_dense(x, 64, 64)))


@pytest.mark.parametrize("frac", [0.0, 0.5, 0.99])
def test_two_level_round_trip(frac):
    x = _pattern(130, 257, "checker", frac, seed=7)
    ps = pack_spikes(x, block_m=64, block_k=64)
    np.testing.assert_array_equal(np.asarray(unpack_spikes(ps)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(unpack_spikes_ref(ps)),
                                  np.asarray(x))
    # occ survives the SpikeTensor wrap and slicing
    st = ops.SpikeTensor.from_packed(ps)
    assert st.occ is not None
    rt = st.to_packed_spikes()
    np.testing.assert_array_equal(np.asarray(rt.occ), np.asarray(ps.occ))


def test_two_level_bytes_shrink_with_clustering():
    m, k = 512, 1024
    clustered = _pattern(m, k, "k_tail", 0.9, seed=8)
    spread = _pattern(m, k, "none", 0.0, seed=8, rate=0.025)
    b_clustered = pack_spikes(clustered).with_occ().two_level_bytes()
    b_spread = pack_spikes(spread).with_occ().two_level_bytes()
    # same-order event counts, but clustering empties word-columns the
    # two-level format then does not ship
    assert b_clustered < b_spread
    assert b_clustered < pack_spikes(clustered).packed_bytes


# -------------------------------------------------------------------- auto
def _fresh_tuner():
    return AutoTuner()


def test_auto_matches_selected_concrete_policy():
    m = k = 256
    n = 128
    x = _pattern(m, k, "k_tail", 0.9, seed=9)
    w = jnp.asarray(np.random.default_rng(10).standard_normal((k, n)),
                    jnp.float32)
    st = ops.SpikeTensor.dense(x)
    tuner = ops.get_tuner()
    tuner.reset()
    out_auto = ops.matmul(st, w, policy="auto")
    plan = tuner.plan_for(st, n, block_m=128, block_n=128, block_k=128)
    pol = "reference" if plan.kernels == "reference" else "fused_dense"
    out_fixed = ops.matmul(st, w, policy=pol, skip=plan.skip,
                           block_m=plan.block_m, block_n=plan.block_n,
                           block_k=plan.block_k)
    np.testing.assert_array_equal(np.asarray(out_auto),
                                  np.asarray(out_fixed))


def test_auto_never_slower_than_fixed_candidates():
    tuner = _fresh_tuner()
    for m, k, n, active in [(1024, 1024, 1024, 1.0),
                            (1024, 1024, 1024, 0.1),
                            (128, 4096, 512, 0.1),
                            (256, 256, 128, 0.5)]:
        for fmt in ("dense", "packed"):
            plan = tuner.plan_matmul(m, k, n, fmt=fmt, active_frac=active)
            for kernels, skip in [("fused", "dense"), ("fused", "gated"),
                                  ("fused", "two_level"),
                                  ("reference", "dense")]:
                t = roofline.spike_matmul_traffic(
                    m, k, n, active_frac=bucket(active), occ_frac=1.0,
                    packed=fmt == "packed", skip=skip, kernels=kernels)
                assert plan.est_time_s <= roofline.kernel_time_s(t) + 1e-12, \
                    (m, k, n, fmt, active, kernels, skip)


def test_auto_plans_gated_when_sparse_and_cheap_to_gate():
    # small m (no w-tile re-fetch amplification) + very sparse k: the
    # regime where the compacted grid clearly wins in the model
    tuner = _fresh_tuner()
    plan = tuner.plan_matmul(128, 4096, 512, fmt="packed", active_frac=0.05)
    assert plan.kernels == "fused" and plan.skip in ("gated", "two_level")
    dense_plan = tuner.plan_matmul(128, 4096, 512, fmt="packed",
                                   active_frac=1.0)
    assert plan.est_hbm_bytes < dense_plan.est_hbm_bytes


def test_tuner_observe_and_buckets():
    tuner = _fresh_tuner()
    assert tuner.sparsity_of(
        ops.SpikeTensor.dense(jnp.ones((8, 8), jnp.int8))) == (1.0, 1.0)
    tuner.observe(0.2, 0.5)
    tuner.observe(0.2, 0.5)
    a, o = tuner._hint
    assert 0.15 < a < 0.25 and 0.4 < o < 0.6
    assert bucket(0.02) == 0.0 and bucket(0.93) == 0.95
    assert bucket(-1.0) == 0.0 and bucket(2.0) == 1.0


def test_auto_policy_presets():
    assert ops.as_policy("auto").auto
    assert ops.as_policy("auto_packed").packed
    assert ops.as_policy("auto").fused          # may run fused kernels
    assert ops.as_policy("auto").name == "auto"
    assert ops.as_policy("auto_packed").for_training().name \
        == "auto_packed+grad"


# -------------------------------------------------------------- byte model
def test_modeled_bytes_strictly_decrease_with_sparsity():
    """The CI guard for the tentpole claim: for the GATED kernels, modeled
    HBM bytes strictly decrease as block sparsity rises; the ungated
    (dense-skip) kernel's bytes stay flat — it skips MXU work, not DMA."""
    m = k = n = 1024
    for skip in ("gated", "two_level"):
        byts = [roofline.spike_matmul_traffic(
            m, k, n, active_frac=1.0 - s, skip=skip)["hbm_bytes"]
            for s in (0.0, 0.5, 0.9)]
        assert byts[0] > byts[1] > byts[2], (skip, byts)
    dense = [roofline.spike_matmul_traffic(
        m, k, n, active_frac=1.0 - s, skip="dense")["hbm_bytes"]
        for s in (0.0, 0.5, 0.9)]
    assert dense[0] == dense[1] == dense[2]
    # the acceptance bar: 90%-sparse gated streams >=1.5x fewer bytes
    assert byts[0] / byts[2] >= 1.5


def test_block_contract_errors_name_blocks():
    x = _pattern(128, 128, "none", 0.0)
    ps = pack_spikes(x, block_m=64, block_k=64)
    with pytest.raises(ValueError, match=r"block_m=64.*block_m=128"):
        check_block_contract(ps, 128, 128, "x")
    w = jnp.zeros((128, 64), jnp.float32)
    with pytest.raises(ValueError, match="skip"):
        spike_matmul(x, w, skip="bogus")
