"""Multi-head Fig-5 fusion regressions (the silent-downgrade bugfix).

Historically a fused policy on an h>1 qk_spiking LM silently fell back to
a dense whole-row mask path — the policy you requested was not the policy
that executed. These tests pin the fix three ways:

  * dispatch audit — ``ops.record_dispatches`` proves the executed
    ``(op, mode)`` stream for h>1 (incl. grouped-KV) prefill is exactly
    the fused chain of the requested policy, with NO reference fallback
    and NO dense pack/unpack round-trip under a packed policy;
  * grouped KV is never materialized — ``attention._expand_kv`` (the
    HBM-replicating helper the softmax paths use) must be unreachable
    from the spiking paths, and the fused weight-column expansion is
    token-count independent;
  * the serving engine reports the executed policy and decodes multi-head
    spiking models through the fused chain tick by tick.

Numeric parity for the same configurations lives in
``test_kernel_parity.py`` (head-blocked sweep) and ``test_fused_pe.py`` /
``test_packed_spikes.py`` (end-to-end logits).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops

SPIKING = dict(spiking=True, attention_kind="qk_spiking")


# --------------------------------------------- executed mode == requested
@pytest.mark.parametrize("heads", [dict(n_heads=4, n_kv_heads=4),
                                   dict(n_heads=4, n_kv_heads=2)])
@pytest.mark.parametrize("policy", ["fused_dense", "fused_packed"])
def test_requested_policy_is_executed_mode(lm_zoo, heads, policy):
    """h>1 (MHA and GQA) prefill under a fused policy dispatches ONLY
    fused implementations: no silent reference fallback, and under the
    packed policy no dense pack/unpack round-trip anywhere in the chain."""
    cfg, model, params = lm_zoo("qwen3-1.7b", policy=policy, **SPIKING,
                                **heads)
    assert (cfg.n_heads, cfg.n_kv_heads) \
        == (heads["n_heads"], heads["n_kv_heads"])
    assert cfg.exec_policy.name == policy
    # unique prefill length per case -> cold trace (dispatch happens at
    # trace time; a jit cache hit would replay without re-dispatching)
    s = 7 + 2 * heads["n_kv_heads"] + (policy == "fused_packed")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0,
                              cfg.vocab_size)
    with ops.record_dispatches() as log:
        logits, _ = model.prefill(params, {"tokens": toks},
                                  return_all_logits=True)
        logits.block_until_ready()
    assert log, "prefill must dispatch through the ops registry"
    assert all(mode == "fused" for _, mode in log), log
    # the Fig-5 chain: Q projection + head-masked K projection, then the
    # event-skipped output projection
    assert log.count(("dense_lif", "fused")) >= 2
    assert ("matmul", "fused") in log
    # a packed policy keeps the spike maps packed end to end — the
    # historical downgrade showed up here as pack/unpack conversions
    assert not [e for e in log if e[0] in ("pack", "unpack")], log


# ------------------------------------------------- grouped KV, unreplicated
def test_gqa_spiking_never_calls_expand_kv(lm_zoo, monkeypatch):
    """hkv < h spiking forward (fused AND reference) never touches the
    KV-replicating helper the softmax paths use: the per-query-head mask
    broadcasts over each group instead."""
    from repro.models import attention

    def boom(k, h):
        raise AssertionError("spiking path materialized replicated KV")

    monkeypatch.setattr(attention, "_expand_kv", boom)
    for policy in ("reference", "fused_dense", "fused_packed"):
        cfg, model, params = lm_zoo("qwen3-1.7b", policy=policy, **SPIKING)
        assert cfg.n_kv_heads < cfg.n_heads   # reduced() keeps GQA ratio
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 11), 0,
                                  cfg.vocab_size)
        logits, _ = model.prefill(params, {"tokens": toks},
                                  return_all_logits=True)
        assert np.isfinite(np.asarray(logits)).all()


def test_group_weight_expansion_is_token_independent():
    """Fused GQA expands the K projection's WEIGHT columns — a (d, h*dh)
    tensor whose size never scales with the token count (unlike the
    replicated per-token KV the old path materialized)."""
    from repro.ops.impls import expand_group_weights

    d, h, hkv, dh = 64, 4, 2, 16
    w = jax.random.normal(jax.random.PRNGKey(3), (d, hkv * dh))
    p = expand_group_weights({"w": w, "b": jnp.ones((hkv * dh,))},
                             heads=(h, dh), kv_heads=hkv)
    assert p["w"].shape == (d, h * dh)
    assert p["b"].shape == (h * dh,)
    # group order matches the per-query-head mask: head qh reads kv head
    # qh // (h // hkv)
    g = h // hkv
    for qh in range(h):
        np.testing.assert_array_equal(
            np.asarray(p["w"][:, qh * dh:(qh + 1) * dh]),
            np.asarray(w[:, (qh // g) * dh:(qh // g + 1) * dh]))


# ------------------------------------------------------------ serving path
def test_engine_multihead_fused_decode(lm_zoo):
    """The engine decodes a multi-head (grouped-KV) spiking LM through the
    fused packed chain: generations match the reference engine token for
    token and the stats report the EXECUTED policy."""
    from repro.serve.engine import Engine, EngineConfig

    cfg, model, params = lm_zoo("qwen3-1.7b", **SPIKING)

    def run(ecfg):
        eng = Engine(model, params, ecfg)
        for i in range(2):
            eng.submit(np.arange(4 + i) % cfg.vocab_size, max_new=3)
        fin = eng.run_until_drained()
        return {r.uid: r.out for r in fin}, eng.stats()

    out_pk, stats_pk = run(EngineConfig(max_slots=2, max_len=32,
                                        policy="fused_packed"))
    out_ref, stats_ref = run(EngineConfig(max_slots=2, max_len=32))
    assert out_pk == out_ref
    assert stats_pk["policy"] == "fused_packed"
    assert stats_pk["spike_format"] == "packed"
    assert stats_pk["decode_ticks_measured"] > 0
