"""Straggler monitor + sharded loader (elastic-scale substrate units)."""
import numpy as np
import pytest

from repro.train.straggler import StragglerMonitor


def _feed(mon, times_by_worker, steps=10):
    for s in range(steps):
        for w, t in enumerate(times_by_worker):
            mon.record(w, t * (1.0 + 0.01 * (s % 3)))


def test_no_stragglers_on_uniform_fleet():
    mon = StragglerMonitor(n_workers=8)
    _feed(mon, [1.0] * 8)
    assert mon.stragglers() == []
    assert mon.shard_assignment() == list(range(8))


def test_straggler_detected_and_shard_swapped():
    mon = StragglerMonitor(n_workers=8, threshold=1.5)
    times = [1.0] * 8
    times[3] = 2.5                      # worker 3 runs 2.5x slower
    _feed(mon, times)
    assert mon.stragglers() == [3]
    assignment = mon.shard_assignment()
    # worker 3 no longer owns shard 3; a healthy fast worker does
    assert assignment[3] != 3
    assert sorted(assignment) == list(range(8))   # permutation (no data loss)


def test_assignment_deterministic():
    """Every host must compute the SAME assignment (no coordinator)."""
    def build():
        m = StragglerMonitor(n_workers=6, threshold=1.4)
        times = [1.0, 1.0, 3.0, 1.0, 1.1, 0.9]
        _feed(m, times)
        return m.shard_assignment()
    assert build() == build()


def test_warmup_suppresses_flags():
    mon = StragglerMonitor(n_workers=4, warmup_steps=5)
    for w in range(4):
        mon.record(w, 10.0 if w == 0 else 1.0)
    assert mon.stragglers() == []       # only 1 sample each


def test_summary_shape():
    mon = StragglerMonitor(n_workers=3)
    _feed(mon, [1.0, 1.0, 5.0])
    s = mon.summary()
    assert len(s["ewma"]) == 3 and s["stragglers"] == [2]


def test_loader_reassign():
    import jax
    from repro.data import ShardedLoader, SyntheticTokenDataset
    ds = SyntheticTokenDataset(64, 8, seed=1)
    mesh = jax.make_mesh((1,), ("data",))
    loader = ShardedLoader(
        lambda step, bs, shard, n: {"tokens": ds.batch(step, bs, shard, n)},
        global_batch=4, mesh=mesh, n_shards=4, shard=0)
    a = np.asarray(loader(3)["tokens"])
    loader.reassign(shard=2, n_shards=4)
    b = np.asarray(loader(3)["tokens"])
    assert not np.array_equal(a, b)     # different shard, same step
    loader.reassign(shard=0, n_shards=4)
    c = np.asarray(loader(3)["tokens"])
    np.testing.assert_array_equal(a, c)  # replay-safe
