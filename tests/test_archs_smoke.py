"""Per-architecture smoke tests (the brief's (f)): every assigned arch at a
REDUCED config runs one forward + one train step on CPU, asserting output
shapes and no NaNs — plus prefill->decode cache consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, build_model, get_config, reduced
from repro.optim import adamw_init, adamw_update

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)
    if cfg.family == "vlm":
        return {"tokens": toks,
                "img_embeds": jax.random.normal(
                    jax.random.PRNGKey(key + 1),
                    (b, cfg.n_img_tokens, cfg.d_vision))}
    if cfg.family == "encdec":
        return {"src_embeds": jax.random.normal(
                    jax.random.PRNGKey(key + 1), (b, s, cfg.d_src)),
                "tgt_tokens": toks}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.abs(g).sum())
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"

    opt = adamw_init(params)
    new_p, _ = adamw_update(grads, opt, params, lr=1e-3)
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_p), jax.tree_util.tree_leaves(params)))
    assert delta > 0, f"{arch}: params did not move"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, cache = model.prefill(params, batch, max_len=40)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(jnp.asarray(cache2["len"]).reshape(-1)[0]) == \
        int(jnp.asarray(cache["len"]).reshape(-1)[0]) + 1


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "olmoe-1b-7b", "zamba2-7b"])
def test_spiking_mode(arch):
    """Paper technique flags (C1/C3/C4) apply across families."""
    cfg = reduced(get_config(arch), spiking=True)
    if cfg.family != "hybrid":      # hybrid keeps softmax in shared block
        cfg = dataclasses.replace(cfg, attention_kind="qk_spiking")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g))
    assert gn > 0, "surrogate gradients must flow in spiking mode"


def test_decode_matches_prefill_continuation():
    """KEY consistency: prefill(s tokens) + decode(token s+1) must equal
    prefill(s+1 tokens) — cache semantics are exact."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    # full prefill over s+1 tokens
    full_logits, _ = model.prefill(params, {"tokens": toks})
    # prefill s (with decode headroom), then decode the last token
    part_logits, cache = model.prefill(params, {"tokens": toks[:, :-1]},
                                       max_len=17)
    dec_logits, _ = model.decode_step(params, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-7b"])
def test_decode_matches_prefill_continuation_ssm(arch):
    """Same exactness for the recurrent (state-based) cache."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    full_logits, _ = model.prefill(params, {"tokens": toks})
    part_logits, cache = model.prefill(params, {"tokens": toks[:, :-1]},
                                       max_len=17)
    dec_logits, _ = model.decode_step(params, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)
