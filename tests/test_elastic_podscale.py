"""Elastic re-mesh with the PRODUCTION mesh topology (pod, data, model),
scaled to 32 virtual devices so collectives can actually EXECUTE on one CPU
core (512-thread rendezvous deadlocks a 1-core host; the full-size meshes
are exercised compile-only by the dry-run): compile+run a train step on the
2-pod mesh, lose a pod, rebuild the 1-pod mesh via make_elastic_mesh,
reshard the checkpoint onto it, recompile, and take a step.

The ``ElasticRunner`` edge-case tests below run IN-PROCESS on 1-device
meshes (the re-mesh/reshard/resume control flow is device-count-agnostic):
failure at step 0 with no checkpoint on disk, back-to-back failures before
any ``restore_capacity``, and a failure on the very first step after a
downgrade.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_pod_loss_remesh_at_512():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, build_model
        from repro.launch.mesh import make_production_mesh, make_elastic_mesh
        from repro.models import sharding as shd
        from repro.optim.schedules import constant_lr
        from repro.train import (make_train_step, train_state_init,
                                 save_checkpoint, restore_checkpoint,
                                 latest_checkpoint)
        import tempfile

        cfg = reduced(get_config('qwen3-1.7b'))
        model = build_model(cfg)
        step = make_train_step(model, schedule=constant_lr(1e-3))
        ckdir = tempfile.mkdtemp()

        def run_on(mesh, state=None):
            shd.set_global_mesh(mesh)
            NS = lambda t: jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda s: isinstance(s, P))
            if state is None:
                params = model.init(jax.random.PRNGKey(0))
                params = jax.device_put(params, NS(shd.param_specs(params, mesh)))
                state = train_state_init(params)
            batch = {'tokens': jax.device_put(
                jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                   cfg.vocab_size),
                NS(shd.batch_specs({'t': jax.ShapeDtypeStruct((8, 32),
                                                              jnp.int32)},
                                   mesh))['t'])}
            with mesh:
                state, m = jax.jit(step)(state, batch)
            return state, float(m['loss'])

        # 2 pods of (data=4, model=4) = 32 chips (production topology)
        mesh2 = jax.make_mesh((2, 4, 4), ('pod', 'data', 'model'),
                              devices=jax.devices()[:32])
        state, loss2 = run_on(mesh2)
        save_checkpoint(ckdir, int(state.step), state)

        # pod failure -> elastic 1-pod mesh (16 chips), reshard, resume
        mesh1 = make_elastic_mesh(1, pod_shape=(4, 4))
        shd.set_global_mesh(mesh1)
        shape = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        NS1 = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh1, s), t,
            is_leaf=lambda s: isinstance(s, P))
        from repro.optim.adamw import AdamWState
        sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh1, P()),
                                    shape)
        restored, stp = restore_checkpoint(latest_checkpoint(ckdir), shape, sh)
        state3, loss1 = run_on(mesh1, restored)
        print('OK steps', stp, int(state3.step), 'losses', loss2, loss1)
        assert int(state3.step) == stp + 1
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=32",
               PYTHONPATH=str(ROOT / "src"), JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK steps" in r.stdout


# ===================================================== ElasticRunner edges
def _make_runner(lm_zoo, ckpt_dir, *, ckpt_every=2, n_builders=3):
    """In-process ElasticRunner on 1-device meshes: every builder is
    buildable, so ``level`` tracks pure control-flow (degrade on failure,
    climb on restore_capacity) without needing a multi-device host."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.optim.schedules import constant_lr
    from repro.train import make_train_step, train_state_init
    from repro.train.elastic import ElasticConfig, ElasticRunner

    cfg, model, params = lm_zoo("qwen3-1.7b")
    step = make_train_step(model, schedule=constant_lr(1e-3))
    builders = [
        (lambda: jax.make_mesh((1,), ("data",))) for _ in range(n_builders)]

    def make_step(mesh):
        return jax.jit(step)

    def make_state(mesh):
        return train_state_init(params)

    def state_shardings(shape, mesh):
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), shape)

    tokens = jnp.asarray(
        __import__("numpy").random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 16)), jnp.int32)

    def loader(step_idx):
        return {"tokens": tokens}

    return ElasticRunner(builders, make_step, make_state, state_shardings,
                         loader, ElasticConfig(ckpt_dir=str(ckpt_dir),
                                               ckpt_every=ckpt_every))


def test_elastic_failure_at_step_zero_no_checkpoint(lm_zoo, tmp_path):
    """Failure BEFORE the first step with an empty checkpoint dir: the
    runner must degrade the mesh and restart from a FRESH init (there is
    nothing to restore) instead of crashing on a missing checkpoint."""
    runner = _make_runner(lm_zoo, tmp_path / "ck0")
    runner.inject_failure(0)
    state, events = runner.run(2)
    assert int(state.step) == 2
    kinds = [e["kind"] for e in events]
    assert kinds[:2] == ["failure", "remesh"]
    assert runner.level == 1
    # fresh init, not a restore: no restore event before the remesh
    assert "restore" not in kinds
    (remesh,) = [e for e in events if e["kind"] == "remesh"]
    assert remesh["resume_step"] == 0


def test_elastic_back_to_back_failures_before_restore(lm_zoo, tmp_path):
    """Two failures with NO restore_capacity in between: level degrades
    monotonically (0 -> 1 -> 2), each recovery resumes from the latest
    durable checkpoint, and training still reaches the target step."""
    runner = _make_runner(lm_zoo, tmp_path / "ck1")
    state, _ = runner.run(3)            # checkpoint lands at step 2
    runner.inject_failure(3)
    state, _ = runner.run(4)
    assert runner.level == 1 and int(state.step) == 4
    runner.inject_failure(4)            # second failure, still degraded
    state, events = runner.run(6)
    assert runner.level == 2 and int(state.step) == 6
    fails = [e["step"] for e in events if e["kind"] == "failure"]
    assert fails == [3, 4]
    # every restore — each run()'s warm start AND both post-failure
    # recoveries — came from the step-2 checkpoint (the latest durable)
    restores = [e["step"] for e in events if e["kind"] == "restore"]
    assert len(restores) >= 2 and set(restores) == {2}
    runner.restore_capacity()
    assert runner.level == 0


def test_elastic_failure_on_first_step_after_downgrade(lm_zoo, tmp_path):
    """The downgraded mesh dies on the VERY FIRST step it executes (before
    it ever writes a checkpoint of its own): the runner must re-degrade a
    level further and re-restore from the same pre-failure checkpoint, not
    loop or lose the durable state. The second failure is armed from
    inside the loader — the only hook that runs between the remesh and the
    first degraded step."""
    runner = _make_runner(lm_zoo, tmp_path / "ck2")
    base_loader, tripped = runner.loader, []

    def tripwire(step_idx):
        if not tripped and any(e["kind"] == "remesh" for e in runner.events):
            tripped.append(step_idx)
            runner.inject_failure(step_idx + 1)  # dies right after this step
        return base_loader(step_idx)

    runner.loader = tripwire
    runner.run(3)                       # durable checkpoint labeled step 2
    runner.inject_failure(3)
    state, events = runner.run(6)
    assert int(state.step) == 6
    assert runner.level == 2            # two downgrades, no capacity back
    # the degraded mesh got exactly one step in before its own failure
    assert tripped == [3]
    fails = [e["step"] for e in events if e["kind"] == "failure"]
    assert fails == [3, 4]
    # both recoveries (and run(6)'s warm start) restored the SAME durable
    # checkpoint — the one labeled step 2, written before any failure
    restores = [e["step"] for e in events if e["kind"] == "restore"]
    assert len(restores) >= 2 and set(restores) == {2}
    remeshes = [e["resume_step"] for e in events if e["kind"] == "remesh"]
    assert remeshes == [3, 3]
