"""Elastic re-mesh with the PRODUCTION mesh topology (pod, data, model),
scaled to 32 virtual devices so collectives can actually EXECUTE on one CPU
core (512-thread rendezvous deadlocks a 1-core host; the full-size meshes
are exercised compile-only by the dry-run): compile+run a train step on the
2-pod mesh, lose a pod, rebuild the 1-pod mesh via make_elastic_mesh,
reshard the checkpoint onto it, recompile, and take a step.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_pod_loss_remesh_at_512():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, build_model
        from repro.launch.mesh import make_production_mesh, make_elastic_mesh
        from repro.models import sharding as shd
        from repro.optim.schedules import constant_lr
        from repro.train import (make_train_step, train_state_init,
                                 save_checkpoint, restore_checkpoint,
                                 latest_checkpoint)
        import tempfile

        cfg = reduced(get_config('qwen3-1.7b'))
        model = build_model(cfg)
        step = make_train_step(model, schedule=constant_lr(1e-3))
        ckdir = tempfile.mkdtemp()

        def run_on(mesh, state=None):
            shd.set_global_mesh(mesh)
            NS = lambda t: jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda s: isinstance(s, P))
            if state is None:
                params = model.init(jax.random.PRNGKey(0))
                params = jax.device_put(params, NS(shd.param_specs(params, mesh)))
                state = train_state_init(params)
            batch = {'tokens': jax.device_put(
                jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                   cfg.vocab_size),
                NS(shd.batch_specs({'t': jax.ShapeDtypeStruct((8, 32),
                                                              jnp.int32)},
                                   mesh))['t'])}
            with mesh:
                state, m = jax.jit(step)(state, batch)
            return state, float(m['loss'])

        # 2 pods of (data=4, model=4) = 32 chips (production topology)
        mesh2 = jax.make_mesh((2, 4, 4), ('pod', 'data', 'model'),
                              devices=jax.devices()[:32])
        state, loss2 = run_on(mesh2)
        save_checkpoint(ckdir, int(state.step), state)

        # pod failure -> elastic 1-pod mesh (16 chips), reshard, resume
        mesh1 = make_elastic_mesh(1, pod_shape=(4, 4))
        shd.set_global_mesh(mesh1)
        shape = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        NS1 = lambda t: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh1, s), t,
            is_leaf=lambda s: isinstance(s, P))
        from repro.optim.adamw import AdamWState
        sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh1, P()),
                                    shape)
        restored, stp = restore_checkpoint(latest_checkpoint(ckdir), shape, sh)
        state3, loss1 = run_on(mesh1, restored)
        print('OK steps', stp, int(state3.step), 'losses', loss2, loss1)
        assert int(state3.step) == stp + 1
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=32",
               PYTHONPATH=str(ROOT / "src"), JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK steps" in r.stdout
