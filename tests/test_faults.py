"""Self-healing serving: deterministic fault injection end to end.

The decisive invariants (fixed FaultPlan seeds — every run replays the
same failure script):

  * under the seeded chaos trace (replica killed mid-trace + NaN
    injections + a forced fused-kernel failure) the router completes every
    non-cancelled request EXACTLY ONCE with outputs BIT-IDENTICAL to a
    fault-free run of the same requests;
  * the integrity guard quarantines a poisoned slot (NaN state / corrupted
    packed word) instead of crashing, and the quarantine replay is
    bit-identical with at-most-once FIFO delivery;
  * a fused-kernel raise demotes that (op, mode) to the reference
    implementation (recorded in stats/autotuner) and serving continues;
  * deadlines and cancel() reclaim slots and surface through Request
    status + stats counters;
  * run_until_drained raises StalledEngine on livelock instead of
    silently returning partial work.
"""
import warnings

import numpy as np
import pytest

from repro.core.events import check_packed_invariants, pad_lane_mask
from repro.ops import fallback
from repro.serve import (AllReplicasDead, Engine, EngineConfig, FaultPlan,
                         ReplicaFailure, ReplicaRouter, StalledEngine,
                         clear_jit_cache, demo_chaos_plan)

ARCH = "qwen3-1.7b"
SPIKING = dict(attention_kind="qk_spiking", spiking=True)
SEED = 7


@pytest.fixture(autouse=True)
def _fault_hygiene():
    """Demotions and armed kernel faults are process-global and sticky;
    compiled engine steps bake the demoted graph in. Reset both after any
    test that used them so later suites see pristine fused kernels."""
    yield
    if fallback.demotions() or fallback.armed_kernel_faults():
        fallback.reset()
        clear_jit_cache()


def _prompts(n=4, lens=(3, 10), seed=0, vocab=512):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, int(rng.integers(*lens)))
            for _ in range(n)]


def _engine(lm_zoo, faults=None, spiking=True, **cfg_kw):
    cfg, model, params = lm_zoo(ARCH, **(SPIKING if spiking else {}))
    kw = dict(max_slots=2, max_len=64, prefill_pad=8)
    if spiking:
        kw["policy"] = "fused_packed"
    kw.update(cfg_kw)
    return cfg, Engine(model, params, EngineConfig(**kw), faults=faults)


def _drain(eng, prompts, max_new=6):
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    fin = {r.uid: r for r in eng.run_until_drained()}
    return uids, fin


# ================================================================ FaultPlan
def test_fault_plan_builders_and_determinism():
    plan = (FaultPlan(SEED).nan_state(3).corrupt_word(5, slot=1)
            .kill_replica(8, replica=1).stall_consumer(2, ticks=4)
            .fail_kernel("dense_lif", at_call=2))
    assert len(plan) == 5
    s = plan.summary()
    assert s["seed"] == SEED and s["pending"] == 5 and s["fired"] == 0
    # events fire once at the first tick >= their tick, and defer re-arms
    assert [e.kind for e in plan.due(("nan_state", "corrupt_word"), 4)] \
        == ["nan_state"]
    assert plan.due("nan_state", 10) == []          # already fired
    (ev,) = plan.due("corrupt_word", 6)
    plan.defer(ev)
    assert [e.kind for e in plan.due("corrupt_word", 6)] == ["corrupt_word"]
    assert plan.die_due(7) is None and plan.die_due(8).replica == 1


def test_fault_plan_view_slices_by_replica_and_shares_events():
    plan = (FaultPlan(0).nan_state(2, replica=0).kill_replica(4, replica=1)
            .fail_kernel())
    v0, v1 = plan.view(0), plan.view(1)
    assert [e.kind for e in v0.events] == ["nan_state"]
    assert [e.kind for e in v1.events] == ["die"]    # kernel faults excluded
    v1.die_due(4)
    assert plan.events[1].fired                     # shared event objects


# ===================================================== packed-word invariants
def test_pad_lane_mask_marks_exactly_the_pad_columns():
    mask = pad_lane_mask(40, 3).view(np.uint32)
    assert mask[0] == 0                              # cols 0..31 all valid
    assert mask[1] == 0xFFFFFF00                     # cols 32..39 valid
    assert mask[2] == 0xFFFFFFFF                     # cols 64..95 all pad


def test_check_packed_invariants_flags_corruption():
    from repro import ops

    spikes = (np.random.default_rng(0).random((16, 40)) < 0.3) \
        .astype(np.int8)
    ps = ops.pack(spikes).to_packed_spikes()
    assert check_packed_invariants(ps)["ok"]
    bad = ps.words.at[0, -1].set(np.int32(-1))      # pad lanes + count drift
    import dataclasses

    verdict = check_packed_invariants(dataclasses.replace(ps, words=bad))
    assert not verdict["ok"]
    assert verdict["pad_cols"] > 0 and verdict["vld_mismatch"] > 0


# ========================================================= deadlines + cancel
def test_cancel_everywhere_in_the_pipeline(lm_zoo):
    _, eng = _engine(lm_zoo, spiking=False)
    u_q = [eng.submit(p, max_new=20) for p in _prompts(4)]
    assert eng.cancel(u_q[3])                        # still queued
    for _ in range(2):
        eng.step()
    assert eng.cancel(u_q[0])                        # mid-decode: slot freed
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert fin[u_q[3]].status == "cancelled" and fin[u_q[3]].out == []
    assert fin[u_q[0]].status == "cancelled"
    assert fin[u_q[1]].status == fin[u_q[2]].status == "done"
    assert not eng.cancel(u_q[0])                    # terminal: no-op
    st = eng.stats()
    assert st["cancelled"] == 2 and st["n"] == 2 and st["n_terminal"] == 4
    # tokens emitted before the cancel stay drainable
    assert eng.pop_output(u_q[0]) == fin[u_q[0]].out


def test_deadline_ticks_and_status(lm_zoo):
    _, eng = _engine(lm_zoo, spiking=False, max_slots=1)
    fast = eng.submit(np.arange(1, 4), max_new=3)
    slow = eng.submit(np.arange(1, 6), max_new=30, deadline_ticks=2)
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert fin[fast].status == "done"
    # with one slot, the deadline passes while the request queues
    assert fin[slow].status == "deadline_miss"
    assert eng.stats()["deadline_miss"] == 1


def test_config_default_deadline(lm_zoo):
    _, eng = _engine(lm_zoo, spiking=False, deadline_ticks=3)
    uid = eng.submit(np.arange(1, 4), max_new=30)
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert fin[uid].status == "deadline_miss"
    assert 0 < len(fin[uid].out) <= 4


# ====================================================== integrity quarantine
def test_quarantine_replay_bit_identical_packed(lm_zoo):
    """NaN + packed-word corruption on the spiking packed engine: the
    guard quarantines the poisoned slot, the replay regenerates the exact
    greedy stream, and FIFO delivery stays at-most-once."""
    prompts = _prompts(3, seed=1)
    _, ref_eng = _engine(lm_zoo, integrity_every=1)
    _, ref = _drain(ref_eng, prompts)

    plan = FaultPlan(SEED).corrupt_word(2).nan_state(4)
    _, eng = _engine(lm_zoo, faults=plan, integrity_every=1)
    uids, fin = _drain(eng, prompts)
    assert sorted(fin) == sorted(uids)
    assert {u: fin[u].out for u in uids} == {u: ref[u].out for u in uids}
    assert all(fin[u].status == "done" for u in uids)
    st = eng.stats()
    assert st["quarantined"] == 2 and st["requeues"] == 2
    # at-most-once: the FIFO holds each token exactly once
    for u in uids:
        assert eng.pop_output(u) == fin[u].out


def test_quarantine_nan_state_dense_kv(lm_zoo):
    """Dense-attention engine: NaN lands in the float KV pool and the
    finite-check guard evicts + replays the slot."""
    prompts = _prompts(3, seed=2)
    _, ref_eng = _engine(lm_zoo, spiking=False, integrity_every=1)
    _, ref = _drain(ref_eng, prompts)
    plan = FaultPlan(SEED).nan_state(3)
    _, eng = _engine(lm_zoo, spiking=False, faults=plan, integrity_every=1)
    uids, fin = _drain(eng, prompts)
    assert {u: fin[u].out for u in uids} == {u: ref[u].out for u in uids}
    assert eng.stats()["quarantined"] == 1


def test_quarantine_retry_budget_fails_request(lm_zoo):
    """A slot poisoned on every tick exhausts its retry budget and FAILS
    (loudly, in status + stats) instead of requeueing forever."""
    plan = FaultPlan(SEED)
    for t in range(1, 30):
        plan.nan_logits(t, slot=1)      # highest slot = first admitted
    _, eng = _engine(lm_zoo, faults=plan, quarantine_retries=1,
                     integrity_every=1)
    uid = eng.submit(np.arange(1, 5), max_new=6)
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert fin[uid].status == "failed"
    st = eng.stats()
    assert st["failed"] == 1 and st["quarantined"] == 2  # budget 1 -> 2 hits
    assert st["n"] == 0 and st["n_terminal"] == 1


def test_guard_disabled_by_default(lm_zoo):
    _, eng = _engine(lm_zoo, spiking=False)
    _drain(eng, _prompts(2))
    assert eng.stats()["guard_scans"] == 0


def test_no_fault_guard_parity(lm_zoo):
    """Guards on vs off without faults: identical outputs (the <5%
    overhead bound is measured in benchmarks/serve_throughput.py)."""
    prompts = _prompts(4, seed=3)
    _, e0 = _engine(lm_zoo, integrity_every=0)
    _, r0 = _drain(e0, prompts)
    _, e1 = _engine(lm_zoo, integrity_every=1)
    _, r1 = _drain(e1, prompts)
    assert {u: r.out for u, r in r0.items()} \
        == {u: r.out for u, r in r1.items()}
    assert e1.stats()["guard_scans"] > 0 and e1.stats()["quarantined"] == 0


# ========================================================== consumer stalls
def test_forced_consumer_stall_is_exact(lm_zoo):
    """stall_consumer freezes one slot's drain for a window; outputs stay
    bit-identical (the rollback path the out-FIFO stall machinery uses)."""
    prompts = _prompts(3, seed=4)
    _, ref_eng = _engine(lm_zoo, spiking=False)
    _, ref = _drain(ref_eng, prompts)
    plan = FaultPlan(SEED).stall_consumer(2, ticks=3)
    _, eng = _engine(lm_zoo, spiking=False, faults=plan, out_fifo_depth=64)
    uids, fin = _drain(eng, prompts)
    assert {u: fin[u].out for u in uids} == {u: ref[u].out for u in uids}
    assert eng._stall_ticks > 0


# ========================================================== StalledEngine
def test_run_until_drained_raises_on_livelock(lm_zoo):
    """Every slot stalled on an undrained FIFO, nobody pops: the old code
    silently returned after max_ticks; now the livelock is named."""
    _, eng = _engine(lm_zoo, spiking=False, out_fifo_depth=1)
    uids = [eng.submit(p, max_new=8) for p in _prompts(2)]
    with pytest.raises(StalledEngine) as ei:
        eng.run_until_drained(stall_grace=10)
    rep = ei.value.report
    assert set(rep["stuck_slots"]) and rep["queued"] == 0
    assert {s["uid"] for s in rep["stuck_slots"].values()} <= set(uids)
    # draining the FIFOs un-stalls: the same engine then finishes clean
    for _ in range(200):
        eng.step()
        for u in uids:
            eng.pop_output(u)
        if not eng.pending():
            break
    assert not eng.pending()
    assert {r.uid for r in eng.finished} == set(uids)


def test_run_until_drained_raises_on_budget_exhaustion(lm_zoo):
    _, eng = _engine(lm_zoo, spiking=False)
    eng.submit(np.arange(1, 4), max_new=30)
    with pytest.raises(StalledEngine, match="max_ticks"):
        eng.run_until_drained(max_ticks=3)


def test_router_run_until_drained_raises_on_livelock(lm_zoo):
    cfg, model, params = lm_zoo(ARCH)
    router = ReplicaRouter(
        model, params,
        EngineConfig(max_slots=2, max_len=64, prefill_pad=8,
                     out_fifo_depth=1), n_replicas=2)
    for p in _prompts(3, seed=5):
        router.submit(p, max_new=8)
    with pytest.raises(StalledEngine):
        router.run_until_drained(stall_grace=10)


# ==================================================== fused-kernel demotion
def test_kernel_fault_demotes_to_reference():
    """An armed fused-kernel raise falls back to the reference impl for
    that (op, mode), warns, records the demotion, and steers the autotuner
    away from the broken op."""
    import jax.numpy as jnp

    from repro import ops
    from repro.ops.autotune import get_tuner

    x = (np.random.default_rng(0).random((16, 64)) < 0.3).astype(np.int8)
    w = np.random.default_rng(1).standard_normal((64, 32)).astype(np.float32)
    ref = ops.matmul(x, jnp.asarray(w), policy="reference")
    fallback.arm_kernel_fault("matmul", at_call=0)
    with warnings.catch_warnings(record=True) as wlog:
        warnings.simplefilter("always")
        out = ops.matmul(x, jnp.asarray(w), policy="fused_dense")
    assert any("demoted" in str(x.message) for x in wlog)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert fallback.is_demoted("matmul")
    assert [d["op"] for d in fallback.demotions()] == ["matmul"]
    # sticky: later fused calls route to reference without re-raising
    out2 = ops.matmul(x, jnp.asarray(w), policy="fused_dense")
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    # the autotuner stops pricing the broken op: "auto" resolves reference
    assert get_tuner().is_demoted("matmul")
    out3 = ops.matmul(x, jnp.asarray(w), policy="auto")
    np.testing.assert_array_equal(np.asarray(out3), np.asarray(ref))
    assert "matmul" in get_tuner().snapshot()["demoted_ops"]
    fallback.reset_demotions()
    assert not fallback.is_demoted("matmul")
    assert not get_tuner().is_demoted("matmul")


def test_contract_errors_do_not_demote():
    """ValueError from a shape/argument contract must propagate — masking
    a caller bug behind a reference fallback would hide it. Only
    RuntimeError (XLA/Mosaic failures, injected faults) demotes."""
    from repro.ops.registry import _REGISTRY, lookup, register

    def _contract(*a, **k):
        raise ValueError("bad block shape")

    def _ref(*a, **k):
        return "ref"

    try:
        register("tmp_contract_op", "fused")(_contract)
        register("tmp_contract_op", "reference")(_ref)
        with pytest.raises(ValueError, match="bad block shape"):
            lookup("tmp_contract_op", "fused")()
        assert not fallback.demotions()
        # the RuntimeError twin of the same op DOES demote
        def _boom(*a, **k):
            raise RuntimeError("mosaic lowering failed")
        _REGISTRY[("tmp_contract_op", "fused")] = _boom
        with pytest.warns(RuntimeWarning, match="demoted"):
            assert lookup("tmp_contract_op", "fused")() == "ref"
        assert fallback.is_demoted("tmp_contract_op")
    finally:
        _REGISTRY.pop(("tmp_contract_op", "fused"), None)
        _REGISTRY.pop(("tmp_contract_op", "reference"), None)
        fallback.reset()


# ============================================== the seeded chaos acceptance
def test_chaos_trace_exactly_once_bit_identical(lm_zoo):
    """THE acceptance invariant: 1 replica killed mid-trace + 2 NaN
    injections + 1 forced fused-kernel failure; every request completes
    exactly once, outputs bit-identical to the fault-free run."""
    cfg, model, params = lm_zoo(ARCH, **SPIKING)
    ecfg = EngineConfig(max_slots=2, max_len=64, prefill_pad=8,
                        policy="fused_packed", integrity_every=1)
    prompts = _prompts(6, seed=6)

    ref_router = ReplicaRouter(model, params, ecfg, n_replicas=2)
    ref_uids = [ref_router.submit(p, max_new=6) for p in prompts]
    ref = {r.uid: r.out for r in ref_router.run_until_drained()}

    clear_jit_cache()   # the chaos run must re-trace: its kernel fault
    # fires at trace time and demotes dense_lif before compilation
    plan = demo_chaos_plan(SEED, n_replicas=2, kill_tick=3, nan_ticks=(2, 5))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        router = ReplicaRouter(model, params, ecfg, n_replicas=2,
                               faults=plan)
        uids = [router.submit(p, max_new=6) for p in prompts]
        fin = router.run_until_drained()
    got = {r.uid: r.out for r in fin}
    assert sorted(got) == sorted(uids)              # exactly once
    assert len(fin) == len(prompts)
    assert got == {u: ref[ru] for u, ru in zip(uids, ref_uids)}
    st = router.stats()
    assert st["alive"] == [True, False] and st["failovers"] == 1
    assert st["requeued"] >= 1
    assert [d["op"] for d in fallback.demotions()] == ["dense_lif"]
    # at-most-once delivery through the router-level ledger
    for u in uids:
        assert router.pop_output(u) == got[u]
        assert router.pop_output(u) == []


def test_failover_preserves_partial_delivery(lm_zoo):
    """Tokens the consumer popped BEFORE the replica died are never
    re-delivered; the undelivered remainder arrives exactly once."""
    cfg, model, params = lm_zoo(ARCH)
    ecfg = EngineConfig(max_slots=2, max_len=64, prefill_pad=8)
    ref_router = ReplicaRouter(model, params, ecfg, n_replicas=2)
    prompts = _prompts(2, seed=7)
    ref_uids = [ref_router.submit(p, max_new=8) for p in prompts]
    ref = {r.uid: r.out for r in ref_router.run_until_drained()}

    plan = FaultPlan(SEED).kill_replica(4, replica=1)
    router = ReplicaRouter(model, params, ecfg, n_replicas=2, faults=plan)
    uids = [router.submit(p, max_new=8) for p in prompts]
    streamed = {u: [] for u in uids}
    for _ in range(200):
        router.step()
        for u in uids:
            streamed[u].extend(router.pop_output(u))
        if not router.pending():
            break
    assert not router.pending()
    assert streamed == {u: ref[ru] for u, ru in zip(uids, ref_uids)}
    assert router.stats()["failovers"] == 1


def test_all_replicas_dead_raises(lm_zoo):
    cfg, model, params = lm_zoo(ARCH)
    plan = FaultPlan(SEED).kill_replica(2, replica=0) \
        .kill_replica(3, replica=1)
    router = ReplicaRouter(model, params,
                           EngineConfig(max_slots=2, max_len=64,
                                        prefill_pad=8),
                           n_replicas=2, faults=plan)
    for p in _prompts(3, seed=8):
        router.submit(p, max_new=20)
    with pytest.raises(AllReplicasDead):
        router.run_until_drained()


def test_single_engine_replica_death_propagates(lm_zoo):
    """Without a router there is nowhere to fail over: the injected death
    surfaces to the caller."""
    plan = FaultPlan(SEED).kill_replica(1)
    _, eng = _engine(lm_zoo, spiking=False, faults=plan)
    eng.submit(np.arange(1, 5), max_new=8)
    with pytest.raises(ReplicaFailure):
        eng.run_until_drained()


def test_submit_skips_dead_replica(lm_zoo):
    cfg, model, params = lm_zoo(ARCH)
    router = ReplicaRouter(model, params,
                           EngineConfig(max_slots=2, max_len=64,
                                        prefill_pad=8), n_replicas=2)
    router._fail_replica(1, "test")
    for p in _prompts(4, seed=9):
        router.submit(p, max_new=4)
    fin = router.run_until_drained()
    assert len(fin) == 4
    st = router.stats()
    assert st["dispatch"][1] == 0 and st["alive"] == [True, False]
