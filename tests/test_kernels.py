"""Pallas kernels vs pure-jnp oracles (interpret mode executes the kernel
body on CPU). Shape/dtype sweeps per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.lif_update import lif_update, lif_update_ref
from repro.kernels.qk_attention import qk_attention_fused, qk_attention_ref
from repro.kernels.spike_matmul import spike_matmul, spike_matmul_ref
from repro.kernels.spike_matmul.ops import block_sparsity
from repro.kernels.w2ttfs_pool import w2ttfs_pool_fc, w2ttfs_pool_fc_ref


# ------------------------------------------------------------- spike_matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 200, 60), (130, 129, 257)])
@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
def test_spike_matmul_shapes_dtypes(m, k, n, wdtype):
    x = (jax.random.uniform(jax.random.PRNGKey(m + n), (m, k)) < 0.15
         ).astype(jnp.int8)
    w = (jax.random.normal(jax.random.PRNGKey(k), (k, n)) * 0.1).astype(wdtype)
    out = spike_matmul(x, w)
    ref = spike_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2 if wdtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if wdtype == jnp.bfloat16 else 1e-5)


def test_spike_matmul_all_silent_blocks_exact_zero():
    """Event skip correctness at the extreme: zero input -> zero output,
    every block skipped."""
    x = jnp.zeros((256, 256), jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    out = spike_matmul(x, w)
    assert float(jnp.abs(out).max()) == 0.0
    assert float(block_sparsity(x)) == 1.0


@given(st.integers(0, 1000), st.floats(0.0, 0.5))
@settings(max_examples=10)
def test_spike_matmul_property(seed, rate):
    """Property: event-driven result == dense oracle for any sparsity."""
    x = (jax.random.uniform(jax.random.PRNGKey(seed), (128, 256)) < rate
         ).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, 128)) * 0.1
    np.testing.assert_allclose(np.asarray(spike_matmul(x, w)),
                               np.asarray(spike_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_spike_matmul_structured_sparsity_skips():
    """Silent row-blocks are skipped yet dense rows stay exact."""
    x = jnp.zeros((256, 256), jnp.int8).at[:128].set(1)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128)) * 0.1
    assert float(block_sparsity(x)) == 0.5
    np.testing.assert_allclose(np.asarray(spike_matmul(x, w)),
                               np.asarray(spike_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- qk_attention
@pytest.mark.parametrize("n,d", [(64, 32), (100, 64), (256, 128), (33, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_qk_attention_shapes_dtypes(n, d, dtype):
    q = (jax.random.uniform(jax.random.PRNGKey(n), (2, n, d)) < 0.1
         ).astype(dtype)
    k = (jax.random.uniform(jax.random.PRNGKey(d), (2, n, d)) < 0.3
         ).astype(dtype)
    out = qk_attention_fused(q, k)
    ref = qk_attention_ref(q, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@given(st.integers(0, 500), st.floats(0.0, 1.0))
@settings(max_examples=10)
def test_qk_attention_property(seed, rate):
    q = (jax.random.uniform(jax.random.PRNGKey(seed), (3, 64, 32)) < rate
         ).astype(jnp.float32)
    k = (jax.random.uniform(jax.random.PRNGKey(seed + 1), (3, 64, 32)) < 0.5
         ).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(qk_attention_fused(q, k)),
                                  np.asarray(qk_attention_ref(q, k)))


# -------------------------------------------------------------- w2ttfs_pool
@pytest.mark.parametrize("window,b,hw,c,cls", [(2, 4, 8, 8, 10),
                                               (4, 3, 8, 16, 100),
                                               (8, 8, 8, 4, 10)])
def test_w2ttfs_pool_fused_vs_oracle(window, b, hw, c, cls):
    s = (jax.random.uniform(jax.random.PRNGKey(b), (b, hw, hw, c)) < 0.3
         ).astype(jnp.float32)
    ho = hw // window
    w = jax.random.normal(jax.random.PRNGKey(1), (ho * ho * c, cls)) * 0.1
    bias = jax.random.normal(jax.random.PRNGKey(2), (cls,))
    out = w2ttfs_pool_fc(s, w, bias, window=window)
    ref = w2ttfs_pool_fc_ref(s, w, bias, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- lif_update
@pytest.mark.parametrize("shape", [(4, 128), (3, 7, 64), (2, 5, 5, 16)])
@pytest.mark.parametrize("soft", [False, True])
def test_lif_update_fused_vs_oracle(shape, soft):
    cur = jax.random.normal(jax.random.PRNGKey(0), shape)
    v = jax.random.normal(jax.random.PRNGKey(1), shape)
    s = (jax.random.uniform(jax.random.PRNGKey(2), shape) < 0.5
         ).astype(jnp.float32)
    spk, vn = lif_update(cur, v, s, soft_reset=soft)
    spk_r, vn_r = lif_update_ref(cur, v, s, soft_reset=soft)
    np.testing.assert_array_equal(np.asarray(spk), np.asarray(spk_r))
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vn_r), rtol=1e-6)


@given(st.integers(0, 300), st.floats(0.1, 0.9), st.floats(0.5, 2.0))
@settings(max_examples=10)
def test_lif_update_property(seed, tau, vth):
    cur = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * 2
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 64))
    s = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (8, 64)) < 0.5
         ).astype(jnp.float32)
    spk, vn = lif_update(cur, v, s, tau=tau, v_th=vth)
    spk_r, vn_r = lif_update_ref(cur, v, s, tau=tau, v_th=vth)
    np.testing.assert_array_equal(np.asarray(spk), np.asarray(spk_r))
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vn_r),
                               rtol=1e-5, atol=1e-6)
    # fired neurons hard-reset to exactly 0
    assert np.all(np.asarray(vn)[np.asarray(spk) == 1] == 0.0)
