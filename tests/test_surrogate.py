"""Surrogate-gradient spike function (core enabler of C1 training)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.surrogate import available_surrogates, spike


def test_forward_is_heaviside():
    v = jnp.array([-2.0, -1e-6, 0.0, 1e-6, 3.0])
    out = spike(v)
    np.testing.assert_array_equal(np.asarray(out), [0, 0, 1, 1, 1])


@pytest.mark.parametrize("name", available_surrogates())
def test_gradient_matches_registered_surrogate(name):
    v = jnp.linspace(-2, 2, 41)
    g = jax.vmap(jax.grad(lambda x: spike(x, name, 2.0)))(v)
    assert bool(jnp.all(jnp.isfinite(g)))
    # surrogate gradients are nonnegative and peak at the threshold
    assert bool(jnp.all(g >= 0))
    assert float(g[20]) == float(jnp.max(g))    # v=0 is the peak


@given(st.floats(-10, 10), st.sampled_from(list(available_surrogates())),
       st.floats(0.5, 4.0))
def test_output_is_binary(v, name, alpha):
    out = float(spike(jnp.asarray(v, jnp.float32), name, alpha))
    assert out in (0.0, 1.0)


def test_gradient_flows_through_composition():
    # d/dw of spike(w*x - th) must be nonzero near threshold (trainability)
    f = lambda w: spike(w * 1.0 - 1.0).sum()
    g = jax.grad(f)(jnp.float32(1.0))
    assert float(g) > 0.0
