"""KD loss (C1) + quantization/fusion (F&Q stage)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kd import KDConfig, kd_loss, kl_divergence, sequence_kd_loss
from repro.core.quant import (QuantConfig, fake_quant, fuse_bn_into_conv,
                              fuse_bn_into_linear, quantize_fixed,
                              quantize_fp8)
from repro.models import nn


def test_kl_zero_when_identical():
    logits = jax.random.normal(jax.random.PRNGKey(0), (8, 10))
    assert float(kl_divergence(logits, logits, 4.0)) < 1e-5


def test_kl_positive_and_temperature_scaled():
    s = jax.random.normal(jax.random.PRNGKey(1), (8, 10))
    t = jax.random.normal(jax.random.PRNGKey(2), (8, 10))
    assert float(kl_divergence(s, t, 1.0)) > 0


def test_kd_loss_mixes_ce_and_kl():
    s = jax.random.normal(jax.random.PRNGKey(1), (8, 10))
    t = jax.random.normal(jax.random.PRNGKey(2), (8, 10))
    y = jnp.zeros((8,), jnp.int32)
    loss_kd, m = kd_loss(s, t, y, KDConfig(alpha=0.7))
    np.testing.assert_allclose(float(loss_kd),
                               0.3 * float(m["ce"]) + 0.7 * float(m["kl"]),
                               rtol=1e-5)


def test_kd_gradient_pulls_student_to_teacher():
    t = jnp.array([[4.0, 0.0, 0.0]])
    y = jnp.array([0])
    f = lambda s: kd_loss(s, t, y, KDConfig(alpha=1.0, temperature=1.0))[0]
    s = jnp.zeros((1, 3))
    g = jax.grad(f)(s)
    assert float(g[0, 0]) < 0           # raise the teacher-preferred logit


def test_sequence_kd_masks():
    s = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 8))
    t = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8))
    toks = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.ones((2, 4))
    l1, _ = sequence_kd_loss(s, t, toks, mask=mask)
    l2, _ = sequence_kd_loss(s, t, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


# ------------------------------------------------------------------- quant
@given(st.integers(2, 8), st.integers(0, 100))
@settings(max_examples=20)
def test_fixed_point_error_bound(bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    xq = quantize_fixed(x, bits)
    scale = float(jnp.max(jnp.abs(x))) / (2 ** (bits - 1) - 1)
    assert float(jnp.abs(xq - x).max()) <= scale / 2 + 1e-6


def test_fp8_roundtrip_binary_exact():
    x = jnp.array([0.0, 1.0, -1.0, 0.5])   # exactly representable in e4m3
    np.testing.assert_array_equal(np.asarray(quantize_fp8(x)), np.asarray(x))


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: quantize_fixed(x, 4).sum())(jnp.linspace(-1, 1, 16))
    np.testing.assert_allclose(np.asarray(g), np.ones(16), rtol=1e-6)


def test_bn_conv_fusion_exact():
    """F&Q operator fusion: conv+BN(eval) == fused conv. The deployment
    transform the paper runs before generating FPGA memory files."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 8, 3))
    conv_p = nn.conv_init(key, 3, 3, 3, 16)
    bn_p, bn_s = nn.bn_init(16)
    bn_p = {"scale": jax.random.uniform(key, (16,), minval=0.5, maxval=2.0),
            "bias": jax.random.normal(key, (16,))}
    bn_s = {"mean": jax.random.normal(key, (16,)) * 0.1,
            "var": jax.random.uniform(key, (16,), minval=0.5, maxval=1.5)}
    y_ref, _ = nn.bn_apply(bn_p, bn_s, nn.conv_apply(conv_p, x), train=False)
    w_f, b_f = fuse_bn_into_conv(conv_p["w"], None, bn_p["scale"],
                                 bn_p["bias"], bn_s["mean"], bn_s["var"])
    y_fused = nn.conv_apply({"w": w_f, "b": b_f}, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fused),
                               rtol=1e-4, atol=1e-5)


def test_bn_linear_fusion_exact():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 32))
    w = jax.random.normal(key, (32, 16)) * 0.1
    gamma = jax.random.uniform(key, (16,), minval=0.5, maxval=2.0)
    beta = jax.random.normal(key, (16,))
    mean = jax.random.normal(key, (16,)) * 0.1
    var = jax.random.uniform(key, (16,), minval=0.5, maxval=1.5)
    y_ref = (x @ w - mean) / jnp.sqrt(var + 1e-5) * gamma + beta
    w_f, b_f = fuse_bn_into_linear(w, None, gamma, beta, mean, var)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(x @ w_f + b_f),
                               rtol=1e-4, atol=1e-5)


def test_fake_quant_disabled_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
    np.testing.assert_array_equal(
        np.asarray(fake_quant(x, QuantConfig(enabled=False))), np.asarray(x))
