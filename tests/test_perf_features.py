"""Perf-pass features (EXPERIMENTS §Perf) must preserve semantics:
sequence parallelism, frozen-context CP decode, FP8 KV, FSDP regime."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config, reduced


def _toks(cfg, b=2, s=17, key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)


def test_seq_shard_is_numerically_transparent():
    """seq_shard only adds sharding constraints — on one device the loss
    must be IDENTICAL to the unsharded model."""
    cfg = reduced(get_config("qwen3-1.7b"))
    cfg_sp = dataclasses.replace(cfg, seq_shard=True)
    m, msp = build_model(cfg), build_model(cfg_sp)
    p = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": _toks(cfg, s=32)}
    l1, _ = m.loss(p, batch)
    l2, _ = msp.loss(p, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@pytest.mark.parametrize("arch,kv", [("qwen3-1.7b", None),
                                     ("qwen2.5-3b", None)])
def test_frozen_cp_decode_exact(arch, kv):
    """decode_cp_axis path (grouped-GQA flash-decode, no cache write) must
    equal the full-prefill continuation bit-for-bit (within bf16 tol)."""
    cfg = reduced(get_config(arch))
    cfg_cp = dataclasses.replace(cfg, decode_cp_axis="model")
    m, mcp = build_model(cfg), build_model(cfg_cp)
    p = m.init(jax.random.PRNGKey(0))
    toks = _toks(cfg)
    full, _ = m.prefill(p, {"tokens": toks})
    _, cache = m.prefill(p, {"tokens": toks[:, :-1]}, max_len=17)
    dec, cache2 = mcp.decode_step(p, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
    # frozen context: the cache must be returned UNCHANGED
    k_in, _ = cache["layers"]
    k_out, _ = cache2["layers"]
    np.testing.assert_array_equal(np.asarray(k_in), np.asarray(k_out))


def test_frozen_cp_decode_vector_lens():
    """Per-slot length vectors (serving engine) work through the CP path."""
    cfg = reduced(get_config("qwen3-1.7b"), decode_cp_axis="model")
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(3, 24)
    cache["len"] = jnp.asarray([5, 9, 3], jnp.int32)
    logits, c2 = m.decode_step(p, jnp.ones((3, 1), jnp.int32), cache)
    assert logits.shape == (3, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_fp8_kv_cache():
    """FP8 KV cache: dtype honored, decode runs, logits track bf16 closely
    (context values are O(1) activations — e4m3 keeps ~2 decimal digits)."""
    cfg8 = reduced(get_config("qwen3-1.7b"), kv_dtype="f8_e4m3")
    cfg = reduced(get_config("qwen3-1.7b"))
    m8, m = build_model(cfg8), build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    c8 = m8.init_cache(2, 16)
    assert c8["layers"][0].dtype == jnp.float8_e4m3fn
    c = m.init_cache(2, 16)
    # fill both caches from the same prefill (cast into fp8 for c8)
    toks = _toks(cfg, s=15)
    _, pc = m.prefill(p, {"tokens": toks}, max_len=16)
    k, v = pc["layers"]
    c8["layers"] = (k.astype(jnp.float8_e4m3fn), v.astype(jnp.float8_e4m3fn))
    c["layers"] = (k, v)
    c8["len"] = c["len"] = pc["len"]
    nxt = jnp.ones((2, 1), jnp.int32)
    l8, _ = m8.decode_step(p, nxt, c8)
    lbf, _ = m.decode_step(p, nxt, c)
    # same argmax, close logits
    assert (np.argmax(np.asarray(l8), -1) == np.argmax(np.asarray(lbf), -1)).all()
    np.testing.assert_allclose(np.asarray(l8), np.asarray(lbf),
                               rtol=0.2, atol=0.2)


def test_dp_over_model_flag_runs():
    """FSDP regime flag (worst-cell fix, §Perf D) is semantics-preserving."""
    cfg = reduced(get_config("mamba2-130m"), dp_over_model=True)
    cfg0 = reduced(get_config("mamba2-130m"))
    m, m0 = build_model(cfg), build_model(cfg0)
    p = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": _toks(cfg0, s=32)}
    l1, _ = m.loss(p, batch)
    l0, _ = m0.loss(p, batch)
    np.testing.assert_allclose(float(l1), float(l0), rtol=1e-6)
