"""Bit-packed spike tensors (event compression): pack->unpack bit-exactness,
metadata parity with the dense pipeline, packed operand/output paths of the
kernel suite, and end-to-end packed chaining through the deployed models.

Property-style tests use hypothesis when installed and skip gracefully via
the conftest stub otherwise (same contract as the rest of the suite).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.events import (PackedSpikes, block_count_map_2d,
                               pack_spikes_ref, pack_words, packed_from_words,
                               pad_to_blocks, popcount_block_map,
                               unpack_spikes_ref, unpack_words)
from repro.kernels.packed import pack_spikes, unpack_spikes


def _spikes(seed, shape, rate=0.2):
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < rate
            ).astype(jnp.int8)


# ------------------------------------------------------ pack/unpack exactness
@given(m=st.integers(1, 300), k=st.integers(1, 300),
       rate=st.sampled_from([0.0, 0.05, 0.5, 1.0]))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip_property(m, k, rate):
    """pack -> unpack is the identity on ANY binary map (odd shapes incl.)."""
    x = _spikes(m * 1000 + k, (m, k), rate)
    ps = pack_spikes_ref(x)
    np.testing.assert_array_equal(np.asarray(unpack_spikes_ref(ps)),
                                  np.asarray(x))


@given(m=st.integers(1, 200), k=st.integers(1, 200))
@settings(max_examples=15, deadline=None)
def test_pad_and_count_map_roundtrip_property(m, k):
    """pad_to_blocks + block_count_map_2d on odd shapes: padding adds zero
    events, total count is preserved, and the packed metadata agrees."""
    x = _spikes(m + 7 * k, (m, k))
    xp = pad_to_blocks(x, 128, 128)
    assert xp.shape == (-(-m // 128) * 128, -(-k // 128) * 128)
    cnt = block_count_map_2d(xp, 128, 128)
    assert int(cnt.sum()) == int(jnp.sum(x != 0))
    ps = pack_spikes_ref(x)
    np.testing.assert_array_equal(np.asarray(ps.vld_cnt), np.asarray(cnt))


def test_pallas_pack_matches_ref_and_is_one_pass_metadata():
    """The Pallas pack kernel's words AND popcount vld_cnt == the jnp
    reference == the dense block_count_map_2d."""
    x = _spikes(0, (260, 300))
    ps = pack_spikes(x)
    pr = pack_spikes_ref(x)
    np.testing.assert_array_equal(np.asarray(ps.words), np.asarray(pr.words))
    np.testing.assert_array_equal(np.asarray(ps.vld_cnt),
                                  np.asarray(pr.vld_cnt))
    np.testing.assert_array_equal(np.asarray(unpack_spikes(ps)),
                                  np.asarray(x))
    dense_cnt = block_count_map_2d(pad_to_blocks(x, 128, 128), 128, 128)
    np.testing.assert_array_equal(np.asarray(ps.vld_cnt),
                                  np.asarray(dense_cnt))


def test_pack_leading_dims_and_getitem():
    x = _spikes(1, (3, 2, 70, 90))
    ps = pack_spikes(x)
    assert ps.words.shape[:2] == (3, 2)
    np.testing.assert_array_equal(np.asarray(unpack_spikes(ps)),
                                  np.asarray(x))
    sub = ps[1]
    assert isinstance(sub, PackedSpikes) and sub.shape == (2, 70, 90)
    np.testing.assert_array_equal(np.asarray(sub.words),
                                  np.asarray(ps.words[1]))


def test_packed_bytes_accounting():
    ps = pack_spikes(_spikes(2, (1024, 1024)))
    # 1 bit/spike + the tiny count + occupancy maps vs 1 byte/spike
    assert 7.5 < ps.compression < 8.0
    assert ps.packed_bytes == 1024 * 1024 // 8 + 2 * (4 * 8 * 8)
    assert ps.occ is not None and ps.occ.shape == ps.vld_cnt.shape


def test_word_bit_layout_contract():
    """Word j bit b == column j*32+b (the layout the kernels decompress)."""
    x = jnp.zeros((1, 64), jnp.int8).at[0, 33].set(1)
    w = pack_words(x)
    assert w.shape == (1, 2)
    assert int(w[0, 0]) == 0 and int(w[0, 1]) == 2       # bit 1 of word 1
    np.testing.assert_array_equal(np.asarray(unpack_words(w)), np.asarray(x))
    assert int(popcount_block_map(
        pad_to_blocks(w, 128, 4), 128, 128).sum()) == 1


# ------------------------------------------------------- packed kernel paths
def test_spike_matmul_packed_operand_parity():
    from repro.kernels.spike_matmul import spike_matmul, spike_matmul_ref

    x = _spikes(3, (130, 300))
    w = jax.random.normal(jax.random.PRNGKey(4), (300, 100)) * 0.1
    ref = spike_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(spike_matmul(pack_spikes(x), w)),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_fused_pe_packed_in_q_residual_out_bit_identical():
    """Packed x + packed Q + packed residual + packed output: spikes (after
    unpack) and the emitted vld map are bit-identical to the dense oracle
    chain."""
    from repro.kernels.fused_pe import fused_pe, fused_pe_ref

    m, k, n = 130, 257, 100
    x = _spikes(5, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(6), (k, n)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(7), (n,))
    q = _spikes(8, (m, 64), 0.1)
    res = _spikes(9, (m, n), 0.3)
    ref_spk, _, ref_vld = fused_pe_ref(x, w, bias=b, q=q,
                                       residual=res.astype(jnp.float32))
    out = fused_pe(pack_spikes(x), w, bias=b, q=pack_spikes(q),
                   residual=pack_spikes(res), out_format="packed")
    assert isinstance(out.spikes, PackedSpikes)
    np.testing.assert_array_equal(np.asarray(unpack_spikes(out.spikes)),
                                  np.asarray(ref_spk))
    np.testing.assert_array_equal(np.asarray(out.vld_next),
                                  np.asarray(ref_vld))
    np.testing.assert_array_equal(np.asarray(out.spikes.vld_cnt),
                                  np.asarray(ref_vld))


def test_fused_pe_packed_chain_no_dense_tensor():
    """Layer L (pack_out) -> layer L+1 (packed in): the interchange object
    carries payload + metadata, and the chained result equals the dense
    reference chain bit for bit."""
    from repro.kernels.fused_pe import fused_pe, fused_pe_ref

    x = _spikes(10, (256, 256))
    w1 = jax.random.normal(jax.random.PRNGKey(11), (256, 128)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(12), (128, 64)) * 0.1
    l1 = fused_pe(pack_spikes(x), w1, out_format="packed")
    l2 = fused_pe(l1.spikes, w2, out_format="packed")
    r1, _, _ = fused_pe_ref(x, w1)
    r2, _, _ = fused_pe_ref(r1, w2)
    np.testing.assert_array_equal(np.asarray(unpack_spikes(l2.spikes)),
                                  np.asarray(r2))


def test_im2col_and_maxpool_on_packed_words():
    """im2col is channel-preserving, so it commutes with channel packing;
    max-pool of binary maps == bitwise OR of words."""
    from repro.models import nn

    x = _spikes(13, (2, 8, 8, 64), 0.3)
    kh = kw = 3
    # channel-pack each pixel (pad channels to the 128 lane grid)
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, 64)))
    words = pack_words(xp.reshape(-1, 128)).reshape(2, 8, 8, 4)
    pat_w = nn.im2col_packed(words, kh, kw, 1)
    pat_d = nn.im2col(xp, kh, kw, 1)
    np.testing.assert_array_equal(
        np.asarray(unpack_words(pat_w.reshape(-1, pat_w.shape[-1]))),
        np.asarray(pat_d.reshape(-1, pat_d.shape[-1])))
    pooled_w = nn.max_pool_packed(words)
    pooled_d = nn.max_pool(xp.astype(jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(unpack_words(pooled_w.reshape(-1, 4))),
        np.asarray(pooled_d.reshape(-1, 128).astype(jnp.int8)))


def test_conv_weights_as_matmul_packed_exact():
    from repro.models import nn

    x = _spikes(14, (2, 6, 6, 16), 0.3)
    w = jax.random.normal(jax.random.PRNGKey(15), (3, 3, 16, 24)) * 0.1
    ref = nn.conv_apply({"w": w}, x.astype(jnp.float32))
    xp = jnp.pad(x, ((0, 0),) * 3 + ((0, 128 - 16),))
    words = pack_words(xp.reshape(-1, 128)).reshape(2, 6, 6, 4)
    pat = nn.im2col_packed(words, 3, 3, 1)
    w2d = nn.conv_weights_as_matmul_packed(w, 128)
    ps = packed_from_words(pat.reshape(2 * 36, pat.shape[-1]),
                           (2 * 36, pat.shape[-1] * 32))
    from repro.kernels.spike_matmul import spike_matmul
    out = spike_matmul(ps, w2d)
    np.testing.assert_allclose(np.asarray(out).reshape(2, 6, 6, 24),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------- end-to-end model paths
def test_snn_cnn_packed_event_path_bit_identical_to_dense_event_path():
    """The fully-packed deployed path (PackedSpikes between every layer)
    produces the SAME logits and spike counts as the dense event path and
    the no-kernel reference — and accounts ~8x spike HBM compression."""
    from repro.models import snn_cnn

    cfg = snn_cnn.SNNCNNConfig(arch="qkfresnet11", image_size=16,
                               width_mult=0.25, timesteps=1)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    l_ref, _, aux_ref = snn_cnn.forward(fused, img, cfg)
    cfg_pk = dataclasses.replace(cfg, policy="fused_packed")
    l_pk, _, aux_pk = snn_cnn.forward(fused, img, cfg_pk)
    cfg_dn = dataclasses.replace(cfg, policy="fused_dense")
    l_dn, _, aux_dn = snn_cnn.forward(fused, img, cfg_dn)
    np.testing.assert_allclose(np.asarray(l_pk), np.asarray(l_dn),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_pk), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux_pk["total_spikes"]) == float(aux_ref["total_spikes"])
    assert aux_pk["vld_reused"] >= 5
    assert aux_pk["spike_hbm_packed_bytes"] > 0
    ratio = (aux_pk["spike_hbm_dense_bytes"]
             / aux_pk["spike_hbm_packed_bytes"])
    assert ratio > 4.0, ratio


def test_qk_spiking_packed_serving_parity():
    """LM serving path with spike_format='packed': logits match the dense
    reference and the cache carries the packed per-token spike state."""
    from repro.configs import build_model, get_config, reduced

    cfg = reduced(get_config("qwen3-1.7b"), spiking=True,
                  attention_kind="qk_spiking")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    l_ref, _ = model.prefill(params, {"tokens": toks},
                             return_all_logits=True)
    model.cfg = dataclasses.replace(cfg, policy="fused_packed")
    l_pk, cache = model.prefill(params, {"tokens": toks},
                                return_all_logits=True)
    np.testing.assert_allclose(np.asarray(l_pk), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
    words = [l for l in jax.tree_util.tree_leaves(cache["layers"])
             if l.dtype == jnp.int32]
    assert words and words[0].shape[2:4] == (1, 1)   # per-token state rows


def test_engine_packed_spike_stats():
    """Engine with spike_format='packed': identical generations to the
    dense engine, plus measured sparsity / packed-bytes-in-flight stats."""
    from repro.configs import build_model, get_config, reduced
    from repro.serve.engine import Engine, EngineConfig

    cfg = reduced(get_config("qwen3-1.7b"), spiking=True,
                  attention_kind="qk_spiking")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(ecfg):
        eng = Engine(model, params, ecfg)
        for i in range(2):
            eng.submit(np.arange(4 + i) % cfg.vocab_size, max_new=3)
        fin = eng.run_until_drained()
        return {r.uid: r.out for r in fin}, eng.stats()

    out_pk, stats_pk = run(EngineConfig(max_slots=2, max_len=32,
                                        policy="fused_packed"))
    out_dn, stats_dn = run(EngineConfig(max_slots=2, max_len=32))
    assert out_pk == out_dn
    assert stats_pk["spike_format"] == "packed"
    assert stats_pk["decode_ticks_measured"] > 0
    assert 0.0 <= stats_pk["spike_rate_mean"] <= 1.0
    assert stats_pk["packed_spike_bytes_per_tick_mean"] > 0
    assert stats_pk["spike_state_hbm_reduction"] > 1.0
    assert "spike_rate_mean" not in stats_dn


def test_kernel_bench_packed_model_meets_reduction_target():
    """Acceptance: the modeled spike-tensor HBM reduction at the deployed
    layer config is >= 4x (it is ~8x: 1 bit vs 1 byte + tiny maps)."""
    from benchmarks.kernel_bench import packed_spike_bytes

    model = packed_spike_bytes(1024, 1024, 1024, 1024)
    assert model["reduction"] >= 4.0, model
