"""W2TTFS (paper C2, Algorithm 1): the four-way equivalence that justifies
the mechanism — Algorithm-1 reference == NEURAL's optimized WTFC (unit scale
+ time reuse) == the algebraic classifier == plain avgpool+FC on binary
spikes. This equivalence IS the paper's accuracy-preservation claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.w2ttfs import (avgpool_classifier, w2ttfs_classifier,
                               w2ttfs_expand, w2ttfs_reference,
                               w2ttfs_time_reuse, window_counts)


def _spikes(key, b, h, w, c, rate=0.3):
    return (jax.random.uniform(jax.random.PRNGKey(key), (b, h, w, c))
            < rate).astype(jnp.float32)


@pytest.mark.parametrize("window,b,hw,c,cls", [
    (2, 3, 8, 16, 10), (4, 2, 8, 8, 100), (8, 1, 8, 4, 10), (4, 5, 16, 3, 7)])
def test_four_way_equivalence(window, b, hw, c, cls):
    spikes = _spikes(42, b, hw, hw, c)
    ho = hw // window
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    fc_w = jax.random.normal(k1, (ho * ho * c, cls), jnp.float32) * 0.1
    fc_b = jax.random.normal(k2, (cls,), jnp.float32)

    ref = w2ttfs_reference(spikes, fc_w, fc_b, window)      # Algorithm 1
    opt = w2ttfs_classifier(spikes, fc_w, fc_b, window)     # WTFC algebraic
    reuse = w2ttfs_time_reuse(spikes, fc_w, fc_b, window)   # HW time reuse
    ann = avgpool_classifier(spikes, fc_w, fc_b, window)    # replaced op

    np.testing.assert_allclose(np.asarray(ref), np.asarray(opt),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(reuse),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(ann),
                               rtol=1e-4, atol=1e-4)


def test_expand_is_onehot_over_time():
    spikes = _spikes(0, 2, 8, 8, 4)
    exp = w2ttfs_expand(spikes, 4)                  # [T=17, B, 2, 2, 4]
    assert exp.shape[0] == 17
    np.testing.assert_array_equal(
        np.asarray(exp.sum(axis=0)), np.ones((2, 2, 2, 4)))  # exactly one t
    # the firing time equals the window spike count
    cnt = window_counts(spikes, 4)
    t_idx = jnp.argmax(exp, axis=0)
    np.testing.assert_array_equal(np.asarray(t_idx), np.asarray(cnt))


@given(st.integers(0, 10_000), st.sampled_from([2, 4]),
       st.floats(0.0, 1.0))
@settings(max_examples=20)
def test_equivalence_property(seed, window, rate):
    """Property: for ANY binary map and window, WTFC == Algorithm 1."""
    spikes = _spikes(seed, 2, 8, 8, 4, rate)
    ho = 8 // window
    fc_w = jax.random.normal(jax.random.PRNGKey(seed + 1),
                             (ho * ho * 4, 10)) * 0.1
    fc_b = jnp.zeros((10,))
    ref = w2ttfs_reference(spikes, fc_w, fc_b, window)
    opt = w2ttfs_classifier(spikes, fc_w, fc_b, window)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(opt),
                               rtol=1e-4, atol=1e-4)


def test_counts_range():
    spikes = _spikes(1, 2, 16, 16, 8, rate=0.9)
    cnt = window_counts(spikes, 4)
    assert int(cnt.min()) >= 0 and int(cnt.max()) <= 16
