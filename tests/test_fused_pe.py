"""Fused PE dataflow kernel vs the composed unfused reference chain.

The oracle is BY CONSTRUCTION the 4-kernel pipeline the fusion replaces:
spike_matmul_ref -> lif_update_ref -> qk_attention_ref -> block_count_map_2d
(see repro/kernels/fused_pe/ref.py). Parity requirements from the brief:
spikes bit-for-bit (int8), v_next within 1e-5, emitted vld_next equal to
block_count_map_2d of the emitted spikes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.events import block_count_map_2d, pad_to_blocks
from repro.kernels.fused_pe import fused_pe, fused_pe_layer, fused_pe_ref
from repro.kernels.spike_matmul import spike_matmul, spike_matmul_ref


def _structured_spikes(key, m, k, frac_silent, rate=0.2):
    """Spike matrix with a silent top fraction of rows (whole blocks skip)."""
    rows_on = int(m * (1 - frac_silent))
    x = jnp.zeros((m, k), jnp.int8)
    if rows_on:
        x = x.at[:rows_on].set(
            (jax.random.uniform(key, (rows_on, k)) < rate).astype(jnp.int8))
    return x


def _check(out, ref, v_tol=1e-5):
    spk_r, vn_r, vld_r = ref
    np.testing.assert_array_equal(np.asarray(out.spikes), np.asarray(spk_r))
    if vn_r is None:
        assert out.v_next is None
    else:
        np.testing.assert_allclose(np.asarray(out.v_next), np.asarray(vn_r),
                                   rtol=v_tol, atol=v_tol)
    np.testing.assert_array_equal(np.asarray(out.vld_next), np.asarray(vld_r))


# ------------------------------------------------------- sparsity level sweep
@pytest.mark.parametrize("frac_silent", [0.0, 0.5, 0.9])
def test_fused_pe_sparsity_sweep(frac_silent):
    m = k = 256
    n = 128
    x = _structured_spikes(jax.random.PRNGKey(0), m, k, frac_silent)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    out = fused_pe(x, w)
    _check(out, fused_pe_ref(x, w))


def test_fused_pe_all_silent_is_exact_zero():
    x = jnp.zeros((256, 256), jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    out = fused_pe(x, w)
    assert int(jnp.abs(out.spikes).max()) == 0
    assert int(out.vld_next.sum()) == 0


# --------------------------------------------------------- reset mode + state
@pytest.mark.parametrize("soft_reset", [False, True])
def test_fused_pe_stateful_resets(soft_reset):
    m, k, n = 200, 300, 130            # non-block-multiples: padding path
    x = (jax.random.uniform(jax.random.PRNGKey(0), (m, k)) < 0.2
         ).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    v = jax.random.normal(jax.random.PRNGKey(2), (m, n))
    s = (jax.random.uniform(jax.random.PRNGKey(3), (m, n)) < 0.5
         ).astype(jnp.int8)
    b = jax.random.normal(jax.random.PRNGKey(4), (n,))
    out = fused_pe(x, w, bias=b, v_prev=v, s_prev=s, soft_reset=soft_reset,
                   tau=0.7, v_th=0.8)
    _check(out, fused_pe_ref(x, w, bias=b, v_prev=v, s_prev=s,
                             soft_reset=soft_reset, tau=0.7, v_th=0.8))
    if not soft_reset:
        vn = np.asarray(out.v_next)
        # hard reset: fired neurons sit at exactly 0 (pre-mask spikes)
        cur = np.asarray(spike_matmul_ref(x, w)) + np.asarray(b)[None, :]
        vpre = 0.7 * np.asarray(v) * (1 - np.asarray(s)) + cur
        assert np.all(vn[vpre >= 0.8] == 0.0)


# ------------------------------------------------------------- QK write-back
@pytest.mark.parametrize("with_qk", [False, True])
def test_fused_pe_qk_writeback(with_qk):
    m, k, n = 256, 256, 128
    x = (jax.random.uniform(jax.random.PRNGKey(0), (m, k)) < 0.15
         ).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    q = (jax.random.uniform(jax.random.PRNGKey(2), (m, 96)) < 0.02
         ).astype(jnp.int8) if with_qk else None
    out = fused_pe(x, w, q=q)
    _check(out, fused_pe_ref(x, w, q=q))
    if with_qk:
        # silent-Q tokens must emit NO spikes (atten_reg gating)
        dead = np.asarray(q).sum(axis=1) < 1
        assert np.asarray(out.spikes)[dead].sum() == 0


def test_fused_pe_full_combination():
    m, k, n = 130, 257, 100
    x = (jax.random.uniform(jax.random.PRNGKey(0), (m, k)) < 0.2
         ).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (n,))
    res = jax.random.normal(jax.random.PRNGKey(3), (m, n))
    v = jax.random.normal(jax.random.PRNGKey(4), (m, n))
    s = (jax.random.uniform(jax.random.PRNGKey(5), (m, n)) < 0.5
         ).astype(jnp.int8)
    q = (jax.random.uniform(jax.random.PRNGKey(6), (m, 64)) < 0.1
         ).astype(jnp.int8)
    out = fused_pe(x, w, bias=b, residual=res, v_prev=v, s_prev=s, q=q)
    _check(out, fused_pe_ref(x, w, bias=b, residual=res, v_prev=v,
                             s_prev=s, q=q))


# -------------------------------------------- emitted metadata (PipeSDA C3)
def test_emitted_vld_matches_block_count_of_emitted_spikes():
    """The on-the-fly vld_next IS block_count_map_2d of the emitted spikes."""
    m, k, n = 300, 256, 200
    x = _structured_spikes(jax.random.PRNGKey(7), m, k, 0.5)
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n)) * 0.1
    q = (jax.random.uniform(jax.random.PRNGKey(9), (m, 32)) < 0.05
         ).astype(jnp.int8)
    out = fused_pe(x, w, q=q)
    expect = block_count_map_2d(pad_to_blocks(out.spikes, 128, 128), 128, 128)
    np.testing.assert_array_equal(np.asarray(out.vld_next),
                                  np.asarray(expect))


def test_emitted_vld_chains_into_spike_matmul():
    """Layer L's vld_next drives layer L+1's event skip: result unchanged."""
    m, k, n, n2 = 256, 256, 256, 64
    x = _structured_spikes(jax.random.PRNGKey(0), m, k, 0.5)
    w1 = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(2), (n, n2)) * 0.1
    out = fused_pe(x, w1)
    chained = spike_matmul(out.spikes, w2, vld_cnt=out.vld_next)
    np.testing.assert_allclose(np.asarray(chained),
                               np.asarray(spike_matmul_ref(out.spikes, w2)),
                               rtol=1e-5, atol=1e-5)
    fused_chained = fused_pe(out.spikes, w2, vld_cnt=out.vld_next)
    _check(fused_chained, fused_pe_ref(out.spikes, w2))


# --------------------------------------------------------------- T>1 layers
def test_fused_pe_layer_multistep_matches_lif_multistep():
    from repro.core.lif import LIFConfig, lif_multistep

    t, m, k, n = 3, 96, 128, 64
    xt = (jax.random.uniform(jax.random.PRNGKey(0), (t, m, k)) < 0.2
          ).astype(jnp.int8)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(2), (n,))
    spikes, vld = fused_pe_layer(xt, w, bias=b)
    cur = jnp.einsum("tmk,kn->tmn", xt.astype(jnp.float32), w) + b
    ref = lif_multistep(cur, LIFConfig()).astype(jnp.int8)
    np.testing.assert_array_equal(np.asarray(spikes), np.asarray(ref))
    assert vld.shape == (t, 1, 1)


# ------------------------------------------------- satellite: lif padding fix
def test_lif_update_pallas_non_multiple_block():
    """Regression: lif_update_pallas used to assert m % block == 0."""
    from repro.kernels.lif_update import lif_update_ref
    from repro.kernels.lif_update.lif_update import lif_update_pallas

    m, d = 100, 64                     # not a multiple of any default block
    cur = jax.random.normal(jax.random.PRNGKey(0), (m, d))
    v = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    s = (jax.random.uniform(jax.random.PRNGKey(2), (m, d)) < 0.5
         ).astype(jnp.float32)
    spk, vn = lif_update_pallas(cur, v, s, block=64, interpret=True)
    spk_r, vn_r = lif_update_ref(cur, v, s)
    np.testing.assert_array_equal(np.asarray(spk), np.asarray(spk_r))
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vn_r), rtol=1e-6)


# --------------------------------------------- model wiring (deployed paths)
def test_snn_cnn_forward_event_path_parity():
    """QKFResNet-11 deployed inference: fused-PE event path == dense path,
    and the on-the-fly metadata is chained through the QKFormer block."""
    from repro.models import snn_cnn

    cfg = snn_cnn.SNNCNNConfig(arch="qkfresnet11", image_size=16,
                               width_mult=0.25, timesteps=1)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    l_ref, _, aux_ref = snn_cnn.forward(fused, img, cfg)
    cfg_ev = dataclasses.replace(cfg, policy="fused_packed")
    l_ev, _, aux_ev = snn_cnn.forward(fused, img, cfg_ev)
    np.testing.assert_allclose(np.asarray(l_ev), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux_ev["total_spikes"]) == float(aux_ref["total_spikes"])
    assert aux_ev["vld_reused"] >= 5   # q,k from resblock; proj/mlp1/mlp2


def test_qk_spiking_attention_event_path_parity():
    """LM serving path: fused projections + event wo matmul == jnp path."""
    from repro.configs import build_model, get_config, reduced

    cfg = reduced(get_config("qwen3-1.7b"), spiking=True,
                  attention_kind="qk_spiking")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    l_ref, _ = model.prefill(params, {"tokens": toks},
                             return_all_logits=True)
    model.cfg = dataclasses.replace(cfg, policy="fused_dense")
    l_ev, _ = model.prefill(params, {"tokens": toks}, return_all_logits=True)
    np.testing.assert_allclose(np.asarray(l_ev), np.asarray(l_ref),
                               rtol=2e-4, atol=2e-4)
