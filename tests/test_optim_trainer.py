"""Optimizers, schedules, compression, microbatching equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config, reduced
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, constant_lr, cosine_lr,
                         decompress_int8, error_feedback_init, global_norm,
                         linear_warmup_cosine, sgd_init, sgd_update)
from repro.train import TrainState, make_train_step, train_state_init


def test_adamw_first_step_is_signed_lr():
    """With bias correction, step 1 moves params by ~lr * sign(grad)."""
    params = {"w": jnp.array([1.0, -1.0])}
    grads = {"w": jnp.array([0.5, -0.25])}
    state = adamw_init(params)
    new_p, _ = adamw_update(grads, state, params, lr=0.1, eps=1e-12)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [1.0 - 0.1, -1.0 + 0.1], rtol=1e-4)


def test_adamw_weight_decay_decoupled():
    params = {"w": jnp.array([2.0])}
    grads = {"w": jnp.array([0.0])}
    new_p, _ = adamw_update(grads, adamw_init(params), params, lr=0.1,
                            weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(new_p["w"]), [2.0 - 0.1 * 0.5 * 2.0])


def test_sgd_momentum_accumulates():
    params = {"w": jnp.zeros(1)}
    grads = {"w": jnp.ones(1)}
    st = sgd_init(params)
    p1, st = sgd_update(grads, st, params, lr=1.0, momentum=0.9)
    p2, st = sgd_update(grads, st, p1, lr=1.0, momentum=0.9)
    # steps: 1, then 1 + 0.9
    np.testing.assert_allclose(np.asarray(p2["w"]), [-(1.0 + 1.9)], rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) < 0.2
    np.testing.assert_allclose(float(s(jnp.int32(10))), 1.0, rtol=1e-2)
    assert float(s(jnp.int32(99))) < 0.2
    c = cosine_lr(1.0, 100, final_frac=0.1)
    np.testing.assert_allclose(float(c(jnp.int32(100))), 0.1, rtol=1e-5)
    assert float(constant_lr(0.3)(jnp.int32(7))) == pytest.approx(0.3)


# --------------------------------------------------------------- compression
def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q, scale = compress_int8(x)
    err = jnp.abs(decompress_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


def test_error_feedback_accumulates_truth():
    """EF property: sum of dequantized transmissions converges to the sum of
    true gradients (bias correction over steps)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(32)
    sent_sum = np.zeros(32)
    err = jnp.zeros(32)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=32) * 0.01, jnp.float32)
        true_sum += np.asarray(g)
        q, scale = compress_int8(g + err)
        deq = decompress_int8(q, scale)
        err = (g + err) - deq
        sent_sum += np.asarray(deq)
    # residual bounded by one quantization step, not growing with steps
    np.testing.assert_allclose(sent_sum, true_sum, atol=2e-3)


# ------------------------------------------------------------- microbatching
def test_microbatch_grads_equal_full_batch():
    """KEY equivalence: n_micro gradient accumulation == full-batch step."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size)}
    sched = constant_lr(1e-2)
    full = make_train_step(model, schedule=sched, microbatch=0)
    micro = make_train_step(model, schedule=sched, microbatch=4)
    s0 = train_state_init(params)
    s_full, m_full = full(s0, batch)
    s1 = train_state_init(params)
    s_micro, m_micro = micro(s1, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s_full.params),
                    jax.tree_util.tree_leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_train_step_reduces_loss():
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, schedule=constant_lr(5e-3)))
    state = train_state_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(8):              # same batch -> loss must fall
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
