"""neurallint: both engines, the CLI gate, and the regression that
motivated it (PR 8's silent dense downgrade)."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (RULES, junit_xml, lint_source, render,
                            verify_contracts)
from repro.analysis.lint import lint_paths

REPO = Path(__file__).resolve().parent.parent
NEURALLINT = [sys.executable, str(REPO / "tools" / "neurallint.py")]

#: a non-exempt project path for fixture snippets (rule exemptions are
#: path-based; models/ carries none)
SRC = "src/repro/models/fixture.py"


def _rules(findings):
    return {f.rule for f in findings}


# ------------------------------------------------------ engine 2: fixtures
# (one good + one bad per AST rule; the bad snippet must trip EXACTLY its
# rule so fixtures double as precision tests)
FIXTURES = {
    "NL-REGISTRY-BYPASS": (
        "from repro import ops\ny = ops.matmul\n",
        "from repro.kernels.spike_matmul import spike_matmul\n"),
    "NL-HOST-SYNC": (
        "import jax\n\n@jax.jit\ndef f(x):\n    return x.sum()\n",
        "import jax\n\n@jax.jit\ndef f(x):\n    return float(x.sum())\n"),
    "NL-BARE-HEAVISIDE": (
        "from repro.core.surrogate import spike\n\n\ndef f(v, t):\n"
        "    return spike(v - t)\n",
        "def f(v, t):\n    return (v > t).astype('float32')\n"),
    "NL-INTERPRET-HARDCODE": (
        "def run(x, interpret=None):\n    return go(x, interpret=interpret)\n",
        "def run(x, interpret=True):\n    return go(x, interpret=True)\n"),
    "NL-MUTABLE-DEFAULT": (
        "def f(x, acc=None):\n    return acc\n",
        "def f(x, acc=[]):\n    return acc\n"),
    "NL-LEGACY-FLAGS": (
        "y = ops.matmul(x, w, policy='fused_dense')\n",
        "y = ops.matmul(x, w, use_event_kernels=True)\n"),
    "NL-LEGACY-FORKS": (
        "y = snn_cnn.forward(params, x)\n",
        "y = snn_cnn.apply_fused(params, x)\n"),
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_fixture_pair(rule):
    good, bad = FIXTURES[rule]
    assert rule not in _rules(lint_source(good, SRC)), f"{rule} good fixture"
    hits = [f for f in lint_source(bad, SRC) if f.rule == rule]
    assert hits, f"{rule} bad fixture did not trip"
    assert hits[0].path == SRC and hits[0].line > 0


def test_bad_fixtures_are_precise():
    for rule, (_, bad) in FIXTURES.items():
        assert _rules(lint_source(bad, SRC)) == {rule}, rule


def test_lt_cast_is_not_a_heaviside():
    # `< rate` casts are random spike-mask generation, not Heavisides
    src = "def f(u, rate):\n    return (u < rate).astype('int8')\n"
    assert not lint_source(src, SRC)


def test_host_sync_only_inside_traced_code():
    src = "def f(x):\n    return float(x.sum())\n"   # eager: fine
    assert not lint_source(src, SRC)


def test_suppression_same_line_and_line_above():
    _, bad = FIXTURES["NL-MUTABLE-DEFAULT"]
    line = bad.splitlines()[0]
    same = f"{line}  # neurallint: disable=NL-MUTABLE-DEFAULT\n    return acc\n"
    above = ("# justified  # neurallint: disable=NL-MUTABLE-DEFAULT\n"
             f"{bad}")
    assert not lint_source(same, SRC)
    assert not lint_source(above, SRC)
    # suppressing a DIFFERENT rule must not silence this one
    other = f"{line}  # neurallint: disable=NL-HOST-SYNC\n    return acc\n"
    assert _rules(lint_source(other, SRC)) == {"NL-MUTABLE-DEFAULT"}


def test_repo_is_clean_and_rule_catalog_is_big_enough():
    findings, checked = lint_paths(root=REPO)
    assert not findings, render(findings)
    assert checked > 50
    assert len(RULES) >= 10


# ------------------------------------------- engine 1: the contract sweep
@pytest.fixture(scope="module")
def report():
    return verify_contracts()


def test_sweep_totality_and_zero_violations(report):
    # 100% of the registered (op, mode) pairs must be reachable by the
    # static sweep — an implementation nobody can drive is a coverage gap
    assert report.coverage == report.registered, sorted(report.uncovered)
    assert len(report.registered) >= 24
    assert not report.findings, render(report.findings)


def test_sweep_is_abstract_fast(report):
    # eval_shape only: the whole registry in well under the CI budget
    assert report.duration_s < 60.0
    assert report.cells > 100


def test_silent_downgrade_regression(monkeypatch):
    # re-introduce PR 8's bug class: the fused_pe dispatch resolving the
    # reference implementation while the policy asked for fused kernels
    from repro.ops import dispatch, registry

    real = registry.lookup

    def downgrading(op, mode):
        if op == "fused_pe" and mode.startswith("fused"):
            mode = mode.replace("fused", "reference")
        return real(op, mode)

    monkeypatch.setattr(dispatch, "lookup", downgrading)
    report = verify_contracts(only_ops={"fused_pe"})
    assert "NL-SILENT-DOWNGRADE" in _rules(report.findings), \
        render(report.findings)


def test_sweep_leaves_no_sticky_demotions():
    from repro.ops import fallback

    before = len(fallback.demotions())
    verify_contracts(only_ops={"matmul"})
    assert len(fallback.demotions()) == before


# ------------------------------------------------------------ CLI + junit
def test_junit_report_shape():
    xml = junit_xml([], checked=7)
    assert 'tests="%d"' % len(RULES) in xml and 'failures="0"' in xml
    from repro.analysis import Finding
    f = Finding("NL-HOST-SYNC", "a.py", 3, "sync")
    xml = junit_xml([f], checked=7)
    assert 'failures="1"' in xml and "a.py:3" in xml


def test_finding_requires_catalogued_rule():
    from repro.analysis import Finding
    with pytest.raises(AssertionError):
        Finding("NL-NOT-A-RULE", "a.py", 1, "x")


def test_cli_red_on_seeded_bad_fixture(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(FIXTURES["NL-INTERPRET-HARDCODE"][1])
    r = subprocess.run(NEURALLINT + ["--lint-only", "--paths", str(bad)],
                       capture_output=True, text=True)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "NL-INTERPRET-HARDCODE" in r.stdout

    good = tmp_path / "good.py"
    good.write_text(FIXTURES["NL-INTERPRET-HARDCODE"][0])
    r = subprocess.run(NEURALLINT + ["--lint-only", "--paths", str(good)],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_junit_artifact(tmp_path):
    out = tmp_path / "lint.xml"
    r = subprocess.run(
        NEURALLINT + ["--lint-only", "--paths",
                      str(REPO / "tools" / "neurallint.py"),
                      "--junit", str(out)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert out.exists() and "<testsuite" in out.read_text()


def test_legacy_flags_shim_still_works():
    r = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_no_legacy_flags.py")],
        capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
