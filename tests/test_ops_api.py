"""The ``repro.ops`` API: SpikeTensor pytree behavior, policy dispatch,
format preservation, deprecation shims (old kwargs == new policy, with
warnings), the DEFAULT_BLOCKS drift fix, and the no-legacy-flags guard.
"""
import dataclasses
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.events import DEFAULT_BLOCKS, PackedSpikes, block_occupancy
from repro.ops import ExecutionPolicy, SpikeTensor


def _spikes(seed, shape, rate=0.2):
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < rate
            ).astype(jnp.int8)


def _w(k, n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.1


# ================================================================ SpikeTensor
def test_spike_tensor_pytree_flatten_stability():
    """tree_flatten aux data is stable and value-independent: two tensors
    of the same format/shape produce identical treedefs (the jit cache
    contract), and flatten->unflatten is the identity."""
    x = _spikes(0, (130, 70))
    st = SpikeTensor.dense(x)
    st2 = SpikeTensor.dense(_spikes(1, (130, 70)))
    t1 = jax.tree_util.tree_structure(st)
    t2 = jax.tree_util.tree_structure(st2)
    assert t1 == t2
    leaves, treedef = jax.tree_util.tree_flatten(st)
    rt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rt.fmt == st.fmt and rt.shape == st.shape
    np.testing.assert_array_equal(np.asarray(rt.data), np.asarray(st.data))

    ps = ops.pack(x)
    ps2 = ops.pack(_spikes(1, (130, 70)))
    assert (jax.tree_util.tree_structure(ps)
            == jax.tree_util.tree_structure(ps2))
    assert jax.tree_util.tree_structure(ps) != t1   # formats differ


@pytest.mark.parametrize("fmt", ["dense", "packed"])
def test_spike_tensor_jit_roundtrip(fmt):
    x = _spikes(2, (128, 128))
    st = ops.pack(x) if fmt == "packed" else SpikeTensor.dense(x)

    @jax.jit
    def f(s):
        return s

    out = f(st)
    assert isinstance(out, SpikeTensor)
    assert out.fmt == fmt and out.shape == st.shape
    np.testing.assert_array_equal(np.asarray(ops.unpack(out)),
                                  np.asarray(x))


@pytest.mark.parametrize("fmt", ["dense", "packed"])
def test_spike_tensor_vmap_and_scan(fmt):
    x = _spikes(3, (4, 128, 128))
    st = ops.pack(x) if fmt == "packed" else SpikeTensor.dense(x)

    counted = jax.vmap(lambda s: s.count())(st)
    np.testing.assert_allclose(
        np.asarray(counted),
        np.asarray(x.astype(jnp.float32).sum(axis=(1, 2))))

    def step(carry, s):
        return carry + s.count(), s

    total, out = jax.lax.scan(step, jnp.float32(0), st)
    assert isinstance(out, SpikeTensor) and out.fmt == fmt
    np.testing.assert_allclose(float(total), float(x.sum()))
    np.testing.assert_array_equal(np.asarray(ops.unpack(out)),
                                  np.asarray(x))


def test_spike_tensor_wrap_coercions():
    x = _spikes(4, (64, 64))
    st = SpikeTensor.wrap(x)
    assert st.fmt == "dense" and st.shape == (64, 64)
    from repro.core.events import pack_spikes_ref

    ps = pack_spikes_ref(x)
    st_p = SpikeTensor.wrap(ps)
    assert st_p.is_packed and st_p.shape == (64, 64)
    assert isinstance(st_p.to_packed_spikes(), PackedSpikes)
    assert SpikeTensor.wrap(st_p) is st_p
    np.testing.assert_array_equal(np.asarray(st_p.to_dense()), np.asarray(x))


def test_spike_tensor_bytes_and_count():
    x = _spikes(5, (1024, 1024), 0.2)
    st_d = SpikeTensor.dense(x)
    st_p = ops.pack(x)
    assert st_p.hbm_bytes < st_d.hbm_bytes / 7
    assert st_p.dense_bytes == 1024 * 1024
    np.testing.assert_allclose(float(st_p.count()), float(x.sum()))
    np.testing.assert_allclose(float(st_d.count()), float(x.sum()))


# ============================================================ format dispatch
@pytest.mark.parametrize("fmt", ["dense", "packed"])
def test_format_preserved_through_ops_chain(fmt):
    """ops.* are format-preserving: a chain of fused_pe calls keeps the
    input's variant end to end, and both variants agree bit-for-bit with
    the reference policy."""
    x = _spikes(6, (130, 257))
    w1, w2 = _w(257, 128, 7), _w(128, 64, 8)
    policy = f"fused_{fmt}"
    st = ops.pack(x) if fmt == "packed" else SpikeTensor.dense(x)

    l1 = ops.fused_pe(st, w1, policy=policy).spikes
    assert l1.fmt == fmt and l1.vld_cnt is not None
    l2 = ops.fused_pe(l1, w2, policy=policy).spikes
    assert l2.fmt == fmt

    r1 = ops.fused_pe(x, w1, policy="reference").spikes
    r2 = ops.fused_pe(r1, w2, policy="reference").spikes
    np.testing.assert_array_equal(np.asarray(ops.unpack(l2)),
                                  np.asarray(r2.data))


def test_policy_none_infers_from_operand():
    x = _spikes(9, (128, 128))
    out_d = ops.fused_pe(x, _w(128, 64)).spikes
    assert out_d.fmt == "dense"
    out_p = ops.fused_pe(ops.pack(x), _w(128, 64)).spikes
    assert out_p.fmt == "packed"
    np.testing.assert_array_equal(np.asarray(out_p.to_dense()),
                                  np.asarray(out_d.data))


def test_ops_entry_points_match_kernel_parity():
    """The golden-sweep kernels reached through ops.* produce bit-identical
    results to direct kernel calls for both variants."""
    from repro.kernels.fused_pe import fused_pe as k_fused_pe
    from repro.kernels.spike_matmul import spike_matmul as k_spike_matmul

    x = _spikes(10, (130, 257))
    w = _w(257, 33, 11)
    bias = jax.random.normal(jax.random.PRNGKey(12), (33,)) * 0.5
    q = _spikes(13, (130, 16))

    from repro.core.lif import LIFConfig

    direct = k_fused_pe(x, w, bias=bias, q=q, v_th=0.3)
    via = ops.fused_pe(x, w, bias=bias, q=q, lif_cfg=LIFConfig(v_th=0.3),
                       policy="fused_dense")
    np.testing.assert_array_equal(np.asarray(via.spikes.data),
                                  np.asarray(direct.spikes))
    np.testing.assert_array_equal(np.asarray(via.vld_next),
                                  np.asarray(direct.vld_next))

    np.testing.assert_allclose(
        np.asarray(ops.matmul(ops.pack(x), w, policy="fused_packed")),
        np.asarray(k_spike_matmul(x, w)), rtol=1e-5, atol=1e-5)


def test_lif_qk_mask_attention_pool_dispatch():
    from repro.core.lif import LIFConfig
    from repro.kernels.lif_update import lif_update_ref
    from repro.kernels.qk_attention import qk_attention_ref

    cur = jax.random.normal(jax.random.PRNGKey(14), (3, 130)) * 2
    v = jax.random.normal(jax.random.PRNGKey(15), (3, 130))
    s = _spikes(16, (3, 130)).astype(jnp.float32)
    for pol in ("fused_dense", "reference"):
        spk, vn = ops.lif(cur, v, s, lif_cfg=LIFConfig(), policy=pol)
        spk_r, vn_r = lif_update_ref(cur, v, s)
        np.testing.assert_array_equal(np.asarray(spk), np.asarray(spk_r))
        np.testing.assert_allclose(np.asarray(vn), np.asarray(vn_r),
                                   rtol=1e-6, atol=1e-6)

    q = _spikes(17, (2, 100, 17))
    k = _spikes(18, (2, 100, 17), 0.4)
    for pol in ("fused_dense", "reference"):
        out = ops.qk_mask(q, k, policy=pol)
        np.testing.assert_array_equal(np.asarray(out.data),
                                      np.asarray(qk_attention_ref(q, k)))

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(19), 3)
    qa = jax.random.normal(kq, (1, 64, 2, 64), jnp.float32)
    ka = jax.random.normal(kk, (1, 64, 2, 64), jnp.float32)
    va = jax.random.normal(kv, (1, 64, 2, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.attention(qa, ka, va, q_block=64, kv_block=64,
                                 policy="fused_dense")),
        np.asarray(ops.attention(qa, ka, va, q_block=64, kv_block=64,
                                 policy="reference")),
        rtol=2e-4, atol=2e-4)

    # pool: packed OR == dense max for binary maps, in token layout
    spa = (2, 8, 8, 128)
    xm = _spikes(20, (1, 2 * 8 * 8, 128), 0.3)
    st_d, (h2, w2) = ops.pool(SpikeTensor.dense(xm), spa, t=1,
                              policy="fused_dense")
    st_p, _ = ops.pool(ops.pack(xm), spa, t=1, policy="fused_packed")
    assert (h2, w2) == (4, 4) and st_p.is_packed
    np.testing.assert_array_equal(np.asarray(ops.unpack(st_p)),
                                  np.asarray(st_d.data))


def test_registry_introspection_and_unknown_op():
    impls = ops.implementations()
    families = {op for op, _ in impls}
    assert {"matmul", "lif", "fused_pe", "fused_pe_layer", "pool",
            "im2col", "qk_mask", "pack", "unpack", "attention",
            "dense_lif", "w2ttfs_head"} <= families
    for op in families:
        assert (op, "reference") in impls and (op, "fused") in impls
    with pytest.raises(NotImplementedError):
        ops.lookup("no_such_op", "fused")


# ========================================================== policy + shims
def test_policy_presets_and_parse():
    assert ops.as_policy("fused_packed").packed
    assert ops.as_policy("fused_dense").fused
    assert not ops.as_policy("reference").fused
    assert ops.as_policy(None) == ops.REFERENCE
    assert ops.as_policy(ops.FUSED_PACKED) is ops.FUSED_PACKED
    with pytest.raises(ValueError):
        ops.as_policy("warp_speed")
    assert ExecutionPolicy("reference", "packed").name == "reference_packed"


def _legacy_kwargs(**kw):
    """Build legacy-flag kwargs without tripping the repo's no-legacy-flag
    grep guard (tests are exempt, but the test file shouldn't be the one
    place that keeps the spelling alive as copyable code)."""
    names = {"ev": "use_event_kernels", "fmt": "spike_format"}
    return {names[k]: v for k, v in kw.items()}


def test_legacy_model_config_flags_equal_policy():
    from repro.configs.base import ModelConfig

    from repro.ops.compat import reset_warning_dedup

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64)
    assert cfg.exec_policy == ops.REFERENCE
    reset_warning_dedup()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = dataclasses.replace(cfg, **_legacy_kwargs(ev=True,
                                                           fmt="packed"))
        assert any(issubclass(r.category, DeprecationWarning) for r in rec)
    assert legacy.exec_policy == ops.FUSED_PACKED
    assert ops.with_policy(cfg, ops.FUSED_PACKED).exec_policy \
        == legacy.exec_policy
    # mixing policy= with legacy flags is an error, not a silent override
    with pytest.raises(ValueError):
        dataclasses.replace(legacy, policy="fused_dense")


def test_legacy_snn_config_default_format_is_packed():
    from repro.models.snn_cnn import SNNCNNConfig

    from repro.ops.compat import reset_warning_dedup

    cfg = SNNCNNConfig()
    assert cfg.exec_policy == ops.REFERENCE
    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        legacy = dataclasses.replace(cfg, **_legacy_kwargs(ev=True))
    assert legacy.exec_policy == ops.FUSED_PACKED      # historical default
    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        legacy_d = dataclasses.replace(cfg, **_legacy_kwargs(ev=True,
                                                             fmt="dense"))
    assert legacy_d.exec_policy == ops.FUSED_DENSE


def test_legacy_engine_flags_equal_policy():
    from repro.serve.engine import EngineConfig

    from repro.ops.compat import reset_warning_dedup

    e_new = EngineConfig(policy="fused_packed")
    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        e_old = EngineConfig(**_legacy_kwargs(ev=True, fmt="packed"))
    base = ops.REFERENCE
    assert ops.merge_engine_policy(base, e_new.policy, None,
                                   None) == ops.FUSED_PACKED
    merged_old = ops.merge_engine_policy(base, e_old.policy,
                                         e_old.use_event_kernels,
                                         e_old.spike_format)
    assert merged_old == ops.FUSED_PACKED
    # per-axis override: format-only legacy flag keeps the model's kernels
    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        fmt_only = EngineConfig(**_legacy_kwargs(fmt="packed"))
    assert ops.merge_engine_policy(ops.FUSED_DENSE, fmt_only.policy,
                                   fmt_only.use_event_kernels,
                                   fmt_only.spike_format) == ops.FUSED_PACKED


def test_legacy_apply_fused_kwargs_equal_policy_results():
    """Old-kwarg model calls produce bit-identical outputs to new-policy
    calls (the satellite acceptance for the shims)."""
    from repro.models import snn_cnn

    cfg = snn_cnn.SNNCNNConfig(arch="resnet11", image_size=8,
                               width_mult=0.25, timesteps=1)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    from repro.ops.compat import reset_warning_dedup

    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        legacy_cfg = dataclasses.replace(cfg, **_legacy_kwargs(ev=True,
                                                               fmt="packed"))
    l_old, _, _ = snn_cnn.forward(fused, img, legacy_cfg)
    l_new, _, _ = snn_cnn.forward(fused, img, cfg, policy="fused_packed")
    np.testing.assert_array_equal(np.asarray(l_old), np.asarray(l_new))


def test_legacy_fused_pe_pack_kwarg_warns_and_matches():
    from repro.kernels.fused_pe import fused_pe
    from repro.ops.compat import reset_warning_dedup

    x = _spikes(21, (64, 64))
    w = _w(64, 32, 22)
    reset_warning_dedup()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = fused_pe(x, w, **{"pack_out": True})
        assert any(issubclass(r.category, DeprecationWarning) for r in rec)
    new = fused_pe(x, w, out_format="packed")
    np.testing.assert_array_equal(np.asarray(old.spikes.words),
                                  np.asarray(new.spikes.words))


# ===================================================== DEFAULT_BLOCKS drift
def test_default_blocks_single_source():
    assert ops.DEFAULT_BLOCKS is DEFAULT_BLOCKS
    assert (DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.n, DEFAULT_BLOCKS.k) \
        == (128, 128, 128)
    # the statistics helpers now measure on the kernels' own tile grid:
    # defaults == explicit DEFAULT_BLOCKS arguments
    x = _spikes(23, (300, 300), 0.01)
    np.testing.assert_allclose(
        float(block_occupancy(x)),
        float(block_occupancy(x, DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.k)))
    from repro.core.events import event_stats

    st = event_stats(x)
    np.testing.assert_allclose(
        float(st["block_occupancy"]),
        float(block_occupancy(x, DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.k)))


# ======================================================== repo-wide guards
def test_no_legacy_flag_call_sites_outside_shim():
    """The legacy guard (now a shim over neurallint's NL-LEGACY-* rules)
    passes on the current tree."""
    script = Path(__file__).resolve().parent.parent / "tools" / \
        "check_no_legacy_flags.py"
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
