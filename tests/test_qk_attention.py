"""Spiking QKFormer attention (paper C4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qk_attention import (qk_channel_attention, qk_token_mask,
                                     qk_token_attention,
                                     spiking_self_attention)


def _spk(key, shape, rate=0.2):
    return (jax.random.uniform(jax.random.PRNGKey(key), shape)
            < rate).astype(jnp.float32)


def test_or_mode_equals_any_spike():
    q = _spk(0, (2, 16, 32))
    m = qk_token_mask(q, mode="or")
    np.testing.assert_array_equal(
        np.asarray(m[..., 0]), np.asarray((q.sum(-1) > 0)).astype(np.float32))


def test_token_mask_is_rowwise_local():
    """Row i's mask depends only on row i of Q — the property that allows
    NEURAL's on-the-fly write-back fusion (Fig 5) and O(1) decode."""
    q = _spk(1, (8, 16))
    m1 = qk_token_mask(q, mode="or")
    q2 = q.at[3].set(1.0 - q[3])        # perturb one row
    m2 = qk_token_mask(q2, mode="or")
    changed = np.nonzero(np.asarray(m1 != m2).any(-1))[0]
    assert set(changed) <= {3}


def test_threshold_mode_binary_and_monotone():
    q = _spk(2, (4, 64, 32), rate=0.5)
    m1 = qk_token_mask(q, mode="threshold", threshold=1.0)
    m8 = qk_token_mask(q, mode="threshold", threshold=8.0)
    assert set(np.unique(np.asarray(m1))) <= {0.0, 1.0}
    assert float(m8.sum()) <= float(m1.sum())   # higher bar, fewer tokens


def test_masked_output_zeroes_inactive_tokens():
    q = _spk(3, (16, 32))
    k = _spk(4, (16, 32), rate=0.5)
    out = qk_token_attention(q, k, mode="or")
    inactive = np.asarray(q.sum(-1) == 0)
    assert np.all(np.asarray(out)[inactive] == 0)
    active = ~inactive
    np.testing.assert_array_equal(np.asarray(out)[active],
                                  np.asarray(k)[active])


@given(st.integers(0, 1000), st.sampled_from([17, 64, 130]))
@settings(max_examples=10)
def test_causal_ssa_matches_naive(seed, n):
    """Chunked causal Q(K^T V) == naive masked (QK^T)V on binary spikes."""
    q = _spk(seed, (2, n, 16))
    k = _spk(seed + 1, (2, n, 16))
    v = _spk(seed + 2, (2, n, 16))
    fast = spiking_self_attention(q, k, v, scale=1.0, causal=True)
    scores = jnp.einsum("bnd,bmd->bnm", q, k)
    mask = jnp.tril(jnp.ones((n, n)))
    naive = jnp.einsum("bnm,bme->bne", scores * mask, v)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_noncausal_ssa_linear_identity():
    """Q(K^T V) == (QK^T)V — the linear-attention identity binary spikes buy."""
    q = _spk(5, (2, 32, 16))
    k = _spk(6, (2, 32, 16))
    v = _spk(7, (2, 32, 16))
    fast = spiking_self_attention(q, k, v, scale=0.5, causal=False)
    naive = jnp.einsum("bnm,bme->bne", jnp.einsum("bnd,bmd->bnm", q, k),
                       v) * 0.5
    np.testing.assert_allclose(np.asarray(fast), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_channel_attention_shapes():
    q = _spk(8, (2, 4, 16, 32))
    k = _spk(9, (2, 4, 16, 32))
    out = qk_channel_attention(q, k, mode="or")
    assert out.shape == k.shape
