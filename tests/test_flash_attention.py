"""Pallas flash attention vs naive softmax oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


def _ref_bshd(q, k, v, causal=True):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    ke = jnp.repeat(k, h // hkv, 2)
    ve = jnp.repeat(v, h // hkv, 2)
    out = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        ke.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        ve.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        causal=causal, scale=d ** -0.5)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,h,hkv,d,qb", [(256, 4, 4, 64, 128),
                                          (300, 4, 2, 32, 128),
                                          (128, 2, 1, 16, 64),
                                          (512, 2, 2, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_oracle(s, h, hkv, d, qb, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = jax.random.normal(ks[0], (2, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (2, s, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (2, s, hkv, d)).astype(dtype)
    out = flash_attention(q, k, v, q_block=qb, kv_block=qb)
    ref = _ref_bshd(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@given(st.integers(0, 200), st.sampled_from([64, 128]))
@settings(max_examples=6)
def test_flash_property(seed, qb):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = flash_attention(q, k, v, q_block=qb, kv_block=qb)
    ref = _ref_bshd(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_causality():
    """Future tokens must not influence earlier outputs."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out1 = flash_attention(q, k, v, q_block=64, kv_block=64)
    k2 = k.at[:, 100:].set(99.0)          # perturb the tail
    v2 = v.at[:, 100:].set(-99.0)
    out2 = flash_attention(q, k2, v2, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(out1[:, :100]),
                               np.asarray(out2[:, :100]), rtol=1e-5)
    assert float(jnp.abs(out1[:, 100:] - out2[:, 100:]).max()) > 1.0
