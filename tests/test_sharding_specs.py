"""Sharding rule unit tests (no multi-device needed) + the analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import build_model, get_config, reduced
from repro.launch.hlo_analysis import (analyze, parse_hlo, shape_dims,
                                       type_bytes)
from repro.models.sharding import param_specs, spec_for_leaf


def test_spec_rules():
    assert spec_for_leaf("blocks/attn/wq/w", 3) == P(None, None, "model")
    assert spec_for_leaf("blocks/attn/wo/w", 3) == P(None, "model", None)
    assert spec_for_leaf("blocks/mlp/gate/w", 3) == P(None, None, "model")
    assert spec_for_leaf("blocks/mlp/down/w", 3) == P(None, "model", None)
    assert spec_for_leaf("embed/emb", 2) == P("model", None)
    assert spec_for_leaf("blocks/moe/w_gate", 4) == P(None, "model", None, None)
    assert spec_for_leaf("blocks/moe/router/w", 3) == P(None, None, None)
    assert spec_for_leaf("final_norm/scale", 1) in (P(), P(None))
    assert spec_for_leaf("blocks/mamba/in_proj/w", 3) == P(None, None, "model")
    assert spec_for_leaf("blocks/mamba/out_proj/w", 3) == P(None, "model", None)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "olmoe-1b-7b", "mamba2-130m",
                                  "zamba2-7b", "seamless-m4t-large-v2"])
def test_param_specs_cover_tree(arch):
    """Every leaf gets a spec with matching rank; big matmul weights are
    never left fully replicated."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(shape)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shape)
    assert len(flat_s) == len(flat_l)
    for leaf, spec in zip(flat_l, flat_s):
        assert len(spec) <= leaf.ndim
    # matmul params (>= 2 dims, big) must be sharded somewhere
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(shape)[0], flat_s):
        ps = jax.tree_util.keystr(path)
        if leaf.ndim >= 2 and "norm" not in ps and "router" not in ps \
                and min(leaf.shape[-2:]) >= 64:
            assert any(s is not None for s in spec), (ps, spec)


# ------------------------------------------------------------- hlo analyzer
_FAKE_HLO = """
HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%dot), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ip, %ar)
}

%cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,128]) -> f32[8,128] {
  %x0 = f32[8,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[8,128]) tuple(%c0, %x0)
  %wh = (s32[], f32[8,128]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_analyzer_trip_count_scaling():
    r = analyze(_FAKE_HLO)
    # dot: 2*8*128*128 flops, x12 iterations
    assert r["flops"] == pytest.approx(12 * 2 * 8 * 128 * 128)
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 12
    # ring all-reduce wire bytes: 2*(n-1)/n * bytes, n=4 (iota groups [2,4])
    per = 8 * 128 * 4
    assert ar["wire_bytes"] == pytest.approx(12 * 2 * 3 / 4 * per)


def test_shape_parsing():
    assert type_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert type_bytes("(bf16[4,4], s32[])") == 4 * 4 * 2 + 4
    assert shape_dims("pred[7]") == [("pred", (7,))]


def test_analyzer_on_real_scan():
    """End-to-end on a real compiled module (single device)."""
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    r = analyze(c.as_text())
    assert r["flops"] == pytest.approx(5 * 2 * 64 * 64 * 64, rel=0.01)
