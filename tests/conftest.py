"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests and benches
must see 1 device; sharded tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves.

``hypothesis`` is optional: when it is installed we register the fast CI
profile; when it is missing we install a minimal stub into ``sys.modules`` so
that test modules doing ``from hypothesis import given, ...`` still import,
and every property-based test body skips gracefully instead of aborting the
whole collection.
"""
import os
import sys
import types

import numpy as np
import pytest

try:
    # keep hypothesis deterministic + fast on the 1-core container
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:                      # pragma: no cover - env dep
    def _make_hypothesis_stub() -> types.ModuleType:
        hyp = types.ModuleType("hypothesis")
        strat = types.ModuleType("hypothesis.strategies")

        def _any_strategy(*_a, **_k):
            return None

        # st.integers / st.floats / st.sampled_from / ... all return dummies
        strat.__getattr__ = lambda name: _any_strategy

        def given(*_a, **_k):
            def deco(fn):
                # zero-arg wrapper: pytest must NOT see the original
                # parameters (it would resolve them as fixtures)
                def wrapper():
                    pytest.skip("hypothesis not installed; "
                                "property-based test skipped")
                wrapper.__name__ = fn.__name__
                wrapper.__doc__ = fn.__doc__
                return wrapper
            return deco

        class _Settings:
            """Stub of hypothesis.settings: decorator + profile registry."""

            def __init__(self, *_a, **_k):
                pass

            def __call__(self, fn):
                return fn

            @staticmethod
            def register_profile(*_a, **_k):
                pass

            @staticmethod
            def load_profile(*_a, **_k):
                pass

        class _HealthCheck:
            def __getattr__(self, name):
                return name

        hyp.given = given
        hyp.settings = _Settings
        hyp.HealthCheck = _HealthCheck()
        hyp.strategies = strat
        hyp.__stub__ = True
        sys.modules["hypothesis"] = hyp
        sys.modules["hypothesis.strategies"] = strat
        return hyp

    _make_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
