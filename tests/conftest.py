"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests and benches
must see 1 device; sharded tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves.

``hypothesis`` is optional: when it is installed we register the fast CI
profile; when it is missing we install a deterministic mini-hypothesis into
``sys.modules`` so that property-based tests still RUN (not skip): each
``@given`` body executes over a fixed number of deterministically drawn
examples, the first of which is the strategy's boundary value (min bound /
first element) so the edge cases property tests rely on are always hit.
"""
import sys
import types
import zlib

import numpy as np
import pytest

try:
    # keep hypothesis deterministic + fast on the 1-core container
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:                      # pragma: no cover - env dep
    _STUB_EXAMPLES = 5          # examples per property when stubbing

    def _make_hypothesis_stub() -> types.ModuleType:
        hyp = types.ModuleType("hypothesis")
        strat = types.ModuleType("hypothesis.strategies")

        class _Strategy:
            """A draw(rng, first) callable: ``first`` requests the boundary
            example (strategy minimum), later draws are uniform."""

            def __init__(self, draw):
                self.draw = draw

        def integers(min_value=0, max_value=None, **_k):
            lo = 0 if min_value is None else int(min_value)
            hi = lo + 100 if max_value is None else int(max_value)
            return _Strategy(lambda r, first: lo if first
                             else int(r.integers(lo, hi + 1)))

        def floats(min_value=0.0, max_value=1.0, **_k):
            lo = float(0.0 if min_value is None else min_value)
            hi = float(1.0 if max_value is None else max_value)
            return _Strategy(lambda r, first: lo if first
                             else float(r.uniform(lo, hi)))

        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r, first: seq[0] if first
                             else seq[int(r.integers(len(seq)))])

        def booleans():
            return _Strategy(lambda r, first: False if first
                             else bool(r.integers(2)))

        def just(value):
            return _Strategy(lambda r, first: value)

        strat.integers = integers
        strat.floats = floats
        strat.sampled_from = sampled_from
        strat.booleans = booleans
        strat.just = just
        # anything exotic degrades to None (no current test needs it)
        strat.__getattr__ = lambda name: (lambda *a, **k: _Strategy(
            lambda r, first: None))

        def given(*gargs, **gkwargs):
            def deco(fn):
                # zero-arg wrapper: pytest must NOT see the original
                # parameters (it would resolve them as fixtures)
                def wrapper():
                    seed = zlib.crc32(
                        f"{fn.__module__}.{fn.__name__}".encode())
                    for ex in range(_STUB_EXAMPLES):
                        rng = np.random.default_rng([seed, ex])
                        args = [s.draw(rng, ex == 0) for s in gargs]
                        kwargs = {k: s.draw(rng, ex == 0)
                                  for k, s in gkwargs.items()}
                        try:
                            fn(*args, **kwargs)
                        except Exception as e:
                            raise AssertionError(
                                f"property falsified on stub example "
                                f"{ex}: args={args} kwargs={kwargs}"
                            ) from e
                wrapper.__name__ = fn.__name__
                wrapper.__doc__ = fn.__doc__
                return wrapper
            return deco

        class _Settings:
            """Stub of hypothesis.settings: decorator + profile registry."""

            def __init__(self, *_a, **_k):
                pass

            def __call__(self, fn):
                return fn

            @staticmethod
            def register_profile(*_a, **_k):
                pass

            @staticmethod
            def load_profile(*_a, **_k):
                pass

        class _HealthCheck:
            def __getattr__(self, name):
                return name

        hyp.given = given
        hyp.settings = _Settings
        hyp.HealthCheck = _HealthCheck()
        hyp.strategies = strat
        hyp.__stub__ = True
        sys.modules["hypothesis"] = hyp
        sys.modules["hypothesis.strategies"] = strat
        return hyp

    _make_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def lm_zoo():
    """Session-memoized reduced models: (cfg, model, params) per
    (arch, overrides). Model init and the engine's shared jit caches are
    the dominant tier-1 cost — building each reduced config once per
    session instead of once per test keeps the suite's wall clock bounded.
    Tests must NOT mutate the returned params."""
    import jax
    from repro.configs import build_model, get_config, reduced

    cache = {}

    def get(arch: str, **overrides):
        key = (arch, tuple(sorted(overrides.items())))
        if key not in cache:
            cfg = reduced(get_config(arch), **overrides)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[key] = (cfg, model, params)
        return cache[key]

    return get
