"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests and benches
must see 1 device; sharded tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os

import numpy as np
import pytest

# keep hypothesis deterministic + fast on the 1-core container
from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow,
                           HealthCheck.data_too_large])
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
