"""The gradient axis of ``repro.ops`` (paper C1, §III.B).

Three layers of guarantees:

  * per-op grad parity — ``jax.grad`` through each differentiable entry
    point under the ``reference`` / ``fused_dense`` / ``fused_packed``
    policies matches the pure-jnp surrogate autodiff, across every
    registered surrogate and edge shapes;
  * legacy equivalence — the unified ``snn_cnn.forward`` training graph is
    bit-identical (logits, BN state) and gradient-identical to the
    pre-unification ``snn_cnn.apply`` body (a verbatim pure-jnp copy kept
    here as the golden reference);
  * train-what-you-serve — ``make_kd_train_step`` through the
    ``fused_dense`` policy produces the same loss/gradients as the
    reference autodiff within float tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.kd import KDConfig
from repro.core.lif import LIFConfig, lif_multistep
from repro.core.qk_attention import qk_token_mask
from repro.core.surrogate import available_surrogates, spike
from repro.core.w2ttfs import avgpool_classifier, w2ttfs_classifier
from repro.models import nn, snn_cnn
from repro.optim import sgd_init
from repro.optim.schedules import constant_lr
from repro.train import make_kd_train_step

GRAD_POLICIES = ("reference+grad", "fused_dense+grad", "fused_packed+grad")


def _spikes(seed, shape, rate=0.3):
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < rate
            ).astype(jnp.float32)


def _w(k, n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.3


def _assert_grads_close(g, g_ref, atol=1e-5):
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=atol)


# ================================================================ policy axis
def test_policy_gradient_axis():
    pol = ops.as_policy("fused_dense")
    assert not pol.differentiable and pol.mode == "fused"
    tr = pol.for_training()
    assert tr.differentiable and tr.mode == "fused+grad"
    assert tr.for_inference() == pol
    assert ops.as_policy("fused_packed+grad").differentiable
    assert ops.as_policy("reference+grad").mode == "reference+grad"
    assert str(tr) == "fused_dense+grad"
    with pytest.raises(ValueError):
        ops.as_policy("warp+grad")
    impls = ops.implementations()
    for op in ("matmul", "lif", "fused_pe", "fused_pe_layer", "qk_mask",
               "dense_lif", "w2ttfs_head", "im2col", "pool"):
        assert (op, "reference+grad") in impls, op
        assert (op, "fused+grad") in impls, op


# ============================================================== per-op parity
@pytest.mark.parametrize("policy", GRAD_POLICIES)
@pytest.mark.parametrize("shape", [(70, 130, 65), (3, 5, 2), (128, 256, 128)])
def test_matmul_grad_parity(policy, shape):
    m, k, n = shape
    x, w = _spikes(0, (m, k)), _w(k, n)
    g = jax.grad(lambda a, b: (ops.matmul(a, b, policy=policy)
                               * jnp.arange(n)).sum(), argnums=(0, 1))
    g_ref = jax.grad(lambda a, b: ((a @ b) * jnp.arange(n)).sum(),
                     argnums=(0, 1))
    _assert_grads_close(g(x, w), g_ref(x, w))


@pytest.mark.parametrize("policy", GRAD_POLICIES)
@pytest.mark.parametrize("surrogate", available_surrogates())
def test_lif_grad_parity_all_surrogates(policy, surrogate):
    cfg = LIFConfig(surrogate=surrogate, v_th=0.7)
    cur = jax.random.normal(jax.random.PRNGKey(2), (9, 70)) * 2
    v = jax.random.normal(jax.random.PRNGKey(3), (9, 70))
    s = _spikes(4, (9, 70))

    def loss(c, vp):
        spk, vn = ops.lif(c, vp, s, lif_cfg=cfg, policy=policy)
        return (spk * 3.0 + vn).sum()

    def loss_ref(c, vp):
        vm = cfg.tau * vp * (1.0 - s) + c
        spk = spike(vm - cfg.v_th, cfg.surrogate, cfg.alpha)
        return (spk * 3.0 + vm * (1.0 - spk)).sum()

    _assert_grads_close(jax.grad(loss, argnums=(0, 1))(cur, v),
                        jax.grad(loss_ref, argnums=(0, 1))(cur, v))


@pytest.mark.parametrize("policy", GRAD_POLICIES)
def test_fused_pe_grad_parity(policy):
    m, k, n = 70, 130, 65
    x, w = _spikes(5, (m, k)), _w(k, n)
    bias = jax.random.normal(jax.random.PRNGKey(6), (n,)) * 0.5
    res = _spikes(7, (m, n))
    q = _spikes(8, (m, 16))
    cfg = LIFConfig(v_th=0.5)

    def loss(x, w, bias, res, q):
        out = ops.fused_pe(x, w, bias=bias, residual=res, q=q,
                           lif_cfg=cfg, policy=policy)
        return (out.spikes.data * jnp.arange(n)).sum()

    def loss_ref(x, w, bias, res, q):
        cur = x @ w + bias.reshape(1, -1) + res
        s = spike(cur - cfg.v_th, cfg.surrogate, cfg.alpha)
        mask = spike(q.sum(-1, keepdims=True) - 1.0, cfg.surrogate,
                     cfg.alpha)
        return (s * mask * jnp.arange(n)).sum()

    args = (x, w, bias, res, q)
    _assert_grads_close(jax.grad(loss, argnums=tuple(range(5)))(*args),
                        jax.grad(loss_ref, argnums=tuple(range(5)))(*args))


@pytest.mark.parametrize("policy", GRAD_POLICIES)
@pytest.mark.parametrize("t", [1, 3])
def test_fused_pe_layer_grad_parity(policy, t):
    m, k, n = 40, 70, 33
    x, w = _spikes(9, (t, m, k)), _w(k, n)
    cfg = LIFConfig(v_th=0.5)

    def loss(x, w):
        out = ops.fused_pe_layer(x, w, lif_cfg=cfg, policy=policy)
        return (out.spikes.data * jnp.arange(n)).sum()

    def loss_ref(x, w):
        outs, v, s = [], jnp.zeros((m, n)), jnp.zeros((m, n))
        for ti in range(t):
            cur = x[ti] @ w
            vm = cur if t == 1 else cfg.tau * v * (1.0 - s) + cur
            spk = spike(vm - cfg.v_th, cfg.surrogate, cfg.alpha)
            v, s = vm * (1.0 - spk), spk
            outs.append(spk)
        return (jnp.stack(outs) * jnp.arange(n)).sum()

    _assert_grads_close(jax.grad(loss, argnums=(0, 1))(x, w),
                        jax.grad(loss_ref, argnums=(0, 1))(x, w))


@pytest.mark.parametrize("policy", GRAD_POLICIES)
@pytest.mark.parametrize("mode", ["threshold", "or"])
def test_qk_mask_grad_parity(policy, mode):
    q = _spikes(10, (2, 50, 17))
    k = _spikes(11, (2, 50, 17), 0.4)

    def loss(q, k):
        out = ops.qk_mask(q, k, mode=mode, policy=policy)
        return (out.data * 2.0).sum()

    def loss_ref(q, k):
        mask = qk_token_mask(q, mode)
        return (mask * k * 2.0).sum()

    g = jax.grad(loss, argnums=(0, 1))(q, k)
    g_ref = jax.grad(loss_ref, argnums=(0, 1))(q, k)
    _assert_grads_close(g, g_ref)
    if mode == "threshold":    # the surrogate must actually reach Q
        assert float(jnp.abs(g[0]).sum()) > 0


@pytest.mark.parametrize("policy", GRAD_POLICIES)
def test_dense_lif_grad_parity(policy):
    m, k, n = 40, 33, 65
    x = jax.random.normal(jax.random.PRNGKey(12), (m, k))
    p = {"w": _w(k, n, 13), "b": jnp.zeros((n,)) + 0.1}
    q = _spikes(14, (m, 16))
    cfg = LIFConfig(v_th=0.5)

    def loss(x, p):
        st = ops.dense_lif(p, x, cfg, q=q, policy=policy)
        return (st.data * jnp.arange(n)).sum()

    def loss_ref(x, p):
        cur = x @ p["w"] + p["b"]
        s = spike(cur - cfg.v_th, cfg.surrogate, cfg.alpha)
        mask = spike(q.sum(-1, keepdims=True) - 1.0, cfg.surrogate,
                     cfg.alpha)
        return (s * mask * jnp.arange(n)).sum()

    _assert_grads_close(jax.grad(loss, argnums=(0, 1))(x, p),
                        jax.grad(loss_ref, argnums=(0, 1))(x, p))


@pytest.mark.parametrize("policy", GRAD_POLICIES)
def test_w2ttfs_head_grad_parity(policy):
    spk = _spikes(15, (2, 8, 8, 24))
    fc_w = _w(24, 10, 16)
    fc_b = jnp.zeros((10,))

    def loss(s_, w_, b_):
        return (ops.w2ttfs_head(s_, w_, b_, window=8, policy=policy)
                * jnp.arange(10)).sum()

    def loss_ref(s_, w_, b_):
        return (w2ttfs_classifier(s_, w_, b_, 8) * jnp.arange(10)).sum()

    args = (spk, fc_w, fc_b)
    _assert_grads_close(jax.grad(loss, argnums=(0, 1, 2))(*args),
                        jax.grad(loss_ref, argnums=(0, 1, 2))(*args))


# =========================================== legacy snn_cnn.apply equivalence
def _legacy_apply(variables, images, cfg, train=False):
    """The pre-unification pure-jnp training forward, kept verbatim as the
    golden reference the unified body must reproduce bit-for-bit."""
    from repro.core.quant import fake_quant

    def qw(w):
        return fake_quant(w, cfg.quant, is_weight=True)

    def per_step(fn, x):
        t, b = x.shape[0], x.shape[1]
        y = fn(x.reshape(t * b, *x.shape[2:]))
        return y.reshape(t, b, *y.shape[1:])

    def conv_bn(p, s, x, stride=1):
        cur = per_step(lambda z: nn.conv_apply({"w": qw(p["conv"]["w"])},
                                               z, stride), x)
        t, b = cur.shape[0], cur.shape[1]
        flat = cur.reshape(t * b, *cur.shape[2:])
        y, new_bn = nn.bn_apply(p["bn"], s, flat, train)
        return y.reshape(t, b, *cur.shape[2:]), new_bn

    params, state = variables["params"], variables["state"]
    layers = snn_cnn.build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    new_state = []
    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            cur, bn_s = conv_bn({"conv": p["conv"], "bn": p["bn"]},
                                s["bn"], x, layer[3])
            x = lif_multistep(cur, cfg.lif)
            new_state.append({"bn": bn_s})
        elif kind == "maxpool":
            x = per_step(nn.max_pool, x)
            new_state.append({})
        elif kind == "resblock":
            stride = layer[3]
            cur1, bn1_s = conv_bn({"conv": p["conv1"], "bn": p["bn1"]},
                                  s["bn1"], x, stride)
            s1 = lif_multistep(cur1, cfg.lif)
            cur2, bn2_s = conv_bn({"conv": p["conv2"], "bn": p["bn2"]},
                                  s["bn2"], s1, 1)
            ns = {"bn1": bn1_s, "bn2": bn2_s}
            if "conv_sc" in p:
                sc, bnsc_s = conv_bn({"conv": p["conv_sc"],
                                      "bn": p["bn_sc"]}, s["bn_sc"], x,
                                     stride)
                ns["bn_sc"] = bnsc_s
            else:
                sc = x
            x = lif_multistep(cur2 + sc, cfg.lif)
            new_state.append(ns)
        elif kind == "qkformer":
            d = layer[1]
            tb = x.shape[:2]
            hw = x.shape[2] * x.shape[3]
            tok = x.reshape(*tb, hw, d)

            def lin_bn(name, inp, st):
                cur = inp @ qw(p[name]["w"])
                y, bns = nn.bn_apply(p[f"bn_{name}"], st[f"bn_{name}"],
                                     cur.reshape(tb[0] * tb[1], hw, d)
                                     .reshape(-1, d), train)
                return y.reshape(*tb, hw, d), bns

            qc, bnq_s = lin_bn("q", tok, s)
            q = lif_multistep(qc, cfg.lif)
            kc, bnk_s = lin_bn("k", tok, s)
            k = lif_multistep(kc, cfg.lif)
            mask = qk_token_mask(q, cfg.qk_mask_mode,
                                 surrogate=cfg.lif.surrogate,
                                 alpha=cfg.lif.alpha)
            pc, bnp_s = lin_bn("proj", mask * k, s)
            y = lif_multistep(pc + tok, cfg.lif)
            m1c, bnm1_s = lin_bn("mlp1", y, s)
            m1 = lif_multistep(m1c, cfg.lif)
            m2c, bnm2_s = lin_bn("mlp2", m1, s)
            y2 = lif_multistep(m2c + y, cfg.lif)
            x = y2.reshape(*tb, x.shape[2], x.shape[3], d)
            new_state.append({"bn_q": bnq_s, "bn_k": bnk_s,
                              "bn_proj": bnp_s, "bn_mlp1": bnm1_s,
                              "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, _, size = layer
            fc_w, fc_b = qw(p["fc"]["w"]), p["fc"]["b"]

            def head_one(s_t):
                if cfg.head == "w2ttfs":
                    return w2ttfs_classifier(s_t, fc_w, fc_b, size)
                return avgpool_classifier(s_t, fc_w, fc_b, size)

            logits = jnp.mean(jnp.stack([head_one(x[ti])
                                         for ti in range(t)]), axis=0)
            new_state.append({})
    return logits, new_state


def _cfg(arch, **kw):
    return snn_cnn.SNNCNNConfig(arch=arch, num_classes=10, image_size=16,
                                width_mult=0.125, **kw)


@pytest.mark.parametrize("arch,t", [("vgg11", 1), ("resnet11", 1),
                                    ("qkfresnet11", 1), ("resnet11", 3)])
def test_unified_forward_matches_legacy_apply(arch, t):
    cfg = _cfg(arch, timesteps=t)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    for train in (True, False):
        lo, so = _legacy_apply(var, imgs, cfg, train=train)
        ln, sn, _ = snn_cnn.forward(var, imgs, cfg, train=train)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(ln))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            so, sn)


def _kd_setup(cfg):
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3))
    batch = {"images": imgs, "labels": jnp.array([0, 1, 2, 3])}

    def teacher_apply(_, x):
        flat = x.reshape(x.shape[0], -1)
        return flat[:, :10] * 0.1

    return var, batch, teacher_apply


def test_kd_train_step_matches_legacy_apply():
    """One KD step on the legacy body == one KD step on the unified body:
    same loss, same gradients, same updated params."""
    cfg = _cfg("resnet11")
    var, batch, teacher_apply = _kd_setup(cfg)

    def legacy_student(p, s, x):
        return _legacy_apply({"params": p, "state": s}, x, cfg, train=True)

    def unified_student(p, s, x):
        logits, new_s, _ = snn_cnn.forward({"params": p, "state": s}, x,
                                           cfg, train=True)
        return logits, new_s

    results = []
    for student in (legacy_student, unified_student):
        step = jax.jit(make_kd_train_step(
            student, teacher_apply, None, kd=KDConfig(alpha=0.5),
            schedule=constant_lr(0.1)))
        carry = (var["params"], sgd_init(var["params"]), var["state"])
        carry, metrics = step(carry, batch)
        results.append((carry[0], metrics["loss"]))
    np.testing.assert_allclose(float(results[0][1]), float(results[1][1]),
                               rtol=1e-6)
    _assert_grads_close(results[1][0], results[0][0], atol=1e-6)


@pytest.mark.parametrize("heads", [1, 2])
def test_qk_spiking_attention_fused_grad_matches_reference(heads):
    """The spiking-LM attention trains under a fused policy: gradients
    through ``_qk_spiking_apply`` with ``fused_dense+grad`` match the
    pure-jnp reference path — including the multi-head branch, whose
    out-of-kernel QK mask must use the surrogate (a hard ``>=`` would
    silently zero the wq gradient)."""
    import dataclasses

    from repro.configs.base import ModelConfig
    from repro.models import attention

    d = 32
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=d,
                      n_heads=heads, n_kv_heads=heads, vocab_size=16,
                      spiking=True, attention_kind="qk_spiking",
                      dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, d))
    p = {"wq": {"w": _w(d, d, 20)}, "wk": {"w": _w(d, d, 21)},
         "wo": {"w": _w(d, d, 22)}}

    def loss(p, policy):
        c = dataclasses.replace(cfg, policy=policy)
        out = attention._qk_spiking_apply(p, c, x, heads, heads)
        return (out * jnp.arange(d)).sum()

    g_ref = jax.grad(loss)(p, "reference")
    g_fused = jax.grad(loss)(p, "fused_dense+grad")
    _assert_grads_close(g_fused, g_ref, atol=1e-4)
    assert float(jnp.abs(g_fused["wq"]["w"]).sum()) > 0


def test_kd_train_step_fused_policy_matches_reference():
    """Train-what-you-serve: the KD step through the fused_dense policy
    (Pallas forward + surrogate custom_vjp backward) produces the same
    loss and gradients as the pure-jnp reference autodiff."""
    cfg = _cfg("resnet11")
    var, batch, teacher_apply = _kd_setup(cfg)

    def student(p, s, x, policy=None):
        logits, new_s, _ = snn_cnn.forward({"params": p, "state": s}, x,
                                           cfg, train=True, policy=policy)
        return logits, new_s

    results = {}
    for pol in ("reference", "fused_dense"):
        step = jax.jit(make_kd_train_step(
            student, teacher_apply, None, kd=KDConfig(alpha=0.5),
            schedule=constant_lr(0.1), policy=pol))
        carry = (var["params"], sgd_init(var["params"]), var["state"])
        carry, metrics = step(carry, batch)
        results[pol] = (carry[0], float(metrics["loss"]))
    np.testing.assert_allclose(results["fused_dense"][1],
                               results["reference"][1], rtol=1e-5)
    _assert_grads_close(results["fused_dense"][0], results["reference"][0],
                        atol=1e-4)
