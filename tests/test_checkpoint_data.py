"""Checkpoint roundtrip/async/prune + synthetic-data determinism."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticImageDataset, SyntheticTokenDataset
from repro.train import (AsyncCheckpointer, latest_checkpoint,
                         restore_checkpoint, save_checkpoint)
from repro.train.checkpoint import prune_checkpoints


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": (jnp.zeros((2, 2)), jnp.full((3,), 2.5))}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = restore_checkpoint(latest_checkpoint(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    for s in (1, 5, 9, 13):
        save_checkpoint(tmp_path, s, _tree())
    assert latest_checkpoint(tmp_path).name == "step_00000013"
    prune_checkpoints(tmp_path, keep=2)
    remaining = sorted(p.name for p in tmp_path.iterdir())
    assert remaining == ["step_00000009", "step_00000013"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    ck.save(3, _tree())
    ck.wait()
    assert latest_checkpoint(tmp_path).name == "step_00000003"


def test_restore_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    bad_like = {"only": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(AssertionError):
        restore_checkpoint(latest_checkpoint(tmp_path), bad_like)


# ------------------------------------------------------- shard integrity
def _like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def test_manifest_carries_per_leaf_crc32(tmp_path):
    import json
    import zlib

    path = save_checkpoint(tmp_path, 2, _tree())
    manifest = json.loads((path / "manifest.json").read_text())
    n = len(manifest["paths"])
    assert len(manifest["crc32"]) == n
    for i in range(n):
        arr = np.load(path / f"{i:04d}.npy")
        assert manifest["crc32"][i] == \
            (zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF)


def test_corrupted_shard_raises_naming_leaf(tmp_path):
    from repro.train.checkpoint import CheckpointCorrupt

    tree = _tree()
    path = save_checkpoint(tmp_path, 3, tree)
    # flip bytes INSIDE shard 0 (same shape/dtype, different contents):
    # only the CRC can catch this class of corruption
    arr = np.load(path / "0000.npy")
    arr = arr + 1
    np.save(path / "0000.npy", arr)
    with pytest.raises(CheckpointCorrupt, match=r"CRC32.*|.*CRC32") as ei:
        restore_checkpoint(path, _like(tree))
    assert "0000.npy" in str(ei.value)      # names the bad shard + leaf
    assert "'a'" in str(ei.value) or "a" in str(ei.value)


def test_wrong_shape_shard_raises(tmp_path):
    from repro.train.checkpoint import CheckpointCorrupt

    tree = _tree()
    path = save_checkpoint(tmp_path, 4, tree)
    np.save(path / "0001.npy", np.zeros((9, 9), np.float32))
    with pytest.raises(CheckpointCorrupt, match="shape"):
        restore_checkpoint(path, _like(tree))


def test_wrong_dtype_shard_raises(tmp_path):
    from repro.train.checkpoint import CheckpointCorrupt

    tree = _tree()
    path = save_checkpoint(tmp_path, 5, tree)
    i = [jax.tree_util.keystr(p) for p, _ in
         jax.tree_util.tree_flatten_with_path(tree)[0]]
    # rewrite shard 0 with the right shape but a different dtype
    arr = np.load(path / "0000.npy")
    np.save(path / "0000.npy", arr.astype(np.float16))
    with pytest.raises(CheckpointCorrupt, match="dtype"):
        restore_checkpoint(path, _like(tree))


def test_crc_less_manifest_still_restores(tmp_path):
    """Checkpoints written before CRC support carry no ``crc32`` key:
    restore must stay backward-compatible (shape/dtype checks only)."""
    import json

    tree = _tree()
    path = save_checkpoint(tmp_path, 6, tree)
    manifest = json.loads((path / "manifest.json").read_text())
    del manifest["crc32"]
    (path / "manifest.json").write_text(json.dumps(manifest))
    restored, step = restore_checkpoint(path, _like(tree))
    assert step == 6
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_checkpoint_warns_and_skips_partial_dirs(tmp_path):
    """A ``.tmp_step_*`` dir is a writer that died mid-save: it must never
    be selected, and the operator hears about it."""
    save_checkpoint(tmp_path, 7, _tree())
    (tmp_path / ".tmp_step_00000009").mkdir()
    with pytest.warns(RuntimeWarning, match="partial"):
        latest = latest_checkpoint(tmp_path)
    assert latest.name == "step_00000007"


# --------------------------------------------------------------------- data
def test_token_data_deterministic_and_shard_distinct():
    ds = SyntheticTokenDataset(vocab_size=128, seq_len=16, seed=3)
    a = ds.batch(5, 4, shard=0)
    b = ds.batch(5, 4, shard=0)
    np.testing.assert_array_equal(a, b)          # replay-safe
    c = ds.batch(5, 4, shard=1)
    assert not np.array_equal(a, c)              # shards differ
    d = ds.batch(6, 4, shard=0)
    assert not np.array_equal(a, d)              # steps differ


def test_token_data_learnable_structure():
    """Bigram structure: successor sets are small (compressible)."""
    ds = SyntheticTokenDataset(vocab_size=64, seq_len=64, seed=0,
                               branching=4)
    batch = ds.batch(0, 64)
    succ = {}
    for row in batch:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    sizes = [len(v) for v in succ.values()]
    assert np.mean(sizes) <= 4.5


def test_image_data_class_structure():
    ds = SyntheticImageDataset(num_classes=4, image_size=8, seed=1,
                               noise=0.1)
    imgs, labels = ds.batch(0, 64)
    # images of the same class are closer to their mean than to others
    for cls in range(4):
        sel = imgs[labels == cls]
        if len(sel) == 0:
            continue
        d_own = np.abs(sel - ds.means[cls]).mean()
        d_other = np.abs(sel - ds.means[(cls + 1) % 4]).mean()
        assert d_own < d_other
