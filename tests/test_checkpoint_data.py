"""Checkpoint roundtrip/async/prune + synthetic-data determinism."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticImageDataset, SyntheticTokenDataset
from repro.train import (AsyncCheckpointer, latest_checkpoint,
                         restore_checkpoint, save_checkpoint)
from repro.train.checkpoint import prune_checkpoints


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": (jnp.zeros((2, 2)), jnp.full((3,), 2.5))}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = restore_checkpoint(latest_checkpoint(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    for s in (1, 5, 9, 13):
        save_checkpoint(tmp_path, s, _tree())
    assert latest_checkpoint(tmp_path).name == "step_00000013"
    prune_checkpoints(tmp_path, keep=2)
    remaining = sorted(p.name for p in tmp_path.iterdir())
    assert remaining == ["step_00000009", "step_00000013"]


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    ck.save(3, _tree())
    ck.wait()
    assert latest_checkpoint(tmp_path).name == "step_00000003"


def test_restore_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    bad_like = {"only": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(AssertionError):
        restore_checkpoint(latest_checkpoint(tmp_path), bad_like)


# --------------------------------------------------------------------- data
def test_token_data_deterministic_and_shard_distinct():
    ds = SyntheticTokenDataset(vocab_size=128, seq_len=16, seed=3)
    a = ds.batch(5, 4, shard=0)
    b = ds.batch(5, 4, shard=0)
    np.testing.assert_array_equal(a, b)          # replay-safe
    c = ds.batch(5, 4, shard=1)
    assert not np.array_equal(a, c)              # shards differ
    d = ds.batch(6, 4, shard=0)
    assert not np.array_equal(a, d)              # steps differ


def test_token_data_learnable_structure():
    """Bigram structure: successor sets are small (compressible)."""
    ds = SyntheticTokenDataset(vocab_size=64, seq_len=64, seed=0,
                               branching=4)
    batch = ds.batch(0, 64)
    succ = {}
    for row in batch:
        for a, b in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(b))
    sizes = [len(v) for v in succ.values()]
    assert np.mean(sizes) <= 4.5


def test_image_data_class_structure():
    ds = SyntheticImageDataset(num_classes=4, image_size=8, seed=1,
                               noise=0.1)
    imgs, labels = ds.batch(0, 64)
    # images of the same class are closer to their mean than to others
    for cls in range(4):
        sel = imgs[labels == cls]
        if len(sel) == 0:
            continue
        d_own = np.abs(sel - ds.means[cls]).mean()
        d_other = np.abs(sel - ds.means[(cls + 1) % 4]).mean()
        assert d_own < d_other
