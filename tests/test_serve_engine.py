"""Serving engine: continuous batching correctness + elastic-FIFO
invariants.

The decisive tests:
  * the engine's greedy output for each request EQUALS a naive
    single-request reference loop — slot pooling, padding buckets, and
    per-slot length vectors must not change a single token;
  * the chunked-prefill pipeline is BIT-IDENTICAL to the blocking engine
    (same tokens per request, any family);
  * per-request outputs are invariant to arrival order and slot
    contention, and to downstream out-FIFO stalls;
  * no request starves under sustained admission backpressure (bounded
    ticks-to-first-token at a full queue).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import Engine, EngineConfig, QueueFull, ReplicaRouter

ARCHS = ["qwen3-1.7b", "mamba2-130m", "zamba2-7b"]
REF_MAXLEN = 32          # fixed reference cache size: one decode compile/arch
_REF_JIT: dict = {}


def _prompts(cfg, n=3, lens=(3, 12), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(*lens)))
            for _ in range(n)]


def _ref_steps(model):
    key = (type(model), model.cfg)
    if key not in _REF_JIT:
        _REF_JIT[key] = (
            jax.jit(functools.partial(model.prefill,
                                      return_all_logits=False,
                                      max_len=REF_MAXLEN)),
            jax.jit(model.decode_step))
    return _REF_JIT[key]


def _reference_greedy(model, params, prompt, max_new):
    prefill, decode = _ref_steps(model)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = prefill(params, {"tokens": toks})
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(max_new - 1):
        l, cache = decode(params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(l[0])))
    return out


def _run(model, params, prompts, max_new=6, **cfg_kw):
    kw = dict(max_slots=3, max_len=64, prefill_pad=8)
    kw.update(cfg_kw)
    eng = Engine(model, params, EngineConfig(**kw))
    uids = [eng.submit(p, max_new=max_new) for p in prompts]
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert len(fin) == len(prompts)
    return [fin[u].out for u in uids], eng


@pytest.mark.parametrize("arch", ARCHS)
def test_engine_matches_reference(arch, lm_zoo):
    cfg, model, params = lm_zoo(arch)
    prompts = _prompts(cfg)
    outs, _ = _run(model, params, prompts)
    for out, prompt in zip(outs, prompts):
        ref = _reference_greedy(model, params, prompt, 6)
        assert out == ref, f"engine={out} ref={ref}"


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_blocking(arch, lm_zoo):
    """The tentpole invariant: the elastic-FIFO chunked-prefill pipeline is
    BIT-IDENTICAL to the blocking engine under greedy decode — chunks run
    over the same padded bucket, so every reduction keeps its axis length
    and no token may change."""
    cfg, model, params = lm_zoo(arch)
    prompts = _prompts(cfg, n=4, lens=(3, 20))
    blocking, _ = _run(model, params, prompts)
    chunked, eng = _run(model, params, prompts, prefill_chunk=8)
    assert chunked == blocking
    st = eng.stats()
    assert st["prefill_mode"] == "chunked" and st["prefill_chunks"] > 0


def test_chunked_prefill_matches_blocking_f8_kv(lm_zoo):
    """Quantized serving cache (kv_dtype='f8_e4m3'): the engine must keep
    per-request chunk caches at compute precision and quantize once at the
    slot write — where the blocking path does — so chunked stays
    bit-identical even though the POOL stores f8 keys."""
    cfg, model, params = lm_zoo("qwen3-1.7b", kv_dtype="f8_e4m3")
    prompts = _prompts(cfg, n=3, lens=(3, 14), seed=5)
    blocking, _ = _run(model, params, prompts)
    chunked, _ = _run(model, params, prompts, prefill_chunk=8)
    assert chunked == blocking


def test_submit_rejects_oversized_prompt(lm_zoo):
    cfg, model, params = lm_zoo("qwen3-1.7b")
    eng = Engine(model, params,
                 EngineConfig(max_slots=1, max_len=32, prefill_pad=8,
                              prefill_chunk=8))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(40), max_new=4)


def test_arrival_order_and_slot_contention_invariance(lm_zoo):
    """Per-request outputs depend only on the request, never on arrival
    order or which slots its neighbors occupy: reversing the arrival order
    (different slot assignment, different contention) must reproduce every
    sequence token-for-token."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    prompts = _prompts(cfg, n=5, lens=(3, 16), seed=1)
    fwd, _ = _run(model, params, prompts, prefill_chunk=8, max_slots=2)
    rev, _ = _run(model, params, prompts[::-1], prefill_chunk=8, max_slots=2)
    assert fwd == rev[::-1]


def test_continuous_batching_overlaps(lm_zoo):
    """More requests than slots: all served; slots reused."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    outs, eng = _run(model, params,
                     [np.arange(4) + i for i in range(7)],
                     max_new=4, max_slots=2, max_len=32)
    assert len(outs) == 7
    st = eng.stats()
    assert st["tokens"] == 7 * 4


def test_backpressure_no_starvation(lm_zoo):
    """Sustained submits against a FULL bounded admission FIFO: every
    request is served FIFO (no starvation), and ticks-to-first-token stays
    bounded by the work queued ahead of it — the elastic-FIFO guarantee
    that backpressure delays admission, never progress."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    rng = np.random.default_rng(2)
    eng = Engine(model, params,
                 EngineConfig(max_slots=1, max_len=64, prefill_pad=8,
                              prefill_chunk=8, max_queue=2))
    n_req, max_new = 6, 4
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, 10), max_new=max_new)
            for _ in range(n_req)]
    fin = {r.uid: r for r in eng.run_until_drained()}
    assert len(fin) == n_req                      # nobody starved
    assert eng.stats()["queue_hwm"] == 2          # the FIFO really filled
    # FIFO order: first tokens issue in submit order
    first_ticks = [fin[u].first_token_tick for u in uids]
    assert first_ticks == sorted(first_ticks)
    # bounded ttft: work ahead of any request is at most (queue bound +
    # one live slot) requests x (prefill chunks + decode ticks) each
    per_req = 2 + max_new                         # 2 chunks of 8 for len 10
    bound = (2 + 1) * per_req + per_req
    waits = [fin[u].first_token_tick - fin[u].enqueued_tick for u in uids]
    assert max(waits) <= bound, (waits, bound)


def test_out_fifo_stall_invariance(lm_zoo):
    """A consumer that stops draining stalls ONLY its own slot (exact
    stall: state rolls back, token re-fed) — outputs match the unbounded
    engine token-for-token and the engine reports the stall pressure."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    prompts = _prompts(cfg, n=4, lens=(3, 12), seed=3)
    ref, _ = _run(model, params, prompts, prefill_chunk=8, max_slots=2)
    eng = Engine(model, params,
                 EngineConfig(max_slots=2, max_len=64, prefill_pad=8,
                              prefill_chunk=8, out_fifo_depth=2))
    uids = [eng.submit(p, max_new=6) for p in prompts]
    drained = {u: [] for u in uids}
    for t in range(500):
        eng.step()
        if t % 3 == 2:                            # lazy consumer
            for u in uids:
                drained[u].extend(eng.pop_output(u))
        if not eng.pending():
            break
    for u in uids:
        drained[u].extend(eng.pop_output(u))
    st = eng.stats()
    assert st["stall_ticks"] > 0                  # backpressure really hit
    assert st["out_fifo_hwm"] <= 2                # bound held
    assert [drained[u] for u in uids] == ref


def test_submit_backpressure_raises_nonblocking(lm_zoo):
    cfg, model, params = lm_zoo("qwen3-1.7b")
    eng = Engine(model, params,
                 EngineConfig(max_slots=1, max_len=32, prefill_pad=8,
                              prefill_chunk=8, max_queue=1))
    eng.submit(np.arange(6), max_new=4)
    with pytest.raises(QueueFull):
        eng.submit(np.arange(6), max_new=4, block=False)
    eng.run_until_drained()


def test_stats_expose_fifo_telemetry(lm_zoo):
    """The software analogue of the paper's FIFO-depth elasticity: queue /
    prefill-FIFO / out-FIFO occupancy high-water marks and decode-tick
    latency percentiles are first-class stats."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    _, eng = _run(model, params, _prompts(cfg, n=5), prefill_chunk=8)
    st = eng.stats()
    for key in ("queue_hwm", "prefill_fifo_hwm", "out_fifo_hwm",
                "stall_ticks", "prefill_chunks", "decode_tick_p99_s",
                "decode_tick_p50_s", "decode_ticks"):
        assert key in st, key
    assert st["prefill_fifo_hwm"] >= 1
    assert st["decode_tick_p99_s"] >= st["decode_tick_p50_s"] >= 0.0


def test_replica_router_matches_single_engine(lm_zoo):
    """Data-parallel serving: sharding the slot pools across replicas with
    least-loaded dispatch must not change any request's tokens, and the
    dispatch must actually balance."""
    cfg, model, params = lm_zoo("qwen3-1.7b")
    prompts = _prompts(cfg, n=4, lens=(3, 14), seed=4)
    single, _ = _run(model, params, prompts, prefill_chunk=8, max_slots=2)
    router = ReplicaRouter(
        model, params,
        EngineConfig(max_slots=2, max_len=64, prefill_pad=8,
                     prefill_chunk=8), n_replicas=2)
    uids = [router.submit(p, max_new=6) for p in prompts]
    router.run_until_drained()
    outs = [router.result(u).out for u in uids]
    assert outs == single
    st = router.stats()
    assert st["replicas"] == 2 and sum(st["dispatch"]) == len(prompts)
    assert min(st["dispatch"]) >= 1               # least-loaded balanced


def test_qk_spiking_engine_stateless_cache(lm_zoo):
    """Paper C4 serving: QKFormer attention decodes with a 0-length cache,
    identically under blocking and chunked prefill."""
    cfg, model, params = lm_zoo("qwen3-1.7b", spiking=True,
                                attention_kind="qk_spiking")
    cache = model.init_cache(2, 64)
    k, v = cache["layers"]
    assert k.shape[-3] == 0                     # no KV storage at all
    blocking, _ = _run(model, params, [np.arange(5)], max_new=4,
                       max_slots=2, max_len=32)
    chunked, _ = _run(model, params, [np.arange(5)], max_new=4,
                      max_slots=2, max_len=32, prefill_chunk=4)
    assert blocking == chunked
    assert len(blocking[0]) == 4
