"""Serving engine: continuous batching correctness.

The decisive test: the engine's greedy output for each request must EQUAL a
naive single-request reference loop (prefill exact length + decode one by
one) — slot pooling, padding buckets, and per-slot length vectors must not
change a single token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config, reduced
from repro.serve import Engine, EngineConfig


def _reference_greedy(model, params, prompt, max_new):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks},
                                  max_len=len(prompt) + max_new + 1)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(max_new - 1):
        l, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(l[0])))
    return out


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-130m", "zamba2-7b"])
def test_engine_matches_reference(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(3, 12)))
               for _ in range(5)]

    eng = Engine(model, params, EngineConfig(max_slots=3, max_len=64,
                                             prefill_pad=8))
    uids = [eng.submit(p, max_new=6) for p in prompts]
    finished = {r.uid: r for r in eng.run_until_drained()}
    assert len(finished) == len(prompts)

    for uid, prompt in zip(uids, prompts):
        ref = _reference_greedy(model, params, prompt, 6)
        assert finished[uid].out == ref, \
            f"engine={finished[uid].out} ref={ref}"


def test_continuous_batching_overlaps():
    """More requests than slots: all served; slots reused."""
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_slots=2, max_len=32,
                                             prefill_pad=8))
    for i in range(7):
        eng.submit(np.arange(4) + i, max_new=4)
    done = eng.run_until_drained()
    assert len(done) == 7
    st = eng.stats()
    assert st["tokens"] == 7 * 4


def test_qk_spiking_engine_stateless_cache():
    """Paper C4 serving: QKFormer attention decodes with a 0-length cache."""
    cfg = reduced(get_config("qwen3-1.7b"), spiking=True,
                  attention_kind="qk_spiking")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 64)
    k, v = cache["layers"]
    assert k.shape[-3] == 0                     # no KV storage at all
    eng = Engine(model, params, EngineConfig(max_slots=2, max_len=32))
    eng.submit(np.arange(5), max_new=4)
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].out) == 4
