"""Golden kernel-parity sweep: EVERY Pallas kernel family against its
``ref.py`` oracle over one shared grid of edge shapes and spike patterns.

The per-kernel test files probe each kernel's own corners; this sweep is
the regression net ACROSS the suite — a kernel change cannot pass its own
file while silently breaking an edge (non-multiple-of-block M/K, singleton
batch, all-zero input = every block skipped, all-one input = every block
dense) or one of the two spike formats, because the same grid runs here
for all seven families.

Binary spike outputs must match the oracle EXACTLY (event skip and packing
are exact transforms); f32 accumulations compare at tight tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.events import pack_spikes_ref, unpack_spikes_ref
from repro.core.lif import LIFConfig
from repro.core.surrogate import spike
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.fused_pe import fused_pe, fused_pe_ref
from repro.kernels.lif_update import lif_update, lif_update_ref
from repro.kernels.packed import pack_spikes, unpack_spikes
from repro.kernels.qk_attention import qk_attention_fused, qk_attention_ref
from repro.kernels.spike_matmul import spike_matmul, spike_matmul_ref
from repro.kernels.w2ttfs_pool import w2ttfs_pool_fc, w2ttfs_pool_fc_ref

# (M, K, N): block-aligned, non-multiple-of-block M/K/N, and singleton
MATMUL_SHAPES = [(128, 128, 64), (130, 257, 33), (1, 7, 5)]
# spike fill patterns: random events, no events (all blocks skipped),
# saturated (every block dense)
PATTERNS = ["bernoulli", "zeros", "ones"]
FORMATS = ["dense", "packed"]


def _spikes(shape, pattern, seed=0):
    if pattern == "zeros":
        return jnp.zeros(shape, jnp.int8)
    if pattern == "ones":
        return jnp.ones(shape, jnp.int8)
    return (jax.random.uniform(jax.random.PRNGKey(seed), shape) < 0.2
            ).astype(jnp.int8)


def _weights(k, n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.1


# ------------------------------------------------------------- spike_matmul
@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("fmt", FORMATS)
def test_spike_matmul_parity(m, k, n, pattern, fmt):
    x = _spikes((m, k), pattern, seed=m + k)
    w = _weights(k, n)
    op = pack_spikes(x) if fmt == "packed" else x
    out = spike_matmul(op, w)
    ref = spike_matmul_ref(x, w)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    if pattern == "zeros":     # event skip is exact: no block may write
        assert float(jnp.abs(out).max()) == 0.0


# ----------------------------------------------------------------- fused_pe
@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("fmt", FORMATS)
def test_fused_pe_parity(m, k, n, pattern, fmt):
    x = _spikes((m, k), pattern, seed=m + n)
    w = _weights(k, n)
    bias = jax.random.normal(jax.random.PRNGKey(3), (n,)) * 0.5
    q = _spikes((m, 16), pattern, seed=m + n + 1)
    op = pack_spikes(x) if fmt == "packed" else x
    out = fused_pe(op, w, bias=bias, q=q, v_th=0.3)
    spk_ref, v_ref, vld_ref = fused_pe_ref(x, w, bias=bias, q=q, v_th=0.3)
    np.testing.assert_array_equal(np.asarray(out.spikes),
                                  np.asarray(spk_ref))
    assert out.v_next is None and v_ref is None   # stateless deployed form
    np.testing.assert_array_equal(np.asarray(out.vld_next),
                                  np.asarray(vld_ref))


@pytest.mark.parametrize("m,k,n", [(130, 257, 33)])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_fused_pe_pack_out_parity(m, k, n, pattern):
    """pack_out chains the event-compressed HBM format: unpacking the
    emitted PackedSpikes must reproduce the dense oracle bit-for-bit.
    (Intentional compat-shim exercise — the deprecated kwarg must keep
    working AND keep warning.)"""
    from repro.ops.compat import reset_warning_dedup

    x = _spikes((m, k), pattern, seed=7)
    w = _weights(k, n)
    reset_warning_dedup()
    with pytest.warns(DeprecationWarning):
        out = fused_pe(x, w, pack_out=True)
    spk_ref, _, vld_ref = fused_pe_ref(x, w)
    np.testing.assert_array_equal(np.asarray(unpack_spikes(out.spikes)),
                                  np.asarray(spk_ref))
    np.testing.assert_array_equal(np.asarray(out.spikes.vld_cnt),
                                  np.asarray(vld_ref))


# ------------------------------------------------------------------- packed
@pytest.mark.parametrize("m,k", [(128, 128), (130, 257), (1, 7)])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_packed_roundtrip_parity(m, k, pattern):
    x = _spikes((m, k), pattern, seed=m)
    ps = pack_spikes(x)
    ref = pack_spikes_ref(x)
    np.testing.assert_array_equal(np.asarray(ps.words),
                                  np.asarray(ref.words))
    np.testing.assert_array_equal(np.asarray(ps.vld_cnt),
                                  np.asarray(ref.vld_cnt))
    np.testing.assert_array_equal(np.asarray(unpack_spikes(ps)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(unpack_spikes_ref(ref)),
                                  np.asarray(x))


# --------------------------------------------------------------- lif_update
@pytest.mark.parametrize("shape", [(1, 1), (3, 130), (2, 5, 33)])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_lif_update_parity(shape, pattern):
    if pattern == "bernoulli":
        cur = jax.random.normal(jax.random.PRNGKey(0), shape) * 2
    else:
        cur = (jnp.zeros(shape) if pattern == "zeros"
               else jnp.ones(shape))
    v = jax.random.normal(jax.random.PRNGKey(1), shape)
    s = _spikes(shape, pattern).astype(jnp.float32)
    for soft in (False, True):
        spk, vn = lif_update(cur, v, s, soft_reset=soft)
        spk_r, vn_r = lif_update_ref(cur, v, s, soft_reset=soft)
        np.testing.assert_array_equal(np.asarray(spk), np.asarray(spk_r))
        np.testing.assert_allclose(np.asarray(vn), np.asarray(vn_r),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------- qk_attention
@pytest.mark.parametrize("b,n,d", [(1, 1, 16), (2, 100, 17), (1, 257, 64)])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_qk_attention_parity(b, n, d, pattern):
    q = _spikes((b, n, d), pattern, seed=n)
    k = _spikes((b, n, d), "bernoulli", seed=n + 1)
    out = qk_attention_fused(q, k)
    ref = qk_attention_ref(q, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -------------------------------------------------------------- w2ttfs_pool
@pytest.mark.parametrize("b", [1, 5])        # singleton + non-multiple of 8
@pytest.mark.parametrize("pattern", PATTERNS)
def test_w2ttfs_pool_parity(b, pattern):
    hw, c, cls, window = 4, 8, 10, 2
    s = _spikes((b, hw, hw, c), pattern, seed=b).astype(jnp.float32)
    w = _weights((hw // window) ** 2 * c, cls, seed=2)
    bias = jax.random.normal(jax.random.PRNGKey(4), (cls,))
    out = w2ttfs_pool_fc(s, w, bias, window=window)
    ref = w2ttfs_pool_fc_ref(s, w, bias, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,s,h,hkv,d", [(1, 1, 1, 1, 64),
                                         (1, 100, 4, 2, 64),
                                         (2, 64, 2, 2, 128)])
def test_flash_attention_parity(b, s, h, hkv, d):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, d), jnp.float32)
    out = flash_attention(q, k, v, q_block=64, kv_block=64)
    ke = jnp.repeat(k, h // hkv, axis=2)
    ve = jnp.repeat(v, h // hkv, axis=2)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        ke.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        ve.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        causal=True, scale=d ** -0.5,
    ).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------- multi-head QK write-back sweep
# (h, hkv): multi-head attention (h == hkv) and grouped-KV (hkv == h/2,
# plus the deepest grouping hkv == 1) at every head count — the Fig-5
# on-the-fly dataflow must be head-blocked-exact in BOTH formats.
HEAD_CONFIGS = [(1, 1), (2, 2), (2, 1), (4, 4), (4, 2)]
MH_POLICIES = ["reference", "fused_dense", "fused_packed"]
MH_DH = 16          # head width below the 32-bit pack-word lane: the
                    # packed per-head popcount must split word lanes
MH_QK_THRESHOLD = 5.0


def _mh_inputs(h, hkv, m=130, k=96, dh=MH_DH):
    x = jax.random.normal(jax.random.PRNGKey(7 * h + hkv), (m, k)) * 0.6
    pq = {"w": _weights(k, h * dh, seed=h),
          "b": jnp.full((h * dh,), 0.05)}
    pk = {"w": _weights(k, hkv * dh, seed=h + 50),
          "b": jnp.full((hkv * dh,), 0.05)}
    return x, pq, pk


def _mh_oracle(x, pq, pk, h, hkv, dh, cfg):
    """Independent per-head oracle: threshold each projection, mask each
    QUERY head by its own Q row sum, broadcast the mask over the grouped
    KV head blocks (never materializing a pre-mask replicated KV)."""
    m = x.shape[0]

    def proj(p):
        cur = x.astype(jnp.float32) @ p["w"].astype(jnp.float32) + p["b"]
        return (cur >= cfg.v_th).astype(jnp.int8)

    qs, ks = proj(pq), proj(pk)
    rs = qs.astype(jnp.float32).reshape(m, h, dh).sum(axis=-1)
    mask = (rs >= MH_QK_THRESHOLD).astype(jnp.int8)
    g = h // hkv
    out = (ks.reshape(m, hkv, 1, dh)
           * mask.reshape(m, hkv, g, 1)).reshape(m, h * dh)
    return qs, out


@pytest.mark.parametrize("h,hkv", HEAD_CONFIGS)
@pytest.mark.parametrize("policy", MH_POLICIES)
def test_dense_lif_multihead_parity(h, hkv, policy):
    """Q -> head-masked (grouped) K chain through ops.dense_lif: spikes
    bit-identical to the per-head oracle under every policy."""
    dh = MH_DH
    cfg = LIFConfig(v_th=0.5)
    x, pq, pk = _mh_inputs(h, hkv)
    q_ref, out_ref = _mh_oracle(x, pq, pk, h, hkv, dh, cfg)
    q_st = ops.dense_lif(pq, x, cfg, policy=policy)
    out_st = ops.dense_lif(pk, x, cfg, q=q_st,
                           qk_threshold=MH_QK_THRESHOLD,
                           heads=(h, dh), kv_heads=hkv, policy=policy)
    if policy == "fused_packed":
        assert q_st.is_packed and out_st.is_packed
    np.testing.assert_array_equal(np.asarray(q_st.to_dense()),
                                  np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(out_st.to_dense()),
                                  np.asarray(out_ref))
    # the oracle's mask must actually vary per head (no degenerate sweep)
    if h > 1:
        rs = np.asarray(q_ref).astype(np.float32).reshape(-1, h, dh)
        per_head = (rs.sum(-1) >= MH_QK_THRESHOLD)
        assert 0 < per_head.mean() < 1


@pytest.mark.parametrize("h,hkv", HEAD_CONFIGS)
@pytest.mark.parametrize("policy",
                         [p + "+grad" for p in MH_POLICIES])
def test_dense_lif_multihead_grad_parity(h, hkv, policy):
    """Surrogate gradients through the head-blocked mask match pure-jnp
    autodiff (per-head Heaviside on the row sums, group-broadcast mask,
    UNEXPANDED grouped weights) under every differentiable policy."""
    dh = MH_DH
    cfg = LIFConfig(v_th=0.5)
    x, pq, pk = _mh_inputs(h, hkv)
    m = x.shape[0]
    g = h // hkv
    coeff = jnp.arange(h * dh, dtype=jnp.float32)

    def loss(x_, pq_, pk_):
        q_st = ops.dense_lif(pq_, x_, cfg, policy=policy)
        out = ops.dense_lif(pk_, x_, cfg, q=q_st,
                            qk_threshold=MH_QK_THRESHOLD,
                            heads=(h, dh), kv_heads=hkv, policy=policy)
        return (out.data * coeff).sum()

    def loss_ref(x_, pq_, pk_):
        qs = spike(x_ @ pq_["w"] + pq_["b"] - cfg.v_th,
                   cfg.surrogate, cfg.alpha)
        ks = spike(x_ @ pk_["w"] + pk_["b"] - cfg.v_th,
                   cfg.surrogate, cfg.alpha)
        rs = qs.reshape(m, h, dh).sum(axis=-1)
        mask = spike(rs - MH_QK_THRESHOLD, cfg.surrogate, cfg.alpha)
        out = (ks.reshape(m, hkv, 1, dh)
               * mask.reshape(m, hkv, g, 1)).reshape(m, h * dh)
        return (out * coeff).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(x, pq, pk)
    grads_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, pq, pk)
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(grads_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    # the mask path keeps wq connected to the loss
    assert float(jnp.abs(grads[1]["w"]).max()) > 0
