"""The paper's deployed models (VGG-11 / ResNet-11 / QKFResNet-11):
full-spike execution, F&Q fusion equivalence, W2TTFS head, T>1 baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig
from repro.models import snn_cnn
from repro.models.snn_cnn import SNNCNNConfig


def _cfg(arch, **kw):
    return SNNCNNConfig(arch=arch, num_classes=10, image_size=32,
                        width_mult=0.125, **kw)


def _imgs(b=2, key=0):
    return jax.random.normal(jax.random.PRNGKey(key), (b, 32, 32, 3))


@pytest.mark.parametrize("arch", ["vgg11", "resnet11", "qkfresnet11"])
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    logits, _, aux = snn_cnn.forward(var, _imgs(), cfg, train=False)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux["total_spikes"]) > 0


@pytest.mark.parametrize("arch", ["vgg11", "qkfresnet11"])
def test_full_spike_execution(arch):
    """Every inter-layer activation is binary — the paper's full-spike
    claim (C2/C3): spike rates in [0,1] and integer spike counts."""
    cfg = _cfg(arch)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    _, _, aux = snn_cnn.forward(var, _imgs(), cfg, train=False)
    for name, rate in aux["rates"].items():
        r = float(rate)
        assert 0.0 <= r <= 1.0, (name, r)
    for name, count in aux["spikes"].items():
        c = float(count)
        assert abs(c - round(c)) < 1e-3, (name, c)   # whole spikes only


def test_train_gradients_flow():
    cfg = _cfg("resnet11")
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    imgs, labels = _imgs(4), jnp.array([0, 1, 2, 3])

    def loss_fn(params):
        logits, _, _ = snn_cnn.forward({"params": params,
                                        "state": var["state"]}, imgs, cfg,
                                       train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, labels[:, None], 1).mean()

    g = jax.grad(loss_fn)(var["params"])
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["vgg11", "resnet11", "qkfresnet11"])
def test_fuse_model_close_to_eval(arch):
    """F&Q stage: BN-fused inference == eval-mode unfused network (exact up
    to float assoc). This is the deployment artifact NEURAL executes."""
    cfg = _cfg(arch)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    # non-trivial BN state so fusion actually does something
    var["state"] = jax.tree_util.tree_map(
        lambda s: s + 0.1 * jax.random.uniform(jax.random.PRNGKey(1),
                                               s.shape), var["state"])
    imgs = _imgs()
    ref, _, _ = snn_cnn.forward(var, imgs, cfg, train=False)
    fused = snn_cnn.fuse_model(var, cfg)
    out, _, aux = snn_cnn.forward(fused, imgs, cfg)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-3, atol=1e-3)


def test_event_kernel_path_bit_exact():
    """C3 integration: routing the QKFormer matmuls through the Pallas
    spike_matmul (block event-skip) changes NOTHING numerically."""
    cfg = dataclasses.replace(_cfg("qkfresnet11"), image_size=16)
    cfg_ev = dataclasses.replace(cfg, policy="fused_packed")
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    imgs = _imgs()[:, :16, :16, :]
    ref, _, _ = snn_cnn.forward(fused, imgs, cfg)
    ev, _, _ = snn_cnn.forward(fused, imgs, cfg_ev)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ev),
                               rtol=1e-4, atol=1e-4)


def test_quantized_fused_model_runs():
    cfg = _cfg("vgg11", quant=QuantConfig(enabled=True, bits=8))
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    out, _, _ = snn_cnn.forward(fused, _imgs(), cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_multi_timestep_baseline():
    """T=4 baseline (SiBrain-style) runs and spikes accumulate over T."""
    cfg1 = _cfg("resnet11", timesteps=1)
    cfg4 = _cfg("resnet11", timesteps=4)
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg1)
    _, _, aux1 = snn_cnn.forward(var, _imgs(), cfg1, train=False)
    _, _, aux4 = snn_cnn.forward(var, _imgs(), cfg4, train=False)
    assert float(aux4["total_spikes"]) > float(aux1["total_spikes"])


def test_w2ttfs_head_equals_avgpool_head():
    """Swapping the AP head for W2TTFS must not change logits (paper's
    accuracy-preservation argument, end-to-end through a real model)."""
    cfg_w = _cfg("vgg11", head="w2ttfs")
    cfg_a = _cfg("vgg11", head="avgpool")
    var = snn_cnn.init(jax.random.PRNGKey(0), cfg_w)
    lw, _, _ = snn_cnn.forward(var, _imgs(), cfg_w, train=False)
    la, _, _ = snn_cnn.forward(var, _imgs(), cfg_a, train=False)
    np.testing.assert_allclose(np.asarray(lw), np.asarray(la),
                               rtol=1e-4, atol=1e-4)
