"""End-to-end driver for the PAPER's experiment (Fig 2b / Fig 8):

  ANN teacher (ResNet-18) -> KD single-timestep SNN student (VGG-11)
  -> F&Q quantization -> KD-QAT -> W2TTFS head -> fused deployment model.

Trains for a few hundred steps on synthetic CIFAR-like data and prints the
stage-by-stage accuracy table (the paper's Fig 8) plus the Total-Spikes
metric (Table II) of the final deployment artifact.

Every stage runs the ONE ``snn_cnn.forward`` body; ``--policy`` picks the
execution policy of the student's TRAINING forward (e.g. ``fused_dense``
trains on the event-driven Pallas kernels the model deploys on — the
surrogate custom_vjp supplies the backward), and the deployment artifact
runs the same graph under the same policy family.

  PYTHONPATH=src python examples/train_kd_cifar.py [--steps 220]
      [--arch vgg11] [--policy reference|fused_dense|fused_packed]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=220)
    ap.add_argument("--arch", default="vgg11",
                    choices=["vgg11", "resnet11", "qkfresnet11"])
    ap.add_argument("--policy", default=None,
                    choices=["reference", "fused_dense", "fused_packed"],
                    help="execution policy for the KD training forward "
                         "(default: reference); deployment below uses the "
                         "same choice")
    args = ap.parse_args()

    # the benchmark module IS the pipeline implementation — reuse it (the
    # step budget is an explicit parameter, not an env side channel)
    from benchmarks import fig8_kd_accuracy
    res = fig8_kd_accuracy.run(args.arch, steps=args.steps,
                               policy=args.policy)

    import jax
    import jax.numpy as jnp
    from repro.core.quant import QuantConfig
    from repro.data import SyntheticImageDataset
    from repro.models import snn_cnn

    # deployment artifact: BN-fused + quantized (what NEURAL's EPA executes)
    cfg = snn_cnn.SNNCNNConfig(arch=args.arch, width_mult=0.125, timesteps=1,
                               quant=QuantConfig(enabled=True, bits=8),
                               policy=args.policy)
    var = snn_cnn.init(jax.random.PRNGKey(1), cfg)
    fused = snn_cnn.fuse_model(var, cfg)
    ds = SyntheticImageDataset(image_size=32, seed=0)
    imgs, _ = ds.batch(0, 16)
    logits, _, aux = snn_cnn.forward(fused, jnp.asarray(imgs), cfg)
    print(f"\ndeployment model: fused+int8, total_spikes/img = "
          f"{float(aux['total_spikes']) / 16:.0f} (paper Table II metric)")
    print("stage accuracies:", {k: round(v, 4) for k, v in res.items()})


if __name__ == "__main__":
    main()
