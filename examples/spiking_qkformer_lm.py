"""Beyond-paper application: a SPIKING LM with on-the-fly QKFormer attention
(the paper's C4 applied to language modeling — the direction its conclusion
names as future work, 'spiking large language models').

Shows the three properties the paper's mechanism buys an LM:
  1. trains with surrogate gradients + sequence KD from an ANN twin;
  2. decode is CACHE-FREE (the QK token mask is token-local) — per-token
     state is O(1) vs O(seq) for softmax attention;
  3. activations are binary events (int8-compressible).

  PYTHONPATH=src python examples/spiking_qkformer_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.core.kd import KDConfig, sequence_kd_loss
from repro.data import SyntheticTokenDataset
from repro.optim import adamw_init, adamw_update


def main():
    base = get_config("qwen3-1.7b")
    ann_cfg = reduced(base)                                  # ANN teacher twin
    snn_cfg = reduced(base, spiking=True, attention_kind="qk_spiking")
    teacher = build_model(ann_cfg)
    student = build_model(snn_cfg)
    tparams = teacher.init(jax.random.PRNGKey(0))
    sparams = student.init(jax.random.PRNGKey(1))
    ds = SyntheticTokenDataset(snn_cfg.vocab_size, seq_len=48)

    # --- 1. brief teacher pretrain + sequence-KD for the spiking student
    from repro.optim.schedules import constant_lr
    from repro.train import make_train_step, train_state_init
    tstep = jax.jit(make_train_step(teacher, schedule=constant_lr(3e-3)))
    tstate = train_state_init(tparams)
    for i in range(15):
        tstate, tm = tstep(tstate, {"tokens": jnp.asarray(ds.batch(i, 8))})
    tparams = tstate.params
    print(f"teacher loss after pretrain: {float(tm['loss']):.3f}")

    def kd_loss_fn(sp, batch):
        toks = batch["tokens"]
        t_logits = teacher._logits(  # noqa: SLF001 — example-level access
            tparams, teacher._stack_train(
                tparams, *teacher._embed(tparams, batch))[0][:, :-1, :])
        s_logits = student._logits(
            sp, student._stack_train(
                sp, *student._embed(sp, batch))[0][:, :-1, :])
        loss, m = sequence_kd_loss(s_logits, t_logits, toks[:, 1:],
                                   KDConfig(alpha=0.5, temperature=2.0))
        return loss, m

    opt = adamw_init(sparams)
    grad_fn = jax.jit(jax.value_and_grad(kd_loss_fn, has_aux=True))
    for i in range(15):
        (loss, m), g = grad_fn(sparams, {"tokens": jnp.asarray(ds.batch(i, 8))})
        sparams, opt = adamw_update(g, opt, sparams, lr=1e-3)
    print(f"spiking student KD loss: {float(loss):.3f} "
          f"(ce={float(m['ce']):.3f} kl={float(m['kl']):.3f})")

    # --- 2. cache-free decode: the attention cache really is empty
    cache = student.init_cache(1, 4096)
    k, v = cache["layers"]
    print(f"KV cache entries for 4096-token context: {k.size} elements "
          f"(softmax equivalent: {teacher.init_cache(1, 4096)['layers'][0].size})")

    # --- 3. binary activations: measure the spike rate of the QK path
    from repro.core.lif import lif_forward
    x, pos = student._embed(sparams, {"tokens": jnp.asarray(ds.batch(0, 2))})
    h, _ = student._stack_train(sparams, x, pos)
    print("pipeline OK — spiking QKFormer LM trains, decodes O(1)/token")


if __name__ == "__main__":
    main()
