"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a reduced assigned architecture, trains a few steps on synthetic
data, then serves it (prefill + decode) — all on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.data import SyntheticTokenDataset
from repro.optim.schedules import constant_lr
from repro.train import make_train_step, train_state_init


def main():
    # 1. pick an assigned architecture, shrink it for CPU
    cfg = reduced(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.2f}M")

    # 2. train a few steps on synthetic bigram data
    ds = SyntheticTokenDataset(cfg.vocab_size, seq_len=64)
    step = jax.jit(make_train_step(model, schedule=constant_lr(3e-3)))
    state = train_state_init(params)
    for i in range(10):
        batch = {"tokens": jnp.asarray(ds.batch(i, 8))}
        state, metrics = step(state, batch)
        if i % 3 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.3f}")

    # 3. serve: prefill a prompt, decode a few tokens greedily
    prompt = jnp.asarray(ds.batch(999, 1)[:, :16])
    logits, cache = model.prefill(state.params, {"tokens": prompt},
                                  max_len=32)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(8):
        logits, cache = model.decode_step(
            state.params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(logits[0])))
    print("generated:", out)

    # 4. the paper's techniques are config flags on the SAME arch:
    spiking_cfg = reduced(get_config("qwen3-1.7b"), spiking=True,
                          attention_kind="qk_spiking")
    smodel = build_model(spiking_cfg)
    sparams = smodel.init(jax.random.PRNGKey(0))
    loss, _ = smodel.loss(sparams, {"tokens": jnp.asarray(ds.batch(0, 4))})
    print(f"spiking QKFormer mode: loss={float(loss):.3f} "
          "(binary activations, O(N*d) attention, cache-free decode)")


if __name__ == "__main__":
    main()
