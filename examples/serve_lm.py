"""Continuous-batching serving demo: more requests than slots, mixed prompt
lengths, greedy + sampled decoding, engine stats.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
"""
import argparse

import jax
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.serve import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_slots=4, max_len=96,
                                             prefill_pad=16))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=int(rng.integers(4, 12)),
                   temperature=0.0 if i % 2 else 0.8)
    done = eng.run_until_drained()
    for r in done[:4]:
        print(f"req {r.uid}: prompt_len={len(r.prompt)} -> {r.out}")
    print("stats:", eng.stats())


if __name__ == "__main__":
    main()
