"""Continuous-batching serving demo: more requests than slots, mixed prompt
lengths, greedy + sampled decoding, engine stats — now through the
elastic-FIFO pipeline: chunked prefill (one long prompt no longer stalls
the live decode slots), a bounded admission FIFO with backpressure on
``submit``, and streaming consumption from the per-slot output FIFOs.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
                                             [--replicas 2]
"""
import argparse

import jax
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.serve import Engine, EngineConfig, ReplicaRouter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=1)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_slots=4, max_len=96, prefill_pad=16,
                        prefill_chunk=16,     # elastic chunked prefill
                        max_queue=8)          # bounded admission FIFO
    if args.replicas > 1:
        eng = ReplicaRouter(model, params, ecfg, n_replicas=args.replicas)
    else:
        eng = Engine(model, params, ecfg)
    rng = np.random.default_rng(0)
    uids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        # submit blocks (runs engine ticks) if the admission FIFO is full —
        # the elastic-FIFO backpressure discipline
        uids.append(eng.submit(rng.integers(0, cfg.vocab_size, plen),
                               max_new=int(rng.integers(4, 12)),
                               temperature=0.0 if i % 2 else 0.8))
    # stream: drain per-slot output FIFOs while the engine runs
    streamed = {u: [] for u in uids}
    while eng.step() or eng.pending():
        for u in uids:
            streamed[u].extend(eng.pop_output(u))
    for u in uids:
        streamed[u].extend(eng.pop_output(u))
    for u in uids[:4]:
        print(f"req {u}: streamed {len(streamed[u])} tokens -> "
              f"{streamed[u]}")
    print("stats:", eng.stats())


if __name__ == "__main__":
    main()
