"""Continuous-batching serving demo: more requests than slots, mixed prompt
lengths, greedy + sampled decoding, engine stats — through the elastic-FIFO
pipeline: chunked prefill (one long prompt no longer stalls the live decode
slots), a bounded admission FIFO with backpressure on ``submit``, and
streaming consumption from the per-slot output FIFOs.

How the model executes is one knob — the execution policy
(``repro.ops.ExecutionPolicy``): ``--spiking --policy fused_packed`` serves
the paper-C4 QKFormer mode on the fused event kernels with bit-packed spike
state, and ``stats()`` then reports measured sparsity + packed bytes in
flight.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
                                             [--replicas 2]
                                             [--spiking]
                                             [--policy fused_packed]
"""
import argparse

import jax
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.serve import Engine, EngineConfig, ReplicaRouter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--spiking", action="store_true",
                    help="serve the paper-C4 spiking QKFormer attention "
                         "(token-local masks: O(1) decode, no KV cache)")
    ap.add_argument("--policy", default=None,
                    choices=["reference", "fused_dense", "fused_packed"],
                    help="execution policy override for this engine "
                         "(default: inherit the model config's policy)")
    args = ap.parse_args()
    if args.policy and not args.spiking:
        # the engine applies its policy to qk_spiking models only; without
        # --spiking the softmax path would silently ignore the choice
        ap.error("--policy requires --spiking (execution policies govern "
                 "the spiking qk_spiking path)")

    overrides = ({"spiking": True, "attention_kind": "qk_spiking"}
                 if args.spiking else {})
    cfg = reduced(get_config(args.arch), **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_slots=4, max_len=96, prefill_pad=16,
                        prefill_chunk=16,     # elastic chunked prefill
                        max_queue=8,          # bounded admission FIFO
                        policy=args.policy)
    if args.replicas > 1:
        eng = ReplicaRouter(model, params, ecfg, n_replicas=args.replicas)
    else:
        eng = Engine(model, params, ecfg)
    rng = np.random.default_rng(0)
    uids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        # submit blocks (runs engine ticks) if the admission FIFO is full —
        # the elastic-FIFO backpressure discipline
        uids.append(eng.submit(rng.integers(0, cfg.vocab_size, plen),
                               max_new=int(rng.integers(4, 12)),
                               temperature=0.0 if i % 2 else 0.8))
    # stream: drain per-slot output FIFOs while the engine runs
    streamed = {u: [] for u in uids}
    while eng.step() or eng.pending():
        for u in uids:
            streamed[u].extend(eng.pop_output(u))
    for u in uids:
        streamed[u].extend(eng.pop_output(u))
    for u in uids[:4]:
        print(f"req {u}: streamed {len(streamed[u])} tokens -> "
              f"{streamed[u]}")
    print("stats:", eng.stats())


if __name__ == "__main__":
    main()
