"""Continuous-batching serving demo: more requests than slots, mixed prompt
lengths, greedy + sampled decoding, engine stats — through the elastic-FIFO
pipeline: chunked prefill (one long prompt no longer stalls the live decode
slots), a bounded admission FIFO with backpressure on ``submit``, and
streaming consumption from the per-slot output FIFOs.

How the model executes is one knob — the execution policy
(``repro.ops.ExecutionPolicy``): ``--spiking --policy fused_packed`` serves
the paper-C4 QKFormer mode on the fused event kernels with bit-packed spike
state, and ``stats()`` then reports measured sparsity + packed bytes in
flight.

Self-healing knobs: ``--chaos`` replays the canned deterministic fault
plan (NaN injections + a fused-kernel fault, plus a replica kill when
``--replicas 2``) while streaming continues uninterrupted;
``--integrity-every N`` runs the numeric/packed-state guard;
``--deadline-ticks N`` bounds every request's time in the engine.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-1.7b]
                                             [--replicas 2]
                                             [--spiking]
                                             [--policy fused_packed]
                                             [--chaos] [--deadline-ticks 64]
"""
import argparse

import jax
import numpy as np

from repro.configs import build_model, get_config, reduced
from repro.serve import (Engine, EngineConfig, ReplicaRouter,
                         demo_chaos_plan)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--spiking", action="store_true",
                    help="serve the paper-C4 spiking QKFormer attention "
                         "(token-local masks: O(1) decode, no KV cache)")
    ap.add_argument("--policy", default=None,
                    choices=["reference", "fused_dense", "fused_packed"],
                    help="execution policy override for this engine "
                         "(default: inherit the model config's policy)")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="per-request deadline in engine ticks "
                         "(0 = none); late requests end 'deadline_miss'")
    ap.add_argument("--integrity-every", type=int, default=0,
                    help="integrity-guard period in decode ticks (0 = "
                         "off); poisoned slots quarantine + replay")
    ap.add_argument("--chaos", action="store_true",
                    help="replay the canned deterministic fault plan "
                         "against this trace (implies --integrity-every "
                         "1); streaming must continue uninterrupted")
    args = ap.parse_args()
    if args.policy and not args.spiking:
        # the engine applies its policy to qk_spiking models only; without
        # --spiking the softmax path would silently ignore the choice
        ap.error("--policy requires --spiking (execution policies govern "
                 "the spiking qk_spiking path)")

    overrides = ({"spiking": True, "attention_kind": "qk_spiking"}
                 if args.spiking else {})
    cfg = reduced(get_config(args.arch), **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_slots=4, max_len=96, prefill_pad=16,
                        prefill_chunk=16,     # elastic chunked prefill
                        max_queue=8,          # bounded admission FIFO
                        policy=args.policy,
                        deadline_ticks=args.deadline_ticks,
                        integrity_every=(args.integrity_every
                                         or (1 if args.chaos else 0)))
    faults = None
    if args.chaos:
        faults = demo_chaos_plan(0, n_replicas=args.replicas)
        print("chaos plan:", [e["kind"] for e in
                              faults.summary()["events"]])
    if args.replicas > 1:
        eng = ReplicaRouter(model, params, ecfg, n_replicas=args.replicas,
                            faults=faults)
    else:
        eng = Engine(model, params, ecfg, faults=faults)
    rng = np.random.default_rng(0)
    uids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        # submit blocks (runs engine ticks) if the admission FIFO is full —
        # the elastic-FIFO backpressure discipline
        uids.append(eng.submit(rng.integers(0, cfg.vocab_size, plen),
                               max_new=int(rng.integers(4, 12)),
                               temperature=0.0 if i % 2 else 0.8))
    # stream: drain per-slot output FIFOs while the engine runs
    streamed = {u: [] for u in uids}
    while eng.step() or eng.pending():
        for u in uids:
            streamed[u].extend(eng.pop_output(u))
    for u in uids:
        streamed[u].extend(eng.pop_output(u))
    for u in uids[:4]:
        print(f"req {u}: streamed {len(streamed[u])} tokens -> "
              f"{streamed[u]}")
    print("stats:", eng.stats())


if __name__ == "__main__":
    main()
