#!/usr/bin/env python
"""neurallint: the repo's static-analysis gate (CI runs this).

Two engines, one exit code:

  * the abstract contract verifier (``repro.analysis.contracts``) — walks
    every registered ``(op, mode)`` pair of the kernel registry under
    ``jax.eval_shape`` (zero FLOPs) and proves the dispatch/format/
    metadata/grad/block/VMEM contracts;
  * the AST lint (``repro.analysis.lint``) — rule-id'd source checks with
    per-line ``# neurallint: disable=RULE`` suppressions.

Usage:
  python tools/neurallint.py                 # both engines, repo scan
  python tools/neurallint.py --rules         # print the rule catalog
  python tools/neurallint.py --lint-only --paths src/repro/ops
  python tools/neurallint.py --select NL-LEGACY-FLAGS,NL-LEGACY-FORKS
  python tools/neurallint.py --junit out.xml # also write a junit report

Exit status: 0 clean, 1 unsuppressed findings, 2 usage/internal error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main(argv=None) -> int:
    from repro.analysis import RULES, junit_xml, lint_paths, render, \
        verify_contracts

    ap = argparse.ArgumentParser(prog="neurallint",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--lint-only", action="store_true",
                    help="engine 2 only (skip the contract sweep)")
    ap.add_argument("--contracts-only", action="store_true",
                    help="engine 1 only (skip the AST lint)")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/dirs for the AST lint (default: repo scan)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to report (default: all)")
    ap.add_argument("--junit", default=None, metavar="FILE",
                    help="write a junit XML report (the CI artifact)")
    args = ap.parse_args(argv)

    if args.rules:
        for rule, desc in RULES.items():
            print(f"{rule}\n    {desc}")
        return 0
    if args.lint_only and args.contracts_only:
        ap.error("--lint-only and --contracts-only are mutually exclusive")

    selected = None
    if args.select:
        selected = {r.strip() for r in args.select.split(",") if r.strip()}
        unknown = selected - set(RULES)
        if unknown:
            ap.error(f"unknown rule id(s): {sorted(unknown)}")

    findings, checked = [], 0
    if not args.contracts_only:
        lint_findings, checked = lint_paths(args.paths, root=REPO)
        findings += lint_findings
        print(f"neurallint: AST lint over {checked} file(s)")
    if not args.lint_only:
        report = verify_contracts()
        findings += report.findings
        checked += report.cells
        print(f"neurallint: contract sweep — "
              f"{len(report.coverage)}/{len(report.registered)} registered "
              f"(op, mode) pairs covered in {report.cells} cells "
              f"({report.duration_s:.1f}s, eval_shape only)")
        if report.uncovered:
            # uncovered pairs already produced NL-DISPATCH-TOTALITY findings
            print(f"neurallint: UNCOVERED: {sorted(report.uncovered)}")

    if selected is not None:
        findings = [f for f in findings if f.rule in selected]
    findings.sort(key=lambda f: (f.rule, f.path, f.line))

    if args.junit:
        Path(args.junit).write_text(junit_xml(findings, checked=checked),
                                    encoding="utf-8")
        print(f"neurallint: junit report -> {args.junit}")
    print(render(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
