#!/usr/bin/env python
"""DEPRECATED shim: the legacy-surface guards moved into neurallint.

The two checks this script used to run are now the ``NL-LEGACY-FLAGS`` and
``NL-LEGACY-FORKS`` rules of ``tools/neurallint.py`` (engine 2), with the
same patterns and allowlists. This entry point stays for muscle memory and
old CI configs; it simply invokes those two rules and forwards the exit
code.

Usage: python tools/check_no_legacy_flags.py  (exit 0 = clean)
"""
from __future__ import annotations

import sys

from neurallint import main as neurallint_main

if __name__ == "__main__":
    print("note: check_no_legacy_flags.py is now a shim over "
          "`tools/neurallint.py --select NL-LEGACY-FLAGS,NL-LEGACY-FORKS`")
    sys.exit(neurallint_main(
        ["--lint-only", "--select", "NL-LEGACY-FLAGS,NL-LEGACY-FORKS"]))
