#!/usr/bin/env python
"""CI guard: the legacy flag kwargs must not reappear outside the shim.

The ``repro.ops`` redesign replaced the ``use_event_kernels=`` /
``spike_format=`` / ``pack_out=`` plumbing with ``ExecutionPolicy``; the
only sanctioned home of those kwarg spellings is the deprecation shim
module (``src/repro/ops/compat.py``) and the test suite (which exercises
the shims on purpose). This script greps the code tree for call-site uses
of the legacy kwargs — the pattern matches ``flag=value`` (PEP8 keyword
arguments carry no spaces around ``=``), so annotated parameter
declarations like ``pack_out: bool | None = None`` that merely ACCEPT the
deprecated kwarg do not trip it — and fails the build on any hit.

Usage: python tools/check_no_legacy_flags.py  (exit 0 = clean)
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "docs")
ALLOWED = {
    REPO / "src" / "repro" / "ops" / "compat.py",   # THE deprecation shim
    REPO / "docs" / "ops_api.md",                   # the migration table
}
# call-site kwarg spelling: name immediately followed by '=' but not '=='
PATTERN = re.compile(r"\b(use_event_kernels|spike_format|pack_out)=(?!=)")


def main() -> int:
    hits: list[str] = []
    targets = [p for d in SCAN_DIRS if (REPO / d).exists()
               for p in sorted((REPO / d).rglob("*"))]
    targets.append(REPO / "README.md")
    for path in targets:
        if path.suffix not in (".py", ".md") or path in ALLOWED:
            continue
        for ln, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            if PATTERN.search(line):
                hits.append(f"{path.relative_to(REPO)}:{ln}: "
                            f"{line.strip()}")
    if hits:
        print("legacy flag kwargs found outside the deprecation shim "
              "(use policy= / out_format= instead):")
        print("\n".join(hits))
        return 1
    print(f"OK: no legacy flag call sites outside the shim "
          f"({', '.join(SCAN_DIRS)} scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
