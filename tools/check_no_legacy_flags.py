#!/usr/bin/env python
"""CI guard: deleted legacy APIs must not reappear outside their shims.

Two generations of legacy surface are guarded:

  * the pre-policy FLAG kwargs (``use_event_kernels=`` / ``spike_format=``
    / ``pack_out=``), replaced by ``ExecutionPolicy``; their only
    sanctioned home is the deprecation shim module
    (``src/repro/ops/compat.py``) and the test suite (which exercises the
    shims on purpose). The pattern matches ``flag=value`` (PEP8 keyword
    arguments carry no spaces around ``=``), so annotated parameter
    declarations like ``pack_out: bool | None = None`` that merely ACCEPT
    the deprecated kwarg do not trip it.
  * the pre-unification SNN-CNN forward FORKS (``_apply_fused_event``,
    ``_apply_fused_reference``, and the standalone ``snn_cnn.apply`` /
    ``snn_cnn.apply_fused`` pair), collapsed into the ONE policy-driven
    ``snn_cnn.forward`` body. Any call site (or re-definition) of the old
    names fails the build — the train/deploy fork must not grow back.

Usage: python tools/check_no_legacy_flags.py  (exit 0 = clean)
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "examples", "docs")
ALLOWED = {
    REPO / "src" / "repro" / "ops" / "compat.py",   # THE deprecation shim
    REPO / "docs" / "ops_api.md",                   # the migration table
}
# call-site kwarg spelling: name immediately followed by '=' but not '=='
PATTERN = re.compile(r"\b(use_event_kernels|spike_format|pack_out)=(?!=)")
# deleted snn_cnn forward forks: neither definitions nor call sites may
# come back anywhere (docs included — only this guard's own description
# and the migration notes name them)
FORK_PATTERN = re.compile(
    r"_apply_fused_event|_apply_fused_reference"
    r"|snn_cnn\.apply(?:_fused)?\s*\(")
FORK_ALLOWED = {
    REPO / "docs" / "training_framework.md",        # the migration notes
}


def main() -> int:
    hits: list[str] = []
    targets = [p for d in SCAN_DIRS if (REPO / d).exists()
               for p in sorted((REPO / d).rglob("*"))]
    targets.append(REPO / "README.md")
    for path in targets:
        if path.suffix not in (".py", ".md"):
            continue
        text = path.read_text(encoding="utf-8")
        for ln, line in enumerate(text.splitlines(), 1):
            if path not in ALLOWED and PATTERN.search(line):
                hits.append(f"{path.relative_to(REPO)}:{ln}: "
                            f"{line.strip()}")
            if path not in FORK_ALLOWED and FORK_PATTERN.search(line):
                hits.append(f"{path.relative_to(REPO)}:{ln}: "
                            f"[deleted forward fork] {line.strip()}")
    if hits:
        print("legacy API uses found outside the sanctioned shims "
              "(use policy= / out_format= / snn_cnn.forward instead):")
        print("\n".join(hits))
        return 1
    print(f"OK: no legacy flag call sites or deleted forward forks "
          f"({', '.join(SCAN_DIRS)} scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
