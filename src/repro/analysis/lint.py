"""Engine 2: project AST lint.

Seven rules over the project source (see ``findings.RULES`` for the
catalog). Python files get the AST rules plus the legacy-surface regex
rules; markdown/docs get the regex rules only (the legacy guards police
prose and examples too — that is where deleted APIs sneak back in).

Suppression: append ``# neurallint: disable=RULE`` (comma-separate for
several) to the flagged line — or put it alone on the line above for lines
with no room. Suppressions are per-line and per-rule; there is no
file-level opt-out, allowlists for the few structurally-exempt files live
in ``_PATH_EXEMPT`` below.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, Optional

from .findings import Finding

# which top-level entries a default repo scan walks (mirrors the legacy
# flag-guard's surface, plus tools/)
DEFAULT_SCAN = ("src", "benchmarks", "examples", "docs", "tools",
                "README.md")

_SUPPRESS_RE = re.compile(r"#\s*neurallint:\s*disable=([A-Z0-9-,\s]+)")

# -- the two legacy-surface regex rules (absorbed from the retired
#    tools/check_no_legacy_flags.py) --
_LEGACY_FLAGS_RE = re.compile(
    r"\b(use_event_kernels|spike_format|pack_out)=(?!=)")  # neurallint: disable=NL-LEGACY-FLAGS
_LEGACY_FORKS_RE = re.compile(
    r"_apply_fused_event|_apply_fused_reference"            # neurallint: disable=NL-LEGACY-FORKS
    r"|snn_cnn\.apply(?:_fused)?\s*\(")

#: rule -> path substrings that are structurally exempt (the compat shim
#: DOCUMENTS the legacy kwargs; ops/kernels ARE the registry; etc.)
_PATH_EXEMPT = {
    "NL-LEGACY-FLAGS": ("repro/ops/compat.py", "docs/ops_api.md",
                        "repro/analysis/", "tools/neurallint.py",
                        "tools/check_no_legacy_flags.py",
                        "docs/static_analysis.md"),
    "NL-LEGACY-FORKS": ("docs/training_framework.md", "repro/analysis/",
                        "tools/neurallint.py",
                        "tools/check_no_legacy_flags.py",
                        "docs/static_analysis.md"),
    # call sites must route through repro.ops — but the registry layers
    # themselves, the analysis pass, and the contract module are the
    # legitimate importers
    "NL-REGISTRY-BYPASS": ("repro/ops/", "repro/kernels/",
                           "repro/analysis/"),
    # Pallas kernel interiors compute inference Heavisides legitimately —
    # the rule polices the differentiable (jnp) surface
    "NL-BARE-HEAVISIDE": ("repro/kernels/",),
}


def _exempt(rule: str, path: str) -> bool:
    p = path.replace("\\", "/")
    return any(frag in p for frag in _PATH_EXEMPT.get(rule, ()))


def _suppressed(lines: list, lineno: int) -> set:
    """Rules suppressed at 1-indexed ``lineno`` (same line or the line
    above when that line holds only the directive)."""
    out: set = set()
    for ln in (lineno, lineno - 1):
        if not 1 <= ln <= len(lines):
            continue
        m = _SUPPRESS_RE.search(lines[ln - 1])
        if m and (ln == lineno or lines[ln - 1].lstrip().startswith("#")):
            out.update(r.strip() for r in m.group(1).split(","))
    return out


# ------------------------------------------------------------- regex rules
def _lint_text(src: str, path: str) -> list:
    findings = []
    lines = src.splitlines()
    for rule, rx in (("NL-LEGACY-FLAGS", _LEGACY_FLAGS_RE),
                     ("NL-LEGACY-FORKS", _LEGACY_FORKS_RE)):
        if _exempt(rule, path):
            continue
        for i, line in enumerate(lines, 1):
            if rx.search(line) and rule not in _suppressed(lines, i):
                findings.append(Finding(
                    rule, path, i,
                    f"legacy surface reintroduced: {line.strip()[:80]!r}"))
    return findings


# --------------------------------------------------------------- AST rules
def _is_jit_decorator(dec: ast.expr) -> bool:
    """@jax.jit / @jit / @functools.partial(jax.jit, ...) / @partial(jit)"""
    def _name(e):
        if isinstance(e, ast.Attribute):
            return e.attr
        if isinstance(e, ast.Name):
            return e.id
        return ""
    if _name(dec) == "jit":
        return True
    if isinstance(dec, ast.Call):
        if _name(dec.func) == "jit":
            return True
        if _name(dec.func) == "partial" and dec.args \
                and _name(dec.args[0]) == "jit":
            return True
    return False


def _dotted(e: ast.expr) -> str:
    if isinstance(e, ast.Name):
        return e.id
    if isinstance(e, ast.Attribute):
        return f"{_dotted(e.value)}.{e.attr}"
    return ""


_HOST_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "onp.asarray", "onp.array",
                    "jax.device_get"}
_TICK_NAMES = ("tick", "route", "step_tick")


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, lines: list):
        self.path, self.lines = path, lines
        self.findings: list = []
        self._traced_depth = 0        # inside a jit / tick / route body

    def _emit(self, rule: str, node: ast.AST, msg: str):
        if _exempt(rule, self.path):
            return
        line = getattr(node, "lineno", 0)
        if rule in _suppressed(self.lines, line):
            return
        self.findings.append(Finding(rule, self.path, line, msg))

    # -- imports: NL-REGISTRY-BYPASS --
    def _check_kernel_import(self, node, modname: str):
        mod = modname or ""
        if "kernels" not in mod.split("."):
            return
        # the contract module is declaration-only data (no Pallas)
        if mod.endswith("kernels.contract") or mod.endswith(
                "kernels") and any(
                a.name == "contract" for a in getattr(node, "names", [])):
            return
        self._emit(
            "NL-REGISTRY-BYPASS", node,
            f"import of {mod!r} bypasses the policy registry — call "
            f"through repro.ops so dispatch, fallback, and autotuning "
            f"stay in the loop")

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self._check_kernel_import(node, a.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = ("." * node.level) + (node.module or "")
        self._check_kernel_import(node, mod)
        self.generic_visit(node)

    # -- function defs: jit scope, mutable defaults, interpret defaults --
    def _visit_fn(self, node):
        for default in list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(default, ast.Call)
                    and _dotted(default.func) in ("list", "dict", "set")):
                self._emit(
                    "NL-MUTABLE-DEFAULT", default,
                    f"mutable default in {node.name}() signature — one "
                    f"shared instance across every call (and every pytree "
                    f"built from it)")
        kwonly = zip(node.args.kwonlyargs, node.args.kw_defaults)
        for arg, default in list(zip(reversed(node.args.args),
                                     reversed(node.args.defaults))
                                 ) + list(kwonly):
            if arg.arg == "interpret" and isinstance(default, ast.Constant) \
                    and default.value is True:
                self._emit(
                    "NL-INTERPRET-HARDCODE", default,
                    f"{node.name}() defaults interpret=True — interpret "
                    f"mode must stay backend-derived (None) outside tests")
        traced = (any(_is_jit_decorator(d) for d in node.decorator_list)
                  or node.name in _TICK_NAMES)
        self._traced_depth += traced
        self.generic_visit(node)
        self._traced_depth -= traced

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_ClassDef(self, node: ast.ClassDef):
        for stmt in node.body:
            value = getattr(stmt, "value", None)
            if isinstance(stmt, (ast.AnnAssign, ast.Assign)) and isinstance(
                    value, (ast.List, ast.Dict, ast.Set)):
                self._emit(
                    "NL-MUTABLE-DEFAULT", stmt,
                    f"mutable class-level default in {node.name} — use "
                    f"dataclasses.field(default_factory=...)")
        self.generic_visit(node)

    # -- calls: host sync, bare Heaviside, interpret=True at call sites --
    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if self._traced_depth:
            is_item = (isinstance(node.func, ast.Attribute)
                       and node.func.attr == "item" and not node.args)
            is_float = (name == "float" and node.args and not isinstance(
                node.args[0], ast.Constant))
            if is_item or is_float or name in _HOST_SYNC_CALLS:
                self._emit(
                    "NL-HOST-SYNC", node,
                    f"{name or '.item'}() inside a traced/per-tick "
                    f"function forces a device->host sync every call")
        if name == "jnp.heaviside" or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype"
                and isinstance(node.func.value, ast.Compare)
                # only > / >= — a `< rate` cast is a random mask, not a
                # membrane threshold
                and all(isinstance(o, (ast.Gt, ast.GtE))
                        for o in node.func.value.ops)):
            self._emit(
                "NL-BARE-HEAVISIDE", node,
                "bare Heaviside (comparison cast) — use "
                "core.surrogate.spike so the registered pseudo-derivative "
                "flows under +grad policies")
        for kw in node.keywords:
            if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is True:
                self._emit(
                    "NL-INTERPRET-HARDCODE", kw.value,
                    f"interpret=True hardcoded at a {name or 'call'}() "
                    f"site — pass None and let the backend decide")
        self.generic_visit(node)


def lint_source(src: str, path: str = "<string>") -> list:
    """Lint one Python source string. Returns findings (suppressions and
    path exemptions already applied)."""
    findings = _lint_text(src, path)
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return findings + [Finding(
            "NL-REGISTRY-BYPASS", path, e.lineno or 0,
            f"unparseable Python (lint skipped): {e.msg}")]
    v = _Visitor(path, src.splitlines())
    v.visit(tree)
    return findings + v.findings


def lint_paths(paths: Optional[Iterable] = None,
               root: Optional[Path] = None) -> tuple:
    """Lint files/dirs (default: ``DEFAULT_SCAN`` under ``root``). Python
    files get AST + regex rules; .md files regex rules only. Test files are
    out of scope (fixtures legitimately contain every bad pattern).
    Returns (findings, files_checked)."""
    root = Path(root) if root else Path.cwd()
    targets = [Path(p) for p in paths] if paths else \
        [root / p for p in DEFAULT_SCAN]
    files: list = []
    for t in targets:
        if t.is_dir():
            files += sorted(t.rglob("*.py")) + sorted(t.rglob("*.md"))
        elif t.exists():
            files.append(t)
    findings, checked = [], 0
    for f in files:
        rel = str(f.relative_to(root) if f.is_absolute() and root in
                  f.parents else f)
        if "tests/" in rel.replace("\\", "/") or \
                f.name.startswith("test_"):
            continue
        checked += 1
        src = f.read_text(encoding="utf-8")
        if f.suffix == ".py":
            findings += lint_source(src, rel)
        else:
            findings += _lint_text(src, rel)
    return findings, checked
