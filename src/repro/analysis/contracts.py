"""Engine 1: the abstract kernel-contract verifier.

Walks the whole ``(op, mode)`` registry — all seven kernel families ×
reference / fused × dense / packed × byte-skip strategies × ±grad × head
configurations — and, via ``jax.eval_shape`` over the declared edge-shape
corpus (``repro.analysis.abstract.EDGE_SHAPES``), proves with ZERO FLOPs:

  * NL-DISPATCH-TOTALITY — every advertised execution point resolves (and
    the sweep itself covers 100% of the registered pairs: an implementation
    nobody can drive is a coverage gap, reported, not ignored);
  * NL-SILENT-DOWNGRADE — the executed registry modes match the requested
    policy's kernel axis (the generalization of PR 8's
    ``record_dispatches`` check to every op);
  * NL-FORMAT-PRESERVE — spike outputs leave in the policy's format with
    the contracted dtypes;
  * NL-META-PROP — every packed output carries a shape-consistent
    ``vld_cnt`` block map (and dense outputs that carry one are grid-true);
  * NL-GRAD-COVERAGE — every op on a grad-declaring family registers both
    ``+grad`` modes;
  * NL-BLOCK-CONTRACT — the packed block-shape contract is satisfiable on
    the corpus AND its runtime guard rejects mismatched tilings;
  * NL-VMEM-BUDGET — each family's declared BlockSpec residency model fits
    ``launch.roofline.VMEM_BYTES``.

Everything runs under abstract evaluation: no kernel launches, no
compilation, CPU-safe, seconds not minutes — which is what lets CI prove
the contracts over the whole registry before anything runs on hardware.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Iterator, Optional

import jax.numpy as jnp

from ..core.events import DEFAULT_BLOCKS
from ..core.lif import LIFConfig
from ..kernels.contract import KernelContract, kernel_contracts
from .abstract import (EDGE_SHAPES, HEAD_CONFIGS, AbstractEvalError,
                       abstract_eval, packed_grid, sds, spike_aval)
from .findings import Finding

GRAD_SUFFIX = "+grad"

#: the policy points the sweep drives (preset name -> (kernels, format)).
#: "auto" is excluded by design: it is a *pricing* layer that resolves to
#: one of these points per concrete call — its bit-identity to the chosen
#: point is covered by tests/test_sparsity_adaptive.py at runtime.
POLICY_POINTS = {
    "reference": ("reference", "dense"),
    "reference_packed": ("reference", "packed"),
    "fused_dense": ("fused", "dense"),
    "fused_packed": ("fused", "packed"),
}


@dataclasses.dataclass
class Cell:
    """One sweep point: an op under one policy/config on one corpus shape."""
    op: str
    mode: str                 # the registry mode the policy requests
    kernels: str              # the policy's kernel axis
    fmt: str
    label: str
    thunk: Callable           # () -> output avals (runs under eval_shape)
    check: Optional[Callable] = None   # (out) -> list[str] extra violations


@dataclasses.dataclass
class ContractReport:
    findings: list
    coverage: set             # (op, mode) pairs the sweep dispatched
    registered: set           # (op, mode) pairs in the registry
    cells: int
    duration_s: float

    @property
    def uncovered(self) -> set:
        return self.registered - self.coverage


def _pol(name: str, grad: bool):
    from ..ops.policy import ExecutionPolicy

    kernels, fmt = POLICY_POINTS[name]
    return ExecutionPolicy(kernels, fmt, differentiable=grad)


def _vld_ok(vld, lead: tuple, m: int, n: int, bm: int, bk: int) -> bool:
    _, _, _, gm, gn = packed_grid(m, n, block_m=bm, block_k=bk)
    return vld is not None and tuple(vld.shape) == (*lead, gm, gn)


def _check_spike_out(st, pol, m: int, n: int, lead: tuple = ()) -> list:
    """Format/dtype preservation + metadata propagation on one emitted
    SpikeTensor. Returns (format_violations, meta_violations)."""
    fmt_bad, meta_bad = [], []
    if pol.differentiable:
        # differentiable outputs are dense f32 for autodiff connectivity
        if st.is_packed or st.data.dtype != jnp.float32:
            fmt_bad.append(f"+grad output must be dense f32, got "
                           f"{st.fmt}/{st.data.dtype}")
        return fmt_bad, meta_bad
    if st.fmt != pol.format:
        fmt_bad.append(f"policy format {pol.format!r} but output left "
                       f"{st.fmt!r}")
        return fmt_bad, meta_bad
    if st.is_packed:
        if st.data.dtype != jnp.int32:
            fmt_bad.append(f"packed words must be int32, got "
                           f"{st.data.dtype}")
        mp, _, words, _, _ = packed_grid(m, n, block_m=st.block_m,
                                         block_k=st.block_k)
        if tuple(st.data.shape) != (*lead, mp, words):
            meta_bad.append(f"packed words shape {tuple(st.data.shape)} != "
                            f"padded grid {(*lead, mp, words)}")
        if not _vld_ok(st.vld_cnt, lead, m, n, st.block_m, st.block_k):
            meta_bad.append(
                f"packed output must carry a vld_cnt map on its "
                f"(block_m={st.block_m}, block_k={st.block_k}) grid; got "
                f"{None if st.vld_cnt is None else tuple(st.vld_cnt.shape)}")
    elif st.vld_cnt is not None and not _vld_ok(st.vld_cnt, lead, m, n,
                                               st.block_m, st.block_k):
        meta_bad.append(f"dense output's vld_cnt grid "
                        f"{tuple(st.vld_cnt.shape)} inconsistent with "
                        f"[{m}, {n}] on its declared blocks")
    return fmt_bad, meta_bad


# ------------------------------------------------------------------ drivers
def _skips_for(pol, contract: KernelContract) -> tuple:
    if pol.kernels != "fused" or pol.differentiable:
        return ("dense",)
    return contract.skips


def _matmul_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    shapes = EDGE_SHAPES if not grad else EDGE_SHAPES[::2]
    for (m, k, n) in shapes:
        for skip in _skips_for(pol, contract):
            st = spike_aval(m, k, pol.format)
            w = sds((k, n))
            yield Cell(
                "matmul", pol.mode, pol.kernels, pol.format,
                f"matmul[{m}x{k}x{n}] skip={skip}",
                functools.partial(
                    abstract_eval, ops.matmul, st, w, policy=pol, skip=skip,
                    what=f"matmul({pol.name}, skip={skip})"),
                lambda out, m=m, n=n: (
                    [] if (tuple(out.shape) == (m, n)
                           and out.dtype == jnp.float32)
                    else [f"matmul must emit f32 [{m}, {n}] current, got "
                          f"{out.dtype}{tuple(out.shape)}"], []))


def _lif_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    m, _, n = EDGE_SHAPES[-1]
    yield Cell(
        "lif", pol.mode, pol.kernels, "dense", f"lif[{m}x{n}]",
        functools.partial(
            abstract_eval, ops.lif, sds((m, n)), sds((m, n)),
            sds((m, n), jnp.int8), policy=pol, what=f"lif({pol.name})"),
        lambda out, m=m, n=n: (
            [] if (tuple(out[0].shape) == (m, n)
                   and tuple(out[1].shape) == (m, n)
                   and out[1].dtype == jnp.float32)
            else ["lif must return (spikes, v_next f32) at the input "
                  "shape"], []))


def _fused_pe_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    lif_cfg = LIFConfig()
    shapes = EDGE_SHAPES if not grad else EDGE_SHAPES[::2]
    for (m, k, n) in shapes:
        for heads, _ in HEAD_CONFIGS:
            if heads is not None and n % heads:
                continue
            hcfg = None if heads is None else (heads, n // heads)
            for skip in _skips_for(pol, contract):
                if skip != "dense" and hcfg is not None:
                    continue          # keep the sweep quadratic, not cubic
                st = spike_aval(m, k, pol.format)
                q = spike_aval(m, n, pol.format)
                res = (spike_aval(m, n, pol.format,
                                  block_k=DEFAULT_BLOCKS.n)
                       if pol.format == "packed" else sds((m, n)))
                yield Cell(
                    "fused_pe", pol.mode, pol.kernels, pol.format,
                    f"fused_pe[{m}x{k}x{n}] heads={hcfg} skip={skip}",
                    functools.partial(
                        abstract_eval, ops.fused_pe, st, sds((k, n)),
                        bias=sds((n,)), residual=res, q=q,
                        lif_cfg=lif_cfg, policy=pol, skip=skip, heads=hcfg,
                        what=f"fused_pe({pol.name}, heads={hcfg}, "
                             f"skip={skip})"),
                    lambda out, m=m, n=n: _check_spike_out(
                        out.spikes, pol, m, n))


def _fused_pe_layer_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    lif_cfg = LIFConfig()
    m, k, n = EDGE_SHAPES[-1]
    for t in (1, 2):
        for heads, _ in ((None, None), (2, 2)):
            if heads is not None and n % heads:
                continue
            hcfg = None if heads is None else (heads, n // heads)
            st = spike_aval(m, k, pol.format, lead=(t,))
            q = spike_aval(m, n, pol.format, lead=(t,))
            yield Cell(
                "fused_pe_layer", pol.mode, pol.kernels, pol.format,
                f"fused_pe_layer[T={t},{m}x{k}x{n}] heads={hcfg}",
                functools.partial(
                    abstract_eval, ops.fused_pe_layer, st, sds((k, n)),
                    q=q, lif_cfg=lif_cfg, policy=pol, heads=hcfg,
                    what=f"fused_pe_layer({pol.name}, T={t}, "
                         f"heads={hcfg})"),
                lambda out, m=m, n=n, t=t: _check_spike_out(
                    out.spikes, pol, m, n, lead=(t,)))


def _dense_lif_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    lif_cfg = LIFConfig()
    m, k, n = EDGE_SHAPES[-1]
    for heads, kv in HEAD_CONFIGS:
        if heads is not None and n % heads:
            continue
        hcfg = None if heads is None else (heads, n // heads)
        wcols = n if kv in (None, heads) else kv * (n // heads)
        p = {"w": sds((k, wcols)), "b": sds((wcols,))}
        q = spike_aval(m, n, pol.format)
        yield Cell(
            "dense_lif", pol.mode, pol.kernels, pol.format,
            f"dense_lif[{m}x{k}x{n}] heads={hcfg} kv={kv}",
            functools.partial(
                abstract_eval, ops.dense_lif, p, sds((m, k)), lif_cfg,
                q=q, heads=hcfg, kv_heads=kv, policy=pol,
                what=f"dense_lif({pol.name}, heads={hcfg}, kv={kv})"),
            lambda out, m=m, n=n: _check_spike_out(out, pol, m, n))


def _qk_mask_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    for (m, k, _) in EDGE_SHAPES[1:]:
        q = spike_aval(m, k, pol.format)
        ks = spike_aval(m, k, pol.format)
        yield Cell(
            "qk_mask", pol.mode if grad else pol.kernels, pol.kernels,
            pol.format, f"qk_mask[{m}x{k}]",
            functools.partial(abstract_eval, ops.qk_mask, q, ks, policy=pol,
                              what=f"qk_mask({pol.name})"),
            lambda out, m=m, k=k: _check_spike_out(out, pol, m, k))


def _pack_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    if pol.format == "packed":
        return                # pack/unpack dispatch on kernels only —
                              # the dense presets already cover both modes
    m, k, _ = EDGE_SHAPES[-1]
    dense = spike_aval(m, k, "dense")
    packed = spike_aval(m, k, "packed")
    yield Cell(
        "pack", pol.kernels, pol.kernels, "packed", f"pack[{m}x{k}]",
        functools.partial(
            abstract_eval, ops.pack, dense,
            policy=dataclasses.replace(pol, format="packed"),
            what=f"pack({pol.kernels})"),
        lambda out, m=m, k=k: _check_spike_out(
            out, _pol("fused_packed", False), m, k))
    yield Cell(
        "unpack", pol.kernels, pol.kernels, "dense", f"unpack[{m}x{k}]",
        functools.partial(abstract_eval, ops.unpack, packed, policy=pol,
                          what=f"unpack({pol.kernels})"),
        lambda out, m=m, k=k: (
            [] if (tuple(out.shape) == (m, k) and out.dtype == jnp.int8)
            else [f"unpack must emit int8 [{m}, {k}], got "
                  f"{out.dtype}{tuple(out.shape)}"], []))


def _spatial_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    b, h, w, c = 2, 8, 8, 24          # ragged channel count (pad lanes)
    spatial = (b, h, w, c)
    st = spike_aval(b * h * w, c, pol.format, lead=(1,))
    yield Cell(
        "im2col", pol.mode, pol.kernels, pol.format, f"im2col{spatial}",
        functools.partial(abstract_eval, ops.im2col, st, spatial, 3, 3, 1,
                          t=1, policy=pol, what=f"im2col({pol.name})"),
        lambda out: _check_spike_out(out[0], pol, *out[0].shape[-2:],
                                     lead=out[0].shape[:-2]))
    yield Cell(
        "pool", pol.mode, pol.kernels, pol.format, f"pool{spatial}",
        functools.partial(abstract_eval, ops.pool, st, spatial, t=1,
                          window=2, policy=pol, what=f"pool({pol.name})"),
        lambda out: _check_spike_out(out[0], pol, *out[0].shape[-2:],
                                     lead=out[0].shape[:-2]))


def _attention_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    for (b, s, h, d) in ((1, 16, 2, 8), (2, 24, 4, 16)):
        yield Cell(
            "attention", pol.kernels, pol.kernels, "dense",
            f"attention[b{b} s{s} h{h} d{d}]",
            functools.partial(
                abstract_eval, ops.attention, sds((b, s, h, d)),
                sds((b, s, h, d)), sds((b, s, h, d)), q_block=s,
                kv_block=s, policy=pol,
                what=f"attention({pol.kernels}, s={s})"),
            lambda out, b=b, s=s, h=h, d=d: (
                [] if tuple(out.shape) == (b, s, h, d)
                else [f"attention output {tuple(out.shape)} != "
                      f"{(b, s, h, d)}"], []))


def _w2ttfs_cells(contract, pol, grad: bool) -> Iterator[Cell]:
    from .. import ops

    b, h, w, c, classes, window = 4, 8, 8, 16, 10, 2
    fc_w = sds(((h // window) * (w // window) * c, classes))
    yield Cell(
        "w2ttfs_head", pol.mode, pol.kernels, "dense",
        f"w2ttfs_head[{b}x{h}x{w}x{c}]",
        functools.partial(
            abstract_eval, ops.w2ttfs_head, sds((b, h, w, c), jnp.int8),
            fc_w, sds((classes,)), window=window, policy=pol,
            what=f"w2ttfs_head({pol.name})"),
        lambda out, b=b, classes=classes: (
            [] if tuple(out.shape) == (b, classes)
            else [f"w2ttfs_head logits {tuple(out.shape)} != "
                  f"{(b, classes)}"], []))


_DRIVERS = {
    "matmul": _matmul_cells,
    "lif": _lif_cells,
    "fused_pe": _fused_pe_cells,
    "fused_pe_layer": _fused_pe_layer_cells,
    "dense_lif": _dense_lif_cells,
    "qk_mask": _qk_mask_cells,
    "pack": _pack_cells,               # also drives "unpack"
    "im2col": _spatial_cells,          # also drives "pool"
    "attention": _attention_cells,
    "w2ttfs_head": _w2ttfs_cells,
}


def _iter_cells(contracts: dict, only_ops: Optional[set]) -> Iterator[Cell]:
    for fam, contract in contracts.items():
        for op in contract.ops:
            driver = _DRIVERS.get(op)
            if driver is None:
                continue              # secondary op of a shared driver
            if only_ops is not None and op not in only_ops:
                continue
            for preset, (kernels, fmt) in POLICY_POINTS.items():
                if fmt == "packed" and "packed" not in contract.formats:
                    continue
                grads = ((False, True)
                         if op in contract.gradient_ops() else (False,))
                for grad in grads:
                    yield from driver(contract, _pol(preset, grad), grad)


# --------------------------------------------------------- one-off checks
def _grad_coverage(contracts: dict, impls: dict) -> list:
    bad = []
    for fam, contract in contracts.items():
        for op in contract.gradient_ops():
            for mode in ("reference+grad", "fused+grad"):
                if (op, mode) not in impls:
                    bad.append(Finding(
                        "NL-GRAD-COVERAGE", "<registry>", 0,
                        f"family {fam!r} declares op {op!r} differentiable "
                        f"but ({op!r}, {mode!r}) is not registered — the "
                        f"+grad-reachable path has no vjp"))
    return bad


def _vmem_budget(contracts: dict) -> list:
    from ..launch.roofline import VMEM_BYTES

    bad = []
    b = DEFAULT_BLOCKS
    for fam, contract in contracts.items():
        if contract.vmem_bytes is None:
            continue
        for packed in ((False, True) if "packed" in contract.formats
                       else (False,)):
            modeled = contract.vmem_bytes(b.m, b.n, b.k, packed)
            if modeled > VMEM_BYTES:
                bad.append(Finding(
                    "NL-VMEM-BUDGET", "<registry>", 0,
                    f"{fam} at blocks ({b.m},{b.n},{b.k}) "
                    f"packed={packed} models {modeled / 2**20:.1f} MiB "
                    f"resident > VMEM budget "
                    f"{VMEM_BYTES / 2**20:.0f} MiB"))
    return bad


def _block_contract_guard() -> list:
    """The packed block-shape contract must be ENFORCED: dispatching a
    tensor packed on one grid into a kernel tiling another must raise, not
    silently misroute on a garbage vld map."""
    from .. import ops

    bad = []
    st64 = spike_aval(128, 128, "packed", block_m=64, block_k=128)
    try:
        abstract_eval(ops.matmul, st64, sds((128, 72)),
                      policy="fused_packed", what="block-contract probe")
        bad.append(Finding(
            "NL-BLOCK-CONTRACT", "<registry>", 0,
            "a tensor packed on block_m=64 dispatched into the default "
            "128-tiling did NOT raise — check_block_contract guard is "
            "missing or bypassed"))
    except AbstractEvalError as e:
        if not isinstance(e.cause, ValueError):
            bad.append(Finding(
                "NL-BLOCK-CONTRACT", "<registry>", 0,
                f"block-shape mismatch must raise ValueError naming both "
                f"tilings, got {type(e.cause).__name__}: {e.cause}"))
    return bad


# ----------------------------------------------------------------- the sweep
def verify_contracts(only_ops: Optional[set] = None) -> ContractReport:
    """Run the registry-wide abstract sweep. ``only_ops`` restricts to a
    subset of entry-point names (test hooks); the default sweeps every
    registered pair and reports any it could not cover."""
    from ..ops import fallback
    from ..ops.registry import implementations, record_dispatches

    t0 = time.time()
    contracts = kernel_contracts()
    impls = implementations()
    registered = set(impls)
    findings: list = []
    coverage: set = set()
    cells = 0
    demoted_before = len(fallback.demotions())

    for cell in _iter_cells(contracts, only_ops):
        cells += 1
        with record_dispatches() as log:
            try:
                out = cell.thunk()
            except AbstractEvalError as e:
                # a ValueError is the block/shape-contract guard firing on
                # a shape the surface advertises; anything else means the
                # advertised (op, policy) point simply does not resolve
                rule = ("NL-BLOCK-CONTRACT"
                        if isinstance(e.cause, ValueError)
                        else "NL-DISPATCH-TOTALITY")
                findings.append(Finding(rule, "<registry>", 0,
                                        f"{cell.label}: {e}"))
                coverage.update(log)
                continue
        coverage.update(log)
        for rop, rmode in log:
            base = rmode[:-len(GRAD_SUFFIX)] \
                if rmode.endswith(GRAD_SUFFIX) else rmode
            if base != cell.kernels:
                findings.append(Finding(
                    "NL-SILENT-DOWNGRADE", "<registry>", 0,
                    f"{cell.label}: policy requested kernels="
                    f"{cell.kernels!r} but the dispatch resolved "
                    f"({rop!r}, {rmode!r}) — a silent "
                    f"{cell.kernels}->{base} downgrade"))
        if cell.check is not None:
            fmt_bad, meta_bad = cell.check(out)
            findings += [Finding("NL-FORMAT-PRESERVE", "<registry>", 0,
                                 f"{cell.label}: {msg}") for msg in fmt_bad]
            findings += [Finding("NL-META-PROP", "<registry>", 0,
                                 f"{cell.label}: {msg}") for msg in meta_bad]

    if only_ops is None:
        findings += _grad_coverage(contracts, impls)
        findings += _vmem_budget(contracts)
        findings += _block_contract_guard()
        for op, mode in sorted(registered - coverage):
            findings.append(Finding(
                "NL-DISPATCH-TOTALITY", "<registry>", 0,
                f"registered implementation ({op!r}, {mode!r}) was not "
                f"reachable by the sweep — add a driver/config so the "
                f"static pass covers it"))

    if len(fallback.demotions()) > demoted_before:
        # an abstract failure tripped the graceful-degradation guard; a
        # sticky demotion from a STATIC pass must not leak into runtime
        fallback.reset_demotions()

    return ContractReport(findings, coverage, registered, cells,
                          time.time() - t0)
