"""The ONE shape-walking implementation: abstract evaluation helpers.

Everything static analysis (and the multi-pod dry run) needs from JAX is
``jax.eval_shape`` — trace a function over ``ShapeDtypeStruct`` leaves,
resolve every shape/dtype/sharding decision, run zero FLOPs. This module
wraps it with the two things the callers kept reimplementing ad hoc:

  * ``abstract_eval(fn, *args, **kw)`` — eval_shape with diagnostics: a
    failure raises ``AbstractEvalError`` naming the callee and the operand
    avals instead of a bare tracer error (``launch.dryrun`` walks model
    init/optimizer shapes through this; ``repro.analysis.contracts`` walks
    the whole kernel registry through it).
  * ``spike_aval(...)`` — abstract ``SpikeTensor`` operands in either
    format, with the padded word grid and metadata map shapes the packed
    contract pins down.
  * ``EDGE_SHAPES`` / ``HEAD_CONFIGS`` — the declared edge-shape corpus the
    contract verifier sweeps: block-aligned, sub-block, and ragged
    (non-multiple) core shapes, plus the head-blocking configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.events import DEFAULT_BLOCKS, LANE_BITS

#: the contract verifier's edge-shape corpus: (m, k, n) core shapes. One
#: block-aligned cell, one sub-block cell (everything inside one tile), and
#: one ragged cell that exercises every pad path (m, k, n all non-multiples
#: of the 128 grid and k a non-multiple of the 32-bit lane width).
EDGE_SHAPES = (
    (128, 128, 128),      # exactly one block tile
    (8, 64, 32),          # sub-block: padding dominates
    (130, 96, 72),        # ragged: pad lanes + partial tiles on every axis
)

#: head-blocking configurations for the QK write-back ops: (heads,
#: kv_heads) with kv_heads < heads exercising the grouped-KV weight
#: expansion. head_dim is derived from the swept n (n // heads).
HEAD_CONFIGS = ((None, None), (2, 2), (4, 2))


class AbstractEvalError(RuntimeError):
    """An abstract evaluation failed: carries the callee and operand avals
    so registry-wide sweeps report *which* cell broke, not a bare tracer
    traceback."""

    def __init__(self, what: str, avals: Any, cause: Exception):
        self.what, self.avals, self.cause = what, avals, cause
        super().__init__(f"abstract eval of {what} failed on {avals}: "
                         f"{type(cause).__name__}: {cause}")


def _aval_str(x: Any) -> str:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return f"{jnp.dtype(x.dtype).name}[{','.join(map(str, x.shape))}]"
    return type(x).__name__


def _is_aval_leaf(x: Any) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def _is_dynamic(x: Any) -> bool:
    """True when the argument is a pure aval pytree (every leaf carries
    shape+dtype) — the operands eval_shape traces. Everything else
    (policies, configs, skip strings, ints, None) is static and closed
    over."""
    leaves = jax.tree_util.tree_leaves(x, is_leaf=_is_aval_leaf)
    return bool(leaves) and all(_is_aval_leaf(l) for l in leaves)


def abstract_eval(fn: Callable, *args, what: str = "", **kwargs):
    """``jax.eval_shape`` over the array-like arguments of ``fn(*args,
    **kwargs)`` with the static arguments closed over, plus diagnostics.

    Returns the output aval tree (ShapeDtypeStructs in the output pytree
    structure — SpikeTensor outputs come back as SpikeTensors of
    ShapeDtypeStruct leaves). Zero FLOPs: nothing is lowered, compiled, or
    executed.
    """
    dyn_idx = [i for i, a in enumerate(args) if _is_dynamic(a)]
    dyn_keys = [k for k, v in kwargs.items() if _is_dynamic(v)]

    def call(dyn_args, dyn_kwargs):
        full = list(args)
        for i, v in zip(dyn_idx, dyn_args):
            full[i] = v
        kw = dict(kwargs, **dyn_kwargs)
        return fn(*full, **kw)

    try:
        return jax.eval_shape(call, [args[i] for i in dyn_idx],
                              {k: kwargs[k] for k in dyn_keys})
    except Exception as e:                      # noqa: BLE001 — re-raised
        name = what or getattr(fn, "__name__", str(fn))
        leaves = [_aval_str(l) for l in jax.tree_util.tree_leaves(
            (args, kwargs), is_leaf=_is_aval_leaf)]
        raise AbstractEvalError(name, leaves, e) from e


def sds(shape: tuple, dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def packed_grid(m: int, k: int, *, block_m: int = DEFAULT_BLOCKS.m,
                block_k: int = DEFAULT_BLOCKS.k) -> tuple:
    """(padded_m, padded_k, word_cols, grid_m, grid_k) of a packed map —
    the shape algebra the metadata-propagation check verifies against."""
    mp, kp = _ceil_to(m, block_m), _ceil_to(k, block_k)
    return mp, kp, kp // LANE_BITS, mp // block_m, kp // block_k


def spike_aval(m: int, k: int, fmt: str = "dense", *, lead: tuple = (),
               block_m: int = DEFAULT_BLOCKS.m,
               block_k: int = DEFAULT_BLOCKS.k, with_vld: bool = False,
               dtype=jnp.int8):
    """An abstract SpikeTensor operand: [*, m, k] logical spikes in either
    format. Packed avals carry the contract-correct padded word grid and
    vld_cnt map; ``with_vld`` attaches the metadata map to dense avals too
    (the chained-layer case)."""
    from ..ops.spike_tensor import SpikeTensor

    if fmt == "packed":
        mp, kp, words, gm, gk = packed_grid(m, k, block_m=block_m,
                                            block_k=block_k)
        return SpikeTensor(sds((*lead, mp, words), jnp.int32),
                           sds((*lead, gm, gk), jnp.int32), "packed",
                           (*lead, m, k), block_m, block_k)
    vld = None
    if with_vld:
        _, _, _, gm, gk = packed_grid(m, k, block_m=block_m, block_k=block_k)
        vld = sds((*lead, gm, gk), jnp.int32)
    return SpikeTensor(sds((*lead, m, k), dtype), vld, "dense",
                       (*lead, m, k), block_m, block_k)


# ------------------------------------------------- model-level shape walking
# (the dry-run's side of the shared implementation)
def module_param_shapes(init_fn: Callable, *init_args):
    """Abstract parameter pytree of a model ``init`` (seeded with key 0 —
    shapes are key-independent)."""
    if not init_args:
        init_args = (jax.random.PRNGKey(0),)
    return abstract_eval(init_fn, *init_args, what="model.init")


def optimizer_shapes(opt_init: Callable, params_shape):
    """Abstract optimizer-state pytree for a parameter aval tree."""
    return abstract_eval(opt_init, params_shape, what="optimizer.init")


@dataclasses.dataclass(frozen=True)
class TileModel:
    """Static VMEM residency of one kernel family at one tiling — what the
    NL-VMEM-BUDGET check prices against ``launch.roofline.VMEM_BYTES``."""
    family: str
    block_m: int
    block_n: int
    block_k: int
    packed: bool
    bytes: int
