"""The finding model shared by both neurallint engines, and the rule
catalog (see docs/static_analysis.md for the prose version)."""
from __future__ import annotations

import dataclasses
from typing import Optional
from xml.sax.saxutils import escape

#: rule id -> one-line description. BOTH engines draw ids from this table;
#: ``tools/neurallint.py --rules`` prints it and the test suite asserts
#: every emitted finding carries a catalogued id.
RULES = {
    # -- engine 1: abstract contract verifier (repro.analysis.contracts) --
    "NL-DISPATCH-TOTALITY": (
        "every advertised (op, policy) point resolves in the registry — "
        "no NotImplementedError at dispatch time"),
    "NL-SILENT-DOWNGRADE": (
        "a dispatch under policy P must only resolve P's kernel axis: a "
        "'fused' request recording a 'reference' lookup (or vice versa) is "
        "the silent-downgrade bug class of PR 8"),
    "NL-FORMAT-PRESERVE": (
        "spike outputs leave in the policy's format with the contracted "
        "dtype (int8 dense / int32 words packed; dense f32 under +grad)"),
    "NL-META-PROP": (
        "every packed output carries a vld_cnt block map whose grid is "
        "shape-consistent with the payload"),
    "NL-GRAD-COVERAGE": (
        "every op of a grad-declaring family registers both "
        "'reference+grad' and 'fused+grad' implementations"),
    "NL-BLOCK-CONTRACT": (
        "the packed block-shape contract is satisfiable on the corpus and "
        "its runtime guard actually rejects mismatched tilings"),
    "NL-VMEM-BUDGET": (
        "each family's declared BlockSpec residency model fits the "
        "per-core VMEM budget (launch.roofline.VMEM_BYTES)"),
    # -- engine 2: AST lint (repro.analysis.lint) --
    "NL-REGISTRY-BYPASS": (
        "repro.kernels.* Pallas entry points imported outside repro.ops / "
        "repro.kernels — call sites must go through the policy registry"),
    "NL-HOST-SYNC": (
        "float()/.item()/np.asarray()/np.array()/jax.device_get() inside "
        "a jit-decorated function or an engine tick/route path — a hidden "
        "host sync in traced or per-tick code"),
    "NL-BARE-HEAVISIDE": (
        "a Heaviside spelled as a comparison cast on the differentiable "
        "surface — use core.surrogate.spike so the registered "
        "pseudo-derivative flows"),
    "NL-INTERPRET-HARDCODE": (
        "interpret=True hardcoded in non-test code — interpret mode must "
        "stay a backend-derived default"),
    "NL-MUTABLE-DEFAULT": (
        "mutable default (list/dict/set literal or constructor) in a "
        "function signature or dataclass field — shared-state pytree bug"),
    "NL-LEGACY-FLAGS": (
        "deleted pre-policy flag kwargs (use_event_kernels= / "
        "spike_format= / pack_out=) outside the compat shim"),
    "NL-LEGACY-FORKS": (
        "deleted snn_cnn forward forks (_apply_fused_event / "
        "_apply_fused_reference / snn_cnn.apply(_fused)) reappearing"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: a catalogued rule, a location, and the message."""
    rule: str
    path: str                      # repo-relative, or "<registry>" for
                                   # engine-1 findings with no source line
    line: int
    message: str

    def __post_init__(self):
        assert self.rule in RULES, f"unknown rule id {self.rule!r}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def render(findings: list) -> str:
    """Human-readable report, grouped by rule."""
    if not findings:
        return "neurallint: clean"
    lines = [f"neurallint: {len(findings)} finding(s)"]
    lines += [str(f) for f in findings]
    return "\n".join(lines)


def junit_xml(findings: list, *, checked: int, suite: str = "neurallint"
              ) -> str:
    """Findings as a junit report (one testcase per rule; a rule with
    findings fails with every location in the failure body) — the CI
    artifact format."""
    by_rule: dict[str, list] = {r: [] for r in RULES}
    for f in findings:
        by_rule[f.rule].append(f)
    cases = []
    for rule, desc in RULES.items():
        hits = by_rule[rule]
        if hits:
            body = escape("\n".join(str(f) for f in hits))
            cases.append(
                f'  <testcase classname="{suite}" name="{rule}">\n'
                f'    <failure message="{len(hits)} finding(s)">'
                f'{body}</failure>\n  </testcase>')
        else:
            cases.append(f'  <testcase classname="{suite}" name="{rule}"/>')
    return (f'<?xml version="1.0" encoding="utf-8"?>\n'
            f'<testsuite name="{suite}" tests="{len(RULES)}" '
            f'failures="{sum(1 for r in by_rule.values() if r)}" '
            f'checked="{checked}">\n' + "\n".join(cases) + "\n</testsuite>\n")
