"""``repro.analysis`` — static analysis over the kernel registry and the
project source.

Two engines, one finding model:

  * ``repro.analysis.contracts`` — the abstract contract verifier: walks
    every registered ``(op, mode)`` pair of the kernel registry under
    ``jax.eval_shape`` (zero FLOPs, zero kernel launches) over a declared
    edge-shape corpus and proves dispatch totality, no silent downgrades,
    format/dtype preservation, metadata propagation, grad coverage,
    block-contract satisfiability, and static VMEM budgets.
  * ``repro.analysis.lint`` — AST lint rules over the project source
    (registry bypass, host sync in traced code, bare Heavisides on the
    differentiable surface, hardcoded interpret mode, mutable default
    pytrees, and the legacy-surface guards), with per-line
    ``# neurallint: disable=RULE`` suppressions.

``tools/neurallint.py`` is the CLI + CI gate over both.
"""
from .findings import Finding, RULES, junit_xml, render
from .abstract import AbstractEvalError, abstract_eval, spike_aval
from .contracts import ContractReport, verify_contracts
from .lint import lint_paths, lint_source

__all__ = [
    "Finding", "RULES", "junit_xml", "render",
    "AbstractEvalError", "abstract_eval", "spike_aval",
    "ContractReport", "verify_contracts",
    "lint_paths", "lint_source",
]
