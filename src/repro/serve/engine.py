"""Batched serving engine: continuous batching over a fixed slot pool.

Design (vLLM-style, TPU-static-shapes edition):
  * ``max_slots`` concurrent sequences share one preallocated KV cache of
    shape [L, max_slots, max_len, Hkv, Dh] — slots are rows of the batch dim.
  * prefill runs per-request (padded to ``prefill_pad`` buckets so a handful
    of compiled shapes serve all prompt lengths) and WRITES the produced
    cache into the slot row.
  * decode is ONE jitted step over the whole pool every tick regardless of
    how many slots are live (static shape — idle slots compute garbage that
    is masked out; this is the standard TPU trade).
  * completion (EOS or max_new) frees the slot; queued requests are admitted
    on the next tick — continuous batching.
  * spiking/QKFormer models (attention_kind='qk_spiking') have an EMPTY
    attention cache (token-local masks), so the same engine serves them with
    per-slot state of size 0 — the paper's O(1)-decode claim in practice.

Sampling: greedy or temperature (per request).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S] int32
    max_new: int = 32
    temperature: float = 0.0            # 0 = greedy
    eos_id: Optional[int] = None
    # -- filled by the engine --
    out: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    enqueued_t: float = 0.0
    first_token_t: float = 0.0
    finished_t: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    prefill_pad: int = 64               # prompt length bucket size
    # deployed spiking path: route qk_spiking models' LIF projections and
    # binary-activation matmuls through the fused-PE / spike_matmul Pallas
    # kernels (forward-exact; serving is inference, so the missing surrogate
    # gradient is irrelevant here)
    use_event_kernels: bool = False
    # HBM format for the qk_spiking path's spike tensors: "packed" ships the
    # masked attention spike maps bit-packed (32 spikes per int32 lane) and
    # caches each slot's spike state packed — the engine then measures spike
    # sparsity and packed bytes in flight every decode tick (see ``stats``)
    spike_format: str = "dense"
    # measure spike telemetry every Nth decode tick (0 disables): each
    # measurement syncs the packed state pool to host, so latency-sensitive
    # deployments should sample sparsely
    spike_stats_every: int = 1


class Engine:
    def __init__(self, model, params, cfg: EngineConfig, rng_seed: int = 0):
        self.model = model
        self.params = params
        self.cfg = cfg
        spiking = getattr(model.cfg, "attention_kind", "") == "qk_spiking"
        repl = {}
        if spiking and cfg.use_event_kernels:
            repl["use_event_kernels"] = True
        if spiking and cfg.spike_format != "dense":
            repl["spike_format"] = cfg.spike_format
        if repl:
            # run THIS engine's prefills/decodes on the fused event-kernel
            # dataflow without mutating the caller's model (the flags are
            # inference-only; a shared model may still be used for training)
            self.model = type(model)(
                dataclasses.replace(model.cfg, **repl))
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self._rng = jax.random.PRNGKey(rng_seed)
        self._uid = itertools.count()
        # per-decode-tick spike telemetry (packed qk_spiking mode only)
        self._track_spikes = (spiking and cfg.spike_format == "packed"
                              and cfg.spike_stats_every > 0)
        self._spike_log: list[dict] = []
        self._tick = 0

        # slot-pool cache; per-slot valid lengths tracked host-side
        self.cache = self.model.init_cache(cfg.max_slots, cfg.max_len)
        self.cache["len"] = jnp.zeros((), jnp.int32)  # engine manages length
        self.slot_len = np.zeros(cfg.max_slots, np.int64)
        self.free_slots = list(range(cfg.max_slots))

        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn,
                                static_argnames=("pad_len",))

    # ----------------------------------------------------------- jitted fns
    def _prefill_fn(self, params, tokens, pad_len):
        # all-position logits: prompts are right-padded, the engine reads
        # the logits at each prompt's true last position
        logits, cache = self.model.prefill(params, {"tokens": tokens},
                                           return_all_logits=True)
        return logits, cache

    def _decode_fn(self, params, tokens, cache):
        """One pool-wide decode tick; cache['len'] is the per-slot [B]
        length vector, so every slot attends exactly its own prefix."""
        return self.model.decode_step(params, tokens, cache)

    # ------------------------------------------------------------ lifecycle
    def submit(self, prompt: np.ndarray, max_new: int = 32,
               temperature: float = 0.0, eos_id: Optional[int] = None) -> int:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, temperature=temperature, eos_id=eos_id)
        req.enqueued_t = time.time()
        self.queue.append(req)
        return req.uid

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            req.slot = slot
            s = len(req.prompt)
            if self.model.cfg.family in ("ssm", "hybrid"):
                # SSM recurrences integrate pad positions into the state —
                # prefill at TRUE length (attention pads are causal-inert,
                # SSM pads are not)
                pad_len = s
            else:
                pad_len = min(
                    self.cfg.max_len,
                    -(-s // self.cfg.prefill_pad) * self.cfg.prefill_pad)
            toks = np.zeros((1, pad_len), np.int32)
            toks[0, :s] = req.prompt        # right-pad (causal: pads inert)
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          pad_len=pad_len)
            self._write_slot(slot, cache)
            self.slot_len[slot] = s         # only the REAL prompt is valid
            tok = self._sample(logits[0, s - 1], req)
            req.out.append(int(tok))
            req.first_token_t = time.time()
            self.active[slot] = req

    def _write_slot(self, slot: int, prefill_cache: dict) -> None:
        """Copy one request's prefill cache into its slot row."""

        def write(path, pool, new):
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            nd = pool.ndim
            idx = [slice(None)] * nd
            if "ssm" in ps:                 # [.., slots, H, P, N]
                idx[nd - 4] = slice(slot, slot + 1)
            elif "conv" in ps:              # [.., slots, K-1, C]
                idx[nd - 3] = slice(slot, slot + 1)
            else:                           # KV [.., slots, max_len, H, D]
                if new.shape[nd - 3] == 0:  # qk_spiking: stateless
                    return pool
                idx[nd - 4] = slice(slot, slot + 1)
                idx[nd - 3] = slice(0, new.shape[nd - 3])
            return pool.at[tuple(idx)].set(new.astype(pool.dtype))

        self.cache["layers"] = jax.tree_util.tree_map_with_path(
            write, self.cache["layers"], prefill_cache["layers"])

    def _sample(self, logits: Array, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / req.temperature))

    def step(self) -> int:
        """One engine tick: admit + one decode for all live slots.
        Returns number of live sequences."""
        self._admit()
        if not self.active:
            return 0
        toks = np.zeros((self.cfg.max_slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out[-1]
        # per-slot length vector: every slot attends exactly its own prefix
        self.cache["len"] = jnp.asarray(self.slot_len, jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache)
        self._tick += 1
        if self._track_spikes and self._tick % self.cfg.spike_stats_every == 0:
            self._record_spike_step(sorted(self.active.keys()))
        done_slots = []
        for slot, req in list(self.active.items()):
            tok = self._sample(logits[slot], req)
            req.out.append(tok)
            self.slot_len[slot] += 1
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.out) >= req.max_new \
                    or self.slot_len[slot] >= self.cfg.max_len - 1:
                req.done = True
                req.finished_t = time.time()
                self.finished.append(req)
                done_slots.append(slot)
        for slot in done_slots:
            del self.active[slot]
            self.slot_len[slot] = 0
            self.free_slots.append(slot)
        return len(self.active)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            live = self.step()
            if not live and not self.queue:
                break
        return self.finished

    def _record_spike_step(self, live_slots: list) -> None:
        """Measure one decode tick's spike activity straight off the PACKED
        per-slot spike state in the cache pool: popcount of the int32 words
        = spike count (the pad lanes are zero), words bytes = what actually
        crossed HBM for spike state this tick."""
        if not live_slots:
            return
        n_units = (self.model.cfg.n_heads *
                   self.model.cfg.resolved_head_dim)
        spikes = packed_b = units = 0
        for leaf in jax.tree_util.tree_leaves(self.cache["layers"]):
            if leaf.dtype != jnp.int32 or leaf.ndim != 5:
                continue                    # only the packed word pools
            sel = np.asarray(leaf)[:, live_slots]
            spikes += int(np.unpackbits(
                np.ascontiguousarray(sel).view(np.uint8)).sum())
            packed_b += sel.size * 4
            units += sel.shape[0] * len(live_slots) * n_units
        if units:
            self._spike_log.append({
                "live": len(live_slots),
                "spike_rate": spikes / units,
                "packed_bytes": packed_b,
                "dense_bytes": units})        # the int8 maps it replaces

    def stats(self) -> dict:
        if not self.finished:
            return {}
        ttft = [r.first_token_t - r.enqueued_t for r in self.finished]
        lat = [r.finished_t - r.enqueued_t for r in self.finished]
        toks = sum(len(r.out) for r in self.finished)
        span = max(r.finished_t for r in self.finished) - \
            min(r.enqueued_t for r in self.finished)
        out = {"n": len(self.finished),
               "ttft_mean_s": float(np.mean(ttft)),
               "latency_mean_s": float(np.mean(lat)),
               "tokens": toks,
               "tok_per_s": toks / max(span, 1e-9),
               "queue_depth": len(self.queue),
               "active": len(self.active),
               "spike_format": self.cfg.spike_format}
        if self._spike_log:
            rate = float(np.mean([e["spike_rate"] for e in self._spike_log]))
            pb = float(np.mean([e["packed_bytes"] for e in self._spike_log]))
            db = float(np.mean([e["dense_bytes"] for e in self._spike_log]))
            out.update({
                "decode_ticks_measured": len(self._spike_log),
                "spike_rate_mean": rate,
                "spike_sparsity_mean": 1.0 - rate,
                "packed_spike_bytes_per_tick_mean": pb,
                "dense_spike_bytes_per_tick_mean": db,
                "spike_state_hbm_reduction": db / max(pb, 1e-9)})
        return out
