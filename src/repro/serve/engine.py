"""Batched serving engine: continuous batching over a fixed slot pool, with
an elastic-FIFO chunked-prefill pipeline (the paper's FIFO-decoupled hybrid
data-event execution applied at the request-scheduling layer).

Design (vLLM-style, TPU-static-shapes edition):
  * ``max_slots`` concurrent sequences share one preallocated KV cache of
    shape [L, max_slots, max_len, Hkv, Dh] — slots are rows of the batch dim.
  * prefill runs per-request (padded to ``prefill_pad`` buckets so a handful
    of compiled shapes serve all prompt lengths) and WRITES the produced
    cache into the slot row.
  * decode is ONE jitted step over the whole pool every tick regardless of
    how many slots are live (static shape — idle slots compute garbage that
    is masked out; this is the standard TPU trade).
  * completion (EOS or max_new) frees the slot; queued requests are admitted
    on the next tick — continuous batching.
  * spiking/QKFormer models (attention_kind='qk_spiking') have an EMPTY
    attention cache (token-local masks), so the same engine serves them with
    per-slot state of size 0 — the paper's O(1)-decode claim in practice.

Elastic-FIFO pipeline (``prefill_chunk > 0``), mirroring the paper's FIFO
depth elasticity in software:
  * chunked prefill — each prompt is split into ``prefill_chunk``-token
    chunks that run through ``LM.prefill_chunk`` against a per-request
    bucket cache; at most ``prefill_chunks_per_tick`` chunks run per engine
    tick, so one long prompt can no longer freeze every live decode slot
    (head-of-line stall → bounded p99 decode-tick latency). Bit-identical
    to the blocking prefill under greedy decode: chunks cover the same
    padded bucket, so every reduction runs over the same axis lengths.
    (Caveat: above ``cfg.flash_threshold`` the blocking prefill switches
    to flash accumulation, whose different f32 reduction order chunked
    prefill does not reproduce — raise the threshold for strict parity on
    very long prompts.)
  * elastic admission FIFO — ``max_queue`` bounds the submit queue;
    ``submit`` applies backpressure by donating engine ticks (draining the
    pipeline) until a queue slot frees, like a producer stalling on a full
    hardware FIFO. Occupancy high-water marks are exported via ``stats()``.
  * per-slot output FIFOs — sampled tokens stream into a per-request FIFO
    (``pop_output``); with ``out_fifo_depth`` set, a slot whose consumer
    stops draining is STALLED (its cache row is restored after the pool
    decode, its token re-fed next tick — exact and order-preserving under
    greedy decode; temperature sampling draws from the engine's shared RNG
    stream, whose consumption order stalls reshuffle) while the other
    slots keep decoding: downstream backpressure without head-of-line
    blocking.

Sampling: greedy or temperature (per request).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import ops
from ..core.events import pad_lane_mask
from .faults import FaultPlan, ReplicaFailure

Array = jax.Array

# Jitted engine step functions shared across Engine instances of the same
# (model class, config): a process serving N replicas — or a test suite
# constructing many engines — compiles each (shape, config) combination
# exactly once instead of once per engine.
_JIT_CACHE: dict = {}


def _jitted_steps(model):
    key = (type(model), model.cfg)
    if key not in _JIT_CACHE:
        def prefill_fn(params, tokens):
            return model.prefill(params, {"tokens": tokens},
                                 return_all_logits=True)

        chunk_fn = getattr(model, "prefill_chunk", None)
        _JIT_CACHE[key] = (jax.jit(prefill_fn),
                           jax.jit(model.decode_step),
                           jax.jit(chunk_fn) if chunk_fn else None)
    return _JIT_CACHE[key]


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the admission FIFO stays full (non-blocking
    submit, or a blocking submit that exhausted its tick budget)."""


class StalledEngine(RuntimeError):
    """``run_until_drained`` detected a livelock: work is still pending but
    no pipeline stage has made progress for the grace window (or the tick
    budget ran out). The message names the stuck slots and FIFO depths;
    ``report`` carries the same data machine-readably."""

    def __init__(self, msg: str, report: Optional[dict] = None):
        super().__init__(msg)
        self.report = report or {}


def clear_jit_cache() -> None:
    """Drop the shared jitted-step cache. Needed when a process-global ops
    demotion (``repro.ops.fallback``) is reset and the engine must re-trace
    through the restored fused kernels — compiled executables baked the
    demoted graph in."""
    _JIT_CACHE.clear()


# Request.status lifecycle. "done" is the only SUCCESS terminal; the
# ``done`` bool means "terminal" (any of the last four).
STATUS_QUEUED = "queued"
STATUS_PREFILL = "prefill"
STATUS_DECODE = "decode"
STATUS_DONE = "done"
STATUS_CANCELLED = "cancelled"
STATUS_DEADLINE = "deadline_miss"
STATUS_FAILED = "failed"
TERMINAL = (STATUS_DONE, STATUS_CANCELLED, STATUS_DEADLINE, STATUS_FAILED)


@dataclasses.dataclass(eq=False)
class Request:
    uid: int
    prompt: np.ndarray                  # [S] int32
    max_new: int = 32
    temperature: float = 0.0            # 0 = greedy
    eos_id: Optional[int] = None
    # deadlines (absolute, resolved at submit; None = none)
    deadline_tick: Optional[int] = None
    deadline_t: Optional[float] = None
    # -- filled by the engine --
    out: list = dataclasses.field(default_factory=list)
    fifo: deque = dataclasses.field(default_factory=deque)  # undrained tokens
    slot: int = -1
    done: bool = False
    status: str = STATUS_QUEUED
    retries: int = 0                    # quarantine evict->requeue count
    pushed: int = 0                     # tokens ever pushed to the FIFO:
    # a quarantine replay regenerates the greedy stream from scratch but
    # only pushes tokens PAST this mark — at-most-once delivery
    enqueued_t: float = 0.0
    first_token_t: float = 0.0
    finished_t: float = 0.0
    enqueued_tick: int = 0
    first_token_tick: int = -1


@dataclasses.dataclass
class _PrefillJob:
    """One request's in-flight chunked prefill (an elastic-FIFO entry)."""
    req: Request
    slot: int
    cache: dict                         # per-request bucket cache
    bucket: int                         # positions this job must process
    done: int = 0                       # positions processed so far
    last_logits: Optional[Array] = None  # logits at the prompt's last token


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    prefill_pad: int = 64               # prompt length bucket size
    # --- elastic-FIFO pipeline ---
    # prefill_chunk > 0: split prefill into chunks of this many tokens that
    # interleave with decode ticks (0 = blocking, monolithic prefill). The
    # engine rounds the chunk up to the model family's exactness granularity
    # (``cfg.prefill_chunk_align``: ssm/hybrid chunk on ssm_chunk bounds).
    prefill_chunk: int = 0
    prefill_chunks_per_tick: int = 1    # prefill work budget per decode tick
    max_queue: int = 0                  # admission FIFO bound (0 = unbounded)
    submit_block_ticks: int = 10_000    # backpressure budget before QueueFull
    out_fifo_depth: int = 0             # per-slot output FIFO bound (0 = inf)
    # policy: how THIS engine executes qk_spiking models, overriding the
    # model config's own policy (repro.ops.ExecutionPolicy or a preset
    # name). "fused_dense"/"fused_packed" route the LIF projections and
    # binary-activation matmuls through the fused-PE / spike_matmul Pallas
    # kernels (forward-exact; serving is inference, so the missing
    # surrogate gradient is irrelevant); a packed policy additionally ships
    # the masked attention spike maps bit-packed (32 spikes per int32
    # lane), caches each slot's spike state packed, and measures spike
    # sparsity + packed bytes in flight every decode tick (see ``stats``).
    # None = inherit the model's policy unchanged.
    policy: Optional[Any] = None
    # deprecated flag pair -> policy (repro.ops.compat translates + warns);
    # each flag ESCALATES only its own policy axis — exactly the pre-policy
    # engine's semantics, which could switch features on but never off
    use_event_kernels: Optional[bool] = None
    spike_format: Optional[str] = None
    # measure spike telemetry every Nth decode tick (0 disables): each
    # measurement syncs the packed state pool to host, so latency-sensitive
    # deployments should sample sparsely
    spike_stats_every: int = 1
    # --- self-healing ---
    # run the per-tick integrity guard every Nth decode tick (0 disables):
    # one jitted scan over the slot-pool cache + logits (finite-check on
    # float state, pad-lane invariant on packed spike words) whose verdict
    # is a [max_slots] bool pair — a flagged LIVE slot is quarantined
    # (evicted, scrubbed, requeued) instead of crashing the engine
    integrity_every: int = 0
    # quarantine retry budget: a request evicted more than this many times
    # is failed (status "failed") instead of requeued again
    quarantine_retries: int = 2
    # default per-request deadline in engine ticks (0 = none); individual
    # submits may override
    deadline_ticks: int = 0

    def __post_init__(self):
        resolved = ops.legacy_flags_policy(
            "EngineConfig", self.policy, self.use_event_kernels,
            self.spike_format)
        if self.policy is not None:
            self.policy = resolved


class Engine:
    def __init__(self, model, params, cfg: EngineConfig, rng_seed: int = 0,
                 faults: Optional[FaultPlan] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        # fault-injection script (None in production); kernel faults are
        # process-global and armed immediately
        self.faults = faults
        if faults is not None:
            faults.arm_kernel_faults()
        spiking = getattr(model.cfg, "attention_kind", "") == "qk_spiking"
        self.policy = getattr(model.cfg, "exec_policy", ops.REFERENCE)
        if spiking:
            eff = ops.merge_engine_policy(
                model.cfg.exec_policy, cfg.policy, cfg.use_event_kernels,
                cfg.spike_format)
            if eff != model.cfg.exec_policy:
                # run THIS engine's prefills/decodes under the engine's
                # policy without mutating the caller's model (fused
                # policies are inference-only; a shared model may still be
                # used for training under its own "reference" policy)
                self.model = type(model)(ops.with_policy(model.cfg, eff))
            self.policy = eff
        self.queue: deque[Request] = deque()
        self.prefill_fifo: deque[_PrefillJob] = deque()
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.requests: dict[int, Request] = {}
        self._rng = jax.random.PRNGKey(rng_seed)
        self._uid = itertools.count()
        # per-decode-tick spike telemetry (packed qk_spiking mode only)
        self._track_spikes = (spiking and self.policy.packed
                              and cfg.spike_stats_every > 0)
        self._spike_log: list[dict] = []
        self._tick = 0
        # elastic-FIFO telemetry: occupancy high-water marks + tick latency
        self._queue_hwm = 0
        self._prefill_fifo_hwm = 0
        self._out_fifo_hwm = 0
        self._stall_ticks = 0
        self._prefill_chunks = 0
        # rolling window: stats() percentiles stay O(window), memory bounded
        self._tick_wall: deque = deque(maxlen=4096)
        # self-healing state + counters
        self._tokens_emitted = 0
        self._cancelled = 0
        self._deadline_miss = 0
        self._quarantined = 0
        self._requeues = 0
        self._failed = 0
        self._guard_scans = 0
        self._guard_fn = None               # lazily-jitted integrity scan
        self._forced_stalls: dict[int, int] = {}   # slot -> stall-until tick

        # slot-pool cache; per-slot valid lengths tracked host-side
        self.cache = self.model.init_cache(cfg.max_slots, cfg.max_len)
        self.cache["len"] = jnp.zeros((), jnp.int32)  # engine manages length
        self.slot_len = np.zeros(cfg.max_slots, np.int64)
        self.free_slots = list(range(cfg.max_slots))

        if cfg.prefill_chunk > 0 and not hasattr(self.model, "prefill_chunk"):
            raise ValueError(
                f"{type(self.model).__name__} has no prefill_chunk: chunked "
                f"prefill serves the decoder-only LM zoo (set "
                f"EngineConfig.prefill_chunk=0 for blocking prefill)")
        # shared jitted steps: prefill returns all-position logits (prompts
        # are right-padded; the engine reads each prompt's true last
        # position) and decode is one pool-wide tick whose cache['len'] is
        # the per-slot [B] length vector, so every slot attends exactly its
        # own prefix
        self._prefill, self._decode, self._prefill_chunk = \
            _jitted_steps(self.model)

    # ------------------------------------------------------------ lifecycle
    def submit(self, prompt: np.ndarray, max_new: int = 32,
               temperature: float = 0.0, eos_id: Optional[int] = None,
               block: bool = True, deadline_ticks: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request. With ``max_queue`` set and the admission FIFO
        full, a blocking submit applies backpressure: it donates engine
        ticks (draining prefill chunks and decode work) until a queue slot
        frees; ``block=False`` raises ``QueueFull`` immediately instead.

        ``deadline_ticks`` (engine ticks from enqueue, deterministic) and
        ``deadline_s`` (wall seconds, for latency SLOs) bound the request's
        lifetime: a request still unfinished past either deadline is
        cancelled with status "deadline_miss" at the next tick, its slot
        reclaimed. ``deadline_ticks=None`` inherits
        ``EngineConfig.deadline_ticks`` (0 = no deadline)."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt: there is no position to read "
                             "first-token logits from")
        if len(prompt) >= self.cfg.max_len:
            raise ValueError(f"prompt length {len(prompt)} >= max_len "
                             f"{self.cfg.max_len}: the slot pool cannot "
                             f"hold it (raise EngineConfig.max_len)")
        if self.cfg.max_queue and len(self.queue) >= self.cfg.max_queue:
            if not block:
                raise QueueFull(f"admission FIFO at bound "
                                f"{self.cfg.max_queue}")
            for _ in range(self.cfg.submit_block_ticks):
                self.step()
                if len(self.queue) < self.cfg.max_queue:
                    break
            else:
                raise QueueFull("backpressure tick budget exhausted")
        req = Request(uid=next(self._uid), prompt=prompt,
                      max_new=max_new, temperature=temperature, eos_id=eos_id)
        req.enqueued_t = time.time()
        req.enqueued_tick = self._tick
        if deadline_ticks is None:
            deadline_ticks = self.cfg.deadline_ticks or None
        if deadline_ticks is not None:
            req.deadline_tick = self._tick + int(deadline_ticks)
        if deadline_s is not None:
            req.deadline_t = req.enqueued_t + float(deadline_s)
        self.queue.append(req)
        self.requests[req.uid] = req
        self._queue_hwm = max(self._queue_hwm, len(self.queue))
        return req.uid

    def cancel(self, uid: int, status: str = STATUS_CANCELLED) -> bool:
        """Cancel a request wherever it is in the pipeline: drop it from
        the admission queue, abandon its in-flight prefill, or evict its
        decode slot (the slot frees this tick — the pool decode simply
        stops computing it; no rollback needed since the row is dead).
        Already-emitted tokens stay drainable via ``pop_output``. Returns
        False for unknown/terminal uids."""
        req = self.requests.get(uid)
        if req is None or req.done:
            return False
        if req in self.queue:
            self.queue.remove(req)
        for job in list(self.prefill_fifo):
            if job.req is req:
                self.prefill_fifo.remove(job)
                self._release_slot(job.slot)
        if req.slot >= 0 and self.active.get(req.slot) is req:
            del self.active[req.slot]
            self._release_slot(req.slot, scrub=self.cfg.integrity_every > 0)
        self._finish(req, status)
        if status == STATUS_CANCELLED:
            self._cancelled += 1
        return True

    def _finish(self, req: Request, status: str) -> None:
        req.done = True
        req.status = status
        req.slot = -1
        req.finished_t = time.time()
        self.finished.append(req)

    def _release_slot(self, slot: int, scrub: bool = False) -> None:
        self.slot_len[slot] = 0
        self.free_slots.append(slot)
        if scrub:
            self._scrub_slot(slot)

    def _deadline_sweep(self) -> None:
        """Cancel every in-flight request whose tick or wall deadline has
        passed (status "deadline_miss")."""
        live = list(self.queue) + [j.req for j in self.prefill_fifo] \
            + list(self.active.values())
        now = None
        for req in live:
            over = (req.deadline_tick is not None
                    and self._tick >= req.deadline_tick)
            if not over and req.deadline_t is not None:
                now = time.time() if now is None else now
                over = now >= req.deadline_t
            if over:
                self.cancel(req.uid, status=STATUS_DEADLINE)
                self._deadline_miss += 1

    def pop_output(self, uid: int) -> list[int]:
        """Drain a request's output FIFO (the consumer side of the per-slot
        elastic FIFO). Draining un-stalls a slot paused by a full FIFO.
        A finished, fully-drained request is retired from the uid map (so a
        long-running server does not accumulate request state); draining an
        unknown/retired uid returns []."""
        req = self.requests.get(uid)
        if req is None:
            return []
        out, req.fifo = list(req.fifo), deque()
        if req.done:
            del self.requests[uid]
        return out

    def load(self) -> int:
        """Requests in flight (queued + prefilling + decoding) — the
        dispatch metric for the multi-replica router."""
        return len(self.queue) + len(self.prefill_fifo) + len(self.active)

    # ------------------------------------------------------------- admission
    def _bucket_len(self, s: int) -> int:
        if self.model.cfg.family in ("ssm", "hybrid"):
            # SSM recurrences integrate pad positions into the state —
            # prefill at TRUE length (attention pads are causal-inert,
            # SSM pads are not)
            return s
        return min(self.cfg.max_len,
                   -(-s // self.cfg.prefill_pad) * self.cfg.prefill_pad)

    def _admit(self) -> None:
        chunked = self.cfg.prefill_chunk > 0
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            req.slot = slot
            req.status = STATUS_PREFILL
            if chunked:
                self._admit_chunked(req, slot)
            else:
                self._admit_blocking(req, slot)

    def _admit_blocking(self, req: Request, slot: int) -> None:
        s = len(req.prompt)
        pad_len = self._bucket_len(s)
        toks = np.zeros((1, pad_len), np.int32)
        toks[0, :s] = req.prompt        # right-pad (causal: pads inert)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        self._write_slot(slot, cache)
        self._activate(req, slot, logits[0, s - 1])

    def _admit_chunked(self, req: Request, slot: int) -> None:
        s = len(req.prompt)
        bucket = self._bucket_len(s)
        cache = self.model.init_cache(1, bucket)
        cache["len"] = jnp.zeros((), jnp.int32)
        if self.model.cfg.kv_dtype:
            # chunk attention must read back the prefix it wrote: keep the
            # per-request cache at COMPUTE precision and quantize (f8 etc.)
            # once at _write_slot — exactly where the blocking path does —
            # or chunked would attend quantized keys blocking never saw
            dt = self.model.cfg.dtype
            cache["layers"] = jax.tree_util.tree_map(
                lambda a: a.astype(dt) if a.dtype == jnp.float8_e4m3fn
                else a, cache["layers"])
        self.prefill_fifo.append(_PrefillJob(req, slot, cache, bucket))
        self._prefill_fifo_hwm = max(self._prefill_fifo_hwm,
                                     len(self.prefill_fifo))

    def _chunk_size(self) -> int:
        align = self.model.cfg.prefill_chunk_align
        return -(-self.cfg.prefill_chunk // align) * align

    def _prefill_step(self, job: _PrefillJob) -> bool:
        """Run ONE chunk of one request's prefill. Returns True when the
        job completed (its slot cache is written and the request is live)."""
        req, s = job.req, len(job.req.prompt)
        chunk = min(self._chunk_size(), job.bucket - job.done)
        toks = np.zeros((1, chunk), np.int32)
        valid = max(0, min(chunk, s - job.done))
        toks[0, :valid] = req.prompt[job.done:job.done + valid]
        logits, job.cache = self._prefill_chunk(self.params,
                                                jnp.asarray(toks), job.cache)
        self._prefill_chunks += 1
        if job.done <= s - 1 < job.done + chunk:
            job.last_logits = logits[0, s - 1 - job.done]
        job.done += chunk
        if job.done < job.bucket:
            return False
        self._write_slot(job.slot, job.cache)
        self._activate(req, job.slot, job.last_logits)
        return True

    def _emit(self, req: Request, tok: int) -> None:
        """Record one sampled token. The FIFO only receives tokens PAST
        ``req.pushed`` — a quarantine replay regenerates the stream from
        scratch (greedy decode is deterministic) without re-delivering."""
        req.out.append(tok)
        self._tokens_emitted += 1
        if len(req.out) > req.pushed:
            req.fifo.append(tok)
            req.pushed = len(req.out)
            self._out_fifo_hwm = max(self._out_fifo_hwm, len(req.fifo))

    def _activate(self, req: Request, slot: int, last_logits: Array) -> None:
        """Prefill finished: slot goes live with the first sampled token."""
        self.slot_len[slot] = len(req.prompt)  # only the REAL prompt is valid
        tok = self._sample(last_logits, req)
        self._emit(req, int(tok))
        if req.first_token_tick < 0:    # a replay keeps the original TTFT
            req.first_token_t = time.time()
            req.first_token_tick = self._tick
        req.status = STATUS_DECODE
        self.active[slot] = req

    # ---------------------------------------------------------- cache moves
    def _write_slot(self, slot: int, prefill_cache: dict) -> None:
        """Copy one request's prefill cache into its slot row."""

        def write(path, pool, new):
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            nd = pool.ndim
            idx = [slice(None)] * nd
            if "ssm" in ps:                 # [.., slots, H, P, N]
                idx[nd - 4] = slice(slot, slot + 1)
            elif "conv" in ps:              # [.., slots, K-1, C]
                idx[nd - 3] = slice(slot, slot + 1)
            else:                           # KV [.., slots, max_len, H, D]
                if new.shape[nd - 3] == 0:  # qk_spiking: stateless
                    return pool
                idx[nd - 4] = slice(slot, slot + 1)
                idx[nd - 3] = slice(0, new.shape[nd - 3])
            return pool.at[tuple(idx)].set(new.astype(pool.dtype))

        self.cache["layers"] = jax.tree_util.tree_map_with_path(
            write, self.cache["layers"], prefill_cache["layers"])

    def _restore_slot(self, slot: int, prev_layers: Any) -> None:
        """Copy one slot's rows back from a pre-decode cache snapshot —
        makes a stalled slot's tick side-effect-free (its SSM/spike state
        must not advance while the consumer is not draining)."""

        def restore(path, pool, prev):
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            nd = pool.ndim
            idx = [slice(None)] * nd
            idx[nd - 3 if "conv" in ps else nd - 4] = slice(slot, slot + 1)
            idx = tuple(idx)
            return pool.at[idx].set(prev[idx])

        self.cache["layers"] = jax.tree_util.tree_map_with_path(
            restore, self.cache["layers"], prev_layers)

    def _scrub_slot(self, slot: int) -> None:
        """Zero one slot's rows in every cache pool — quarantine hygiene:
        a poisoned row must not survive into the slot's next occupant
        (prefill only overwrites the prompt's own positions)."""

        def scrub(path, pool):
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            nd = pool.ndim
            idx = [slice(None)] * nd
            idx[nd - 3 if "conv" in ps else nd - 4] = slice(slot, slot + 1)
            idx = tuple(idx)
            return pool.at[idx].set(jnp.zeros_like(pool[idx]))

        self.cache["layers"] = jax.tree_util.tree_map_with_path(
            scrub, self.cache["layers"])

    # ------------------------------------------------------ fault injection
    def _resolve_fault_slot(self, slot: int) -> Optional[int]:
        if slot >= 0:
            return slot if slot in self.active else None
        return min(self.active) if self.active else None

    def _inject_faults(self, logits: Array) -> Array:
        """Apply this tick's due state/logit faults (post-decode, pre-guard
        — the guard must see the corruption the same tick it lands)."""
        for ev in self.faults.due(
                ("nan_logits", "nan_state", "corrupt_word"), self._tick):
            slot = self._resolve_fault_slot(ev.slot)
            if slot is None:            # no live slot yet: fire next tick
                self.faults.defer(ev)
                continue
            if ev.kind == "corrupt_word" and self._corrupt_words(slot):
                continue
            if ev.kind == "nan_state" and self._corrupt_state(slot, ev.value):
                continue
            # nan_logits — and the fallback when a family has no float or
            # packed per-slot state to corrupt (e.g. qk_spiking is
            # stateless under a dense policy)
            logits = logits.at[slot].set(
                jnp.asarray(ev.value, logits.dtype))
        return logits

    def _corrupt_words(self, slot: int) -> bool:
        """Flip one packed spike-state word of a slot to all-ones (pad
        lanes included — guaranteed to violate the pad-lane invariant).
        False if the cache holds no packed word pool."""
        leaves, treedef = jax.tree_util.tree_flatten(self.cache["layers"])
        for i, leaf in enumerate(leaves):
            if leaf.dtype == jnp.int32 and leaf.ndim == 5 and leaf.size:
                idx = [0] * leaf.ndim
                idx[leaf.ndim - 4] = slot
                idx[-1] = leaf.shape[-1] - 1
                leaves[i] = leaf.at[tuple(idx)].set(jnp.int32(-1))
                self.cache["layers"] = jax.tree_util.tree_unflatten(
                    treedef, leaves)
                return True
        return False

    def _corrupt_state(self, slot: int, value: float) -> bool:
        """Poison one element of a slot's float state row (membrane / KV /
        SSM). False if the model keeps no float per-slot state."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self.cache["layers"])
        leaves = [leaf for _, leaf in flat]
        for i, (path, leaf) in enumerate(flat):
            if not (jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.size):
                continue
            ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            ax = leaf.ndim - (3 if "conv" in ps else 4)
            if ax < 0 or leaf.shape[ax] != self.cfg.max_slots:
                continue
            idx = [0] * leaf.ndim
            idx[ax] = slot
            leaves[i] = leaf.at[tuple(idx)].set(
                jnp.asarray(value, leaf.dtype))
            self.cache["layers"] = jax.tree_util.tree_unflatten(
                treedef, leaves)
            return True
        return False

    # ------------------------------------------------------ integrity guard
    def _integrity_verdict(self, logits: Array) -> tuple:
        """One jitted scan over (slot-pool cache, decode logits): per-slot
        ``(numeric_bad, packed_bad)`` bool vectors. Numeric = any non-finite
        in the slot's logits or float state rows; packed = any set bit in a
        packed word pool's PAD lanes (columns >= n_heads*head_dim — always
        zero for well-formed packed spike state)."""
        if self._guard_fn is None:
            nslots = self.cfg.max_slots
            try:
                d_logical = (self.model.cfg.n_heads *
                             self.model.cfg.resolved_head_dim)
            except AttributeError:
                d_logical = 0

            def scan(layers, lg):
                bad_num = ~jnp.isfinite(lg.astype(jnp.float32)) \
                    .reshape(nslots, -1).all(axis=1)
                bad_pack = jnp.zeros((nslots,), bool)
                flat = jax.tree_util.tree_flatten_with_path(layers)[0]
                for path, leaf in flat:
                    if not leaf.size:
                        continue
                    ps = "/".join(str(getattr(k, "key",
                                              getattr(k, "idx", k)))
                                  for k in path)
                    ax = leaf.ndim - (3 if "conv" in ps else 4)
                    if ax < 0 or leaf.shape[ax] != nslots:
                        continue
                    if jnp.issubdtype(leaf.dtype, jnp.floating):
                        fin = jnp.isfinite(leaf.astype(jnp.float32))
                        bad_num |= ~jnp.moveaxis(fin, ax, 0) \
                            .reshape(nslots, -1).all(axis=1)
                    elif leaf.dtype == jnp.int32 and leaf.ndim == 5 \
                            and d_logical > 0:
                        mask = jnp.asarray(pad_lane_mask(
                            d_logical, leaf.shape[-1]))
                        viol = (leaf & mask) != 0
                        bad_pack |= jnp.moveaxis(viol, ax, 0) \
                            .reshape(nslots, -1).any(axis=1)
                return bad_num, bad_pack

            self._guard_fn = jax.jit(scan)
        return self._guard_fn(self.cache["layers"], logits)

    def _quarantine(self, slot: int, reason: str) -> None:
        """Evict a slot whose state failed the integrity guard: scrub the
        poisoned row, free the slot, and requeue the request from scratch
        (front of the queue; greedy replay regenerates the identical
        stream, ``pushed`` suppresses re-delivery). Past the retry budget
        the request fails loudly instead."""
        req = self.active.pop(slot)
        self._release_slot(slot, scrub=True)
        self._quarantined += 1
        req.retries += 1
        if req.retries > self.cfg.quarantine_retries:
            self._finish(req, STATUS_FAILED)
            self._failed += 1
            return
        req.out = []
        req.slot = -1
        req.status = STATUS_QUEUED
        self.queue.appendleft(req)
        self._requeues += 1

    def _sample(self, logits: Array, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / req.temperature))

    # ------------------------------------------------------------------ tick
    def _stalled_slots(self) -> set:
        stalled = set()
        if self.faults is not None:
            for ev in self.faults.due("stall_consumer", self._tick):
                slot = self._resolve_fault_slot(ev.slot)
                if slot is None:
                    self.faults.defer(ev)
                    continue
                self._forced_stalls[slot] = self._tick + max(ev.ticks, 1)
        if self._forced_stalls:
            self._forced_stalls = {
                s: until for s, until in self._forced_stalls.items()
                if self._tick < until and s in self.active}
            stalled |= set(self._forced_stalls)
        if self.cfg.out_fifo_depth:
            stalled |= {slot for slot, req in self.active.items()
                        if len(req.fifo) >= self.cfg.out_fifo_depth}
        return stalled

    def step(self) -> int:
        """One engine tick: admit, drain up to ``prefill_chunks_per_tick``
        chunks from the prefill FIFO, then one pool decode for all live,
        un-stalled slots. Returns number of live sequences."""
        if self.faults is not None and self.faults.die_due(self._tick):
            raise ReplicaFailure(
                f"injected replica death at tick {self._tick}")
        self._deadline_sweep()
        self._admit()
        if self.cfg.prefill_chunk > 0:
            budget = max(1, self.cfg.prefill_chunks_per_tick)
            while budget > 0 and self.prefill_fifo:
                if self._prefill_step(self.prefill_fifo[0]):
                    self.prefill_fifo.popleft()
                budget -= 1
        if not self.active:
            return 0
        stalled = self._stalled_slots()
        self._tick += 1
        if stalled and len(stalled) == len(self.active):
            self._stall_ticks += 1
            return len(self.active)     # every consumer is backed up
        toks = np.zeros((self.cfg.max_slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out[-1]
        # per-slot length vector: every slot attends exactly its own prefix
        self.cache["len"] = jnp.asarray(self.slot_len, jnp.int32)
        prev_layers = self.cache["layers"] if stalled else None
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache)
        logits = jax.block_until_ready(logits)
        self._tick_wall.append(time.perf_counter() - t0)
        if self.faults is not None:
            # injected corruption lands AFTER the decode, BEFORE the guard
            # — the guard must catch it before a token is sampled from it
            logits = self._inject_faults(logits)
        bad = set()
        if self.cfg.integrity_every > 0 \
                and self._tick % self.cfg.integrity_every == 0:
            self._guard_scans += 1
            bad_num, bad_pack = self._integrity_verdict(logits)
            bad_num, bad_pack = np.asarray(bad_num), np.asarray(bad_pack)
            bad = {s for s in self.active
                   if bad_num[s] or bad_pack[s]}
            reasons = {s: ("packed_invariant" if bad_pack[s]
                           else "non_finite") for s in bad}
        if self._track_spikes and self._tick % self.cfg.spike_stats_every == 0:
            self._record_spike_step(sorted(self.active.keys()))
        if stalled:
            self._stall_ticks += 1
            for slot in stalled:
                # exact stall (greedy): state row rolls back, same token
                # re-fed next tick recomputes the identical step once the
                # FIFO drains; temperature sampling is only reproducible up
                # to the shared RNG stream's consumption order
                self._restore_slot(slot, prev_layers)
        for slot in sorted(bad):
            # quarantine BEFORE sampling: no token leaves a poisoned slot
            self._quarantine(slot, reasons[slot])
        done_slots = []
        for slot, req in list(self.active.items()):
            if slot in stalled:
                continue
            tok = self._sample(logits[slot], req)
            self._emit(req, tok)
            self.slot_len[slot] += 1
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.out) >= req.max_new \
                    or self.slot_len[slot] >= self.cfg.max_len - 1:
                self._finish(req, STATUS_DONE)
                done_slots.append(slot)
        for slot in done_slots:
            del self.active[slot]
            self.slot_len[slot] = 0
            self.free_slots.append(slot)
        return len(self.active)

    def pending(self) -> bool:
        """True while any pipeline stage still holds work (queued,
        prefilling, or decoding) — THE drain predicate; drive loops should
        use this instead of peeking at individual FIFOs."""
        return bool(self.active or self.queue or self.prefill_fifo)

    def _progress_signature(self) -> tuple:
        """Changes iff the pipeline made observable progress this tick."""
        return (self._tokens_emitted, self._prefill_chunks,
                len(self.finished), len(self.queue),
                len(self.prefill_fifo))

    def _stall_report(self) -> dict:
        return {
            "tick": self._tick,
            "queued": len(self.queue),
            "prefilling": [j.req.uid for j in self.prefill_fifo],
            "stuck_slots": {
                slot: {"uid": req.uid, "out_fifo": len(req.fifo),
                       "tokens": len(req.out), "status": req.status}
                for slot, req in sorted(self.active.items())},
            "free_slots": len(self.free_slots),
        }

    def run_until_drained(self, max_ticks: int = 10_000,
                          stall_grace: int = 200) -> list[Request]:
        """Tick until every request reaches a terminal state. Raises
        ``StalledEngine`` when work is pending but NO stage has progressed
        for ``stall_grace`` consecutive ticks (livelock — e.g. every live
        slot stalled on an output FIFO nobody drains), or when
        ``max_ticks`` runs out with work still pending; the silent-return
        of either case would hand the caller a partial result."""
        last, idle = None, 0
        for _ in range(max_ticks):
            self.step()
            if not self.pending():
                return self.finished
            sig = self._progress_signature()
            if sig == last:
                idle += 1
                if idle >= stall_grace:
                    rep = self._stall_report()
                    raise StalledEngine(
                        f"no progress for {idle} ticks with work pending: "
                        f"stuck slots {sorted(rep['stuck_slots'])}, "
                        f"{rep['queued']} queued, "
                        f"{len(rep['prefilling'])} prefilling "
                        f"(are the output FIFOs being drained?)", rep)
            else:
                last, idle = sig, 0
        rep = self._stall_report()
        raise StalledEngine(
            f"max_ticks={max_ticks} exhausted with work still pending: "
            f"stuck slots {sorted(rep['stuck_slots'])}, "
            f"{rep['queued']} queued", rep)

    def _record_spike_step(self, live_slots: list) -> None:
        """Measure one decode tick's spike activity straight off the PACKED
        per-slot spike state in the cache pool: popcount of the int32 words
        = spike count (the pad lanes are zero), words bytes = what actually
        crossed HBM for spike state this tick."""
        if not live_slots:
            return
        n_units = (self.model.cfg.n_heads *
                   self.model.cfg.resolved_head_dim)
        spikes = packed_b = units = 0
        for leaf in jax.tree_util.tree_leaves(self.cache["layers"]):
            if leaf.dtype != jnp.int32 or leaf.ndim != 5:
                continue                    # only the packed word pools
            sel = np.asarray(leaf)[:, live_slots]
            spikes += int(np.unpackbits(
                np.ascontiguousarray(sel).view(np.uint8)).sum())
            packed_b += sel.size * 4
            units += sel.shape[0] * len(live_slots) * n_units
        nz_words = blk_groups = blk_active = occ_words = 0
        for leaf in jax.tree_util.tree_leaves(self.cache["layers"]):
            if leaf.dtype != jnp.int32 or leaf.ndim != 5:
                continue
            sel = np.asarray(leaf)[:, live_slots]
            nz = (sel != 0).reshape(-1, sel.shape[-1])
            # group word columns into 128-column (4-word) metadata blocks:
            # the k-axis granularity of the gated kernels' vld/occ maps
            wpb = min(4, nz.shape[-1])
            g = nz.shape[-1] // wpb
            grp = nz[:, :g * wpb].reshape(-1, g, wpb)
            any_blk = grp.any(axis=-1)
            blk_groups += any_blk.size
            blk_active += int(any_blk.sum())
            occ_words += int(grp.sum())       # nonzero words (all in active)
            nz_words += wpb * int(any_blk.sum())  # words inside active blocks
        if units:
            entry = {
                "live": len(live_slots),
                "spike_rate": spikes / units,
                "packed_bytes": packed_b,
                "dense_bytes": units}         # the int8 maps it replaces
            if blk_groups:
                # feed the measured (block-active, word-occupancy) fractions
                # to the roofline autotuner: the "auto" policy's sparsity
                # hint for traced operands (one EWMA profile per engine)
                from ..ops.autotune import get_tuner

                active = blk_active / blk_groups
                occ = occ_words / max(nz_words, 1)
                entry["block_active_frac"] = active
                entry["word_occ_frac"] = occ
                get_tuner().observe(active, occ)
            self._spike_log.append(entry)

    def stats(self) -> dict:
        if not self.finished:
            return {}
        # timing/token aggregates cover the SUCCESSFUL completions only —
        # a cancelled request may never have produced a first token
        done = [r for r in self.finished if r.status == STATUS_DONE]
        ttft = [r.first_token_t - r.enqueued_t for r in done]
        lat = [r.finished_t - r.enqueued_t for r in done]
        toks = sum(len(r.out) for r in done)
        span = (max(r.finished_t for r in done)
                - min(r.enqueued_t for r in done)) if done else 0.0
        out = {"n": len(done),
               "n_terminal": len(self.finished),
               "ttft_mean_s": float(np.mean(ttft)) if ttft else 0.0,
               "latency_mean_s": float(np.mean(lat)) if lat else 0.0,
               "tokens": toks,
               "tok_per_s": toks / max(span, 1e-9),
               "queue_depth": len(self.queue),
               "active": len(self.active),
               "policy": self.policy.name,
               "spike_format": self.policy.format,
               # self-healing counters (tentpole: these are the fault
               # ledger callers alarm on)
               "ticks": self._tick,
               "cancelled": self._cancelled,
               "deadline_miss": self._deadline_miss,
               "quarantined": self._quarantined,
               "requeues": self._requeues,
               "failed": self._failed,
               "guard_scans": self._guard_scans,
               # elastic-FIFO telemetry: the software analogue of the
               # paper's FIFO-depth elasticity measurements
               "prefill_mode": ("chunked" if self.cfg.prefill_chunk > 0
                                else "blocking"),
               "prefill_chunks": self._prefill_chunks,
               "queue_hwm": self._queue_hwm,
               "prefill_fifo_hwm": self._prefill_fifo_hwm,
               "out_fifo_hwm": self._out_fifo_hwm,
               "stall_ticks": self._stall_ticks}
        if self._tick_wall:
            tw = np.asarray(self._tick_wall)
            out.update({
                "decode_ticks": len(tw),
                "decode_tick_p50_s": float(np.percentile(tw, 50)),
                "decode_tick_p99_s": float(np.percentile(tw, 99)),
                "decode_tick_max_s": float(tw.max())})
        if self._spike_log:
            rate = float(np.mean([e["spike_rate"] for e in self._spike_log]))
            pb = float(np.mean([e["packed_bytes"] for e in self._spike_log]))
            db = float(np.mean([e["dense_bytes"] for e in self._spike_log]))
            out.update({
                "decode_ticks_measured": len(self._spike_log),
                "spike_rate_mean": rate,
                "spike_sparsity_mean": 1.0 - rate,
                "packed_spike_bytes_per_tick_mean": pb,
                "dense_spike_bytes_per_tick_mean": db,
                "spike_state_hbm_reduction": db / max(pb, 1e-9)})
            af = [e["block_active_frac"] for e in self._spike_log
                  if "block_active_frac" in e]
            if af:
                out["block_active_frac_mean"] = float(np.mean(af))
        # the autotuner's live state: the observed-sparsity EWMA feeding
        # "auto" plans for traced operands, and every plan resolved so far
        from ..ops.autotune import get_tuner
        from ..ops import fallback

        out["autotune"] = get_tuner().snapshot()
        # fused->reference demotions (process-global; see ops.fallback)
        out["kernel_demotions"] = fallback.demotions()
        if self.faults is not None:
            out["fault_plan"] = self.faults.summary()
        return out
