"""Deterministic fault injection for the elastic-FIFO serving stack.

A ``FaultPlan`` is a seeded, replayable script of failures the serving
stack must survive — the software analogue of a chaos harness wired
directly into the engine's tick loop so every run of the same plan against
the same trace produces the SAME failure sequence (and therefore the same
recovery path, testable bit-for-bit):

  * ``nan_state(tick, slot)``   — write NaN/Inf into a live slot's cached
    membrane/KV state row (falls back to poisoning that slot's decode
    logits for families whose per-slot state is empty or integer-packed,
    e.g. qk_spiking);
  * ``nan_logits(tick, slot)``  — poison one slot's decode logits;
  * ``corrupt_word(tick, slot)``— flip a packed spike-state word to all
    ones, violating the pad-lane invariant the integrity guard checks;
  * ``kill_replica(tick)``      — the engine raises ``ReplicaFailure`` at
    the top of tick N (the router's failover machinery takes over);
  * ``stall_consumer(tick, slot, ticks)`` — freeze one slot's output
    consumer for a window, exercising the per-slot FIFO stall path;
  * ``fail_kernel(op, at_call)``— arm ``repro.ops.fallback`` so a chosen
    fused-kernel call raises, exercising fused->reference demotion.

Builders chain (each returns the plan). Tick-indexed events fire at the
first engine tick >= their tick; slot ``-1`` resolves to the lowest live
slot at fire time (events wait for a live slot). In a multi-replica
deployment, ``plan.view(r)`` slices the per-replica events for engine
``r`` — kernel faults are process-global (the ops registry is) and are
armed once by whoever owns the plan (Engine or ReplicaRouter).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class ReplicaFailure(RuntimeError):
    """A replica died mid-tick (injected, or a real engine-step crash
    re-raised as one). The ``ReplicaRouter`` catches this, marks the
    replica dead, and requeues its in-flight work."""


# late import path for the kernel-fault arm (keeps this module import-light)
def _ops_fallback():
    from ..ops import fallback

    return fallback


KINDS = ("nan_state", "nan_logits", "corrupt_word", "die",
         "stall_consumer", "kernel_fault")


@dataclasses.dataclass
class FaultEvent:
    kind: str
    tick: int = 0
    slot: int = -1          # -1 = lowest live slot when the event fires
    replica: int = 0
    value: float = float("nan")
    op: str = "*"           # kernel_fault: target op ("*" = any fused op)
    at_call: int = 0        # kernel_fault: which guarded call raises
    ticks: int = 1          # stall_consumer: stall window length
    fired: bool = False

    def describe(self) -> dict:
        d = {"kind": self.kind, "tick": self.tick, "replica": self.replica,
             "fired": self.fired}
        if self.kind in ("nan_state", "nan_logits", "corrupt_word",
                         "stall_consumer"):
            d["slot"] = self.slot
        if self.kind == "stall_consumer":
            d["ticks"] = self.ticks
        if self.kind == "kernel_fault":
            d.update(op=self.op, at_call=self.at_call)
        return d


class FaultPlan:
    """A seeded, ordered script of ``FaultEvent``s (see module docstring).
    The seed is recorded for provenance and reserved for randomized plan
    generators; the built-in events are fully deterministic."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.events: list[FaultEvent] = []
        self.rng = np.random.default_rng(self.seed)

    # ------------------------------------------------------------- builders
    def nan_state(self, tick: int, slot: int = -1, replica: int = 0,
                  value: float = float("nan")) -> "FaultPlan":
        self.events.append(FaultEvent("nan_state", tick=tick, slot=slot,
                                      replica=replica, value=value))
        return self

    def nan_logits(self, tick: int, slot: int = -1, replica: int = 0,
                   value: float = float("nan")) -> "FaultPlan":
        self.events.append(FaultEvent("nan_logits", tick=tick, slot=slot,
                                      replica=replica, value=value))
        return self

    def corrupt_word(self, tick: int, slot: int = -1,
                     replica: int = 0) -> "FaultPlan":
        self.events.append(FaultEvent("corrupt_word", tick=tick, slot=slot,
                                      replica=replica))
        return self

    def kill_replica(self, tick: int, replica: int = 0) -> "FaultPlan":
        self.events.append(FaultEvent("die", tick=tick, replica=replica))
        return self

    def stall_consumer(self, tick: int, slot: int = -1, ticks: int = 4,
                       replica: int = 0) -> "FaultPlan":
        self.events.append(FaultEvent("stall_consumer", tick=tick,
                                      slot=slot, ticks=ticks,
                                      replica=replica))
        return self

    def fail_kernel(self, op: str = "*", at_call: int = 0) -> "FaultPlan":
        self.events.append(FaultEvent("kernel_fault", op=op,
                                      at_call=at_call))
        return self

    # ------------------------------------------------------------ consumers
    def view(self, replica: int) -> "FaultPlan":
        """Per-replica slice for engine ``replica``: SHARES the event
        objects (fired flags propagate) but excludes kernel faults, which
        are process-global and armed by the plan's owner."""
        sub = FaultPlan(self.seed)
        sub.events = [ev for ev in self.events
                      if ev.replica == replica and ev.kind != "kernel_fault"]
        return sub

    def due(self, kinds, tick: int) -> list[FaultEvent]:
        """Pop (mark fired) every unfired event of the given kind(s) whose
        tick has arrived. A consumer that cannot apply an event yet (e.g.
        no live slot) calls ``defer(ev)`` to re-arm it for the next tick."""
        if isinstance(kinds, str):
            kinds = (kinds,)
        out = []
        for ev in self.events:
            if not ev.fired and ev.kind in kinds and ev.tick <= tick:
                ev.fired = True
                out.append(ev)
        return out

    @staticmethod
    def defer(ev: FaultEvent) -> None:
        ev.fired = False

    def die_due(self, tick: int) -> Optional[FaultEvent]:
        hits = self.due("die", tick)
        return hits[0] if hits else None

    def arm_kernel_faults(self) -> int:
        """Arm every kernel_fault event with ``repro.ops.fallback``
        (idempotent: each event arms once). Returns how many were armed."""
        n = 0
        for ev in self.events:
            if ev.kind == "kernel_fault" and not ev.fired:
                ev.fired = True
                _ops_fallback().arm_kernel_fault(ev.op, ev.at_call)
                n += 1
        return n

    # ------------------------------------------------------------ reporting
    def summary(self) -> dict:
        return {
            "seed": self.seed,
            "events": [ev.describe() for ev in self.events],
            "fired": sum(ev.fired for ev in self.events),
            "pending": sum(not ev.fired for ev in self.events),
        }

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return (f"FaultPlan(seed={self.seed}, events={len(self.events)}, "
                f"fired={sum(ev.fired for ev in self.events)})")


def demo_chaos_plan(seed: int = 0, *, n_replicas: int = 1,
                    kill_tick: int = 12, nan_ticks=(6, 9),
                    kernel_op: str = "dense_lif",
                    kernel_call: int = 0) -> FaultPlan:
    """The canned chaos scenario the benchmarks / examples / CI share:
    kill the last replica mid-trace (multi-replica only), two NaN
    injections on replica 0, and one forced fused-kernel failure."""
    plan = FaultPlan(seed)
    for t in nan_ticks:
        plan.nan_state(t, replica=0)
    if n_replicas > 1:
        plan.kill_replica(kill_tick, replica=n_replicas - 1)
    plan.fail_kernel(kernel_op, at_call=kernel_call)
    return plan
