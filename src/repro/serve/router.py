"""Data-parallel multi-replica serving: a least-loaded router over N engine
replicas whose slot pools shard across the local devices.

Each replica is a full ``Engine`` (own slot-pool cache, own elastic FIFOs)
placed on one device via the ``models.sharding`` replica-mesh helpers —
weights replicate, slot pools shard: the serving-side data-parallel axis.
Dispatch is least-loaded (queued + prefilling + active), lowest replica
index on ties, so a given arrival trace routes deterministically and
per-request outputs stay bit-identical to a single engine under greedy
decode (each replica's pool math is slot-count-independent).

Replica health + failover: a replica whose ``step()`` raises — an injected
``ReplicaFailure``, a real kernel crash — or whose tick wall latency trips
``health_latency_s`` is marked DEAD: ``submit`` stops routing to it, and
every one of its non-terminal requests (queued, prefilling, AND mid-decode)
is requeued onto the healthy replicas from the original prompt. Greedy
decode makes the replay bit-identical, and the per-uid delivered-token
ledger drops the replayed prefix the consumer already saw — at-most-once
delivery end to end (tokens sitting undelivered in the dead replica's
FIFOs are discarded and regenerated). Requests that FINISHED on a dead
replica stay readable. Only when the LAST replica dies does the failure
propagate to the caller.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..models.sharding import replica_meshes, replicate_params
from .engine import (Engine, EngineConfig, QueueFull, Request, StalledEngine,
                     TERMINAL)
from .faults import FaultPlan, ReplicaFailure


class AllReplicasDead(RuntimeError):
    """Every replica has failed: nothing can serve the pending work."""


class ReplicaRouter:
    def __init__(self, model, params, cfg: EngineConfig, n_replicas: int = 2,
                 devices: Optional[list] = None, rng_seed: int = 0,
                 faults: Optional[FaultPlan] = None,
                 health_latency_s: Optional[float] = None):
        assert n_replicas >= 1
        meshes = replica_meshes(n_replicas, devices)
        if faults is not None:
            faults.arm_kernel_faults()
        # per-replica rng offset: temperature sampling must not replay the
        # same stream on every replica (greedy decode is seed-independent)
        self.engines = [
            Engine(model, replicate_params(params, mesh), cfg,
                   rng_seed=rng_seed + i,
                   faults=faults.view(i) if faults is not None else None)
            for i, mesh in enumerate(meshes)]
        self.meshes = meshes
        self.faults = faults
        self.health_latency_s = health_latency_s
        self.alive = [True] * n_replicas
        self._dispatch = np.zeros(n_replicas, np.int64)
        self._by_uid: dict[int, tuple[int, int]] = {}   # uid -> (replica, local uid)
        self._uid = 0
        # failover bookkeeping
        self._meta: dict[int, dict] = {}       # uid -> original submit args
        self._delivered: dict[int, int] = {}   # uid -> tokens popped by caller
        self._skip: dict[int, int] = {}        # uid -> replayed prefix to drop
        self._orphans: list[int] = []          # uids awaiting re-dispatch
        self._failures: list[dict] = []
        self._requeued = 0

    # ------------------------------------------------------------- dispatch
    def _order(self) -> list[int]:
        """Alive replicas, least-loaded first (stable on ties)."""
        alive = [r for r in range(len(self.engines)) if self.alive[r]]
        return sorted(alive, key=lambda r: (self.engines[r].load(), r))

    def submit(self, prompt, max_new: int = 32, temperature: float = 0.0,
               eos_id=None, block: bool = True,
               deadline_ticks: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        """Least-loaded dispatch over the ALIVE replicas with router-level
        backpressure: if the chosen replica's admission FIFO is full, try
        the others before falling back to a blocking submit on the
        least-loaded one."""
        meta = dict(max_new=max_new, temperature=temperature, eos_id=eos_id,
                    deadline_ticks=deadline_ticks, deadline_s=deadline_s)
        order = self._order()
        if not order:
            raise AllReplicasDead("submit with no healthy replica")
        attempts = [(r, False) for r in order]
        if block:
            # every FIFO full: block on the LEAST-loaded replica — it is
            # the one whose backpressure ticks free a queue slot soonest
            attempts.append((order[0], True))
        for r, blocking in attempts:
            if not self.alive[r]:       # may have died mid-attempt list
                continue
            try:
                local = self.engines[r].submit(
                    prompt, block=blocking, **meta)
            except QueueFull:
                continue
            except ReplicaFailure as exc:
                # a blocking submit donates engine ticks, which can trip
                # an injected death — fail over and keep trying
                self._fail_replica(r, f"submit backpressure: {exc}")
                continue
            uid = self._uid
            self._uid += 1
            self._by_uid[uid] = (r, local)
            self._meta[uid] = dict(meta, prompt=np.asarray(prompt, np.int32))
            self._dispatch[r] += 1
            return uid
        raise QueueFull("every replica's admission FIFO is full")

    # ------------------------------------------------------------- failover
    def _fail_replica(self, r: int, reason: str) -> None:
        """Mark replica ``r`` dead and orphan its non-terminal requests for
        re-dispatch. The dead engine is never stepped again, so requests
        that already FINISHED there stay readable from its request map."""
        self.alive[r] = False
        dead = self.engines[r]
        self._failures.append({"replica": r, "tick": dead._tick,
                               "reason": reason, "t": time.time()})
        for uid, (rr, local) in sorted(self._by_uid.items()):
            if rr != r:
                continue
            req = dead.requests.get(local)
            if req is None or req.status in TERMINAL:
                continue                # fully served (or retired): keep
            # undelivered tokens in the dead FIFO are DISCARDED — the
            # replay regenerates them; the skip ledger only drops what the
            # consumer actually saw (at-most-once, no loss of the rest)
            req.fifo.clear()
            self._skip[uid] = self._delivered.get(uid, 0)
            self._orphans.append(uid)
        self._dispatch_orphans()

    def _dispatch_orphans(self) -> None:
        """Resubmit orphaned requests (prompt from the original submit) on
        healthy replicas, non-blocking — what does not fit now retries at
        the next step()."""
        still: list[int] = []
        for uid in self._orphans:
            placed = False
            for r in self._order():
                try:
                    local = self.engines[r].submit(
                        block=False, **self._meta[uid])
                except QueueFull:
                    continue
                self._by_uid[uid] = (r, local)
                self._dispatch[r] += 1
                self._requeued += 1
                placed = True
                break
            if not placed:
                still.append(uid)
        self._orphans = still

    # ------------------------------------------------------------ lifecycle
    def step(self) -> int:
        if self._orphans:
            self._dispatch_orphans()
        total = 0
        for r, e in enumerate(self.engines):
            if not self.alive[r]:
                continue
            others_alive = any(self.alive[i] for i in range(len(self.engines))
                               if i != r)
            t0 = time.perf_counter()
            try:
                total += e.step()
            except ReplicaFailure as exc:
                self._fail_replica(r, f"step raised: {exc}")
                continue
            except Exception as exc:
                if not others_alive:
                    raise       # nowhere to fail over to: surface the bug
                self._fail_replica(
                    r, f"step raised: {type(exc).__name__}: {exc}")
                continue
            dt = time.perf_counter() - t0
            if self.health_latency_s is not None \
                    and dt > self.health_latency_s:
                self._fail_replica(
                    r, f"tick latency {dt:.3f}s > health threshold "
                       f"{self.health_latency_s:.3f}s")
        return total

    def pending(self) -> bool:
        return bool(self._orphans) or any(
            e.pending() for r, e in enumerate(self.engines) if self.alive[r])

    def run_until_drained(self, max_ticks: int = 10_000,
                          stall_grace: int = 200) -> list[Request]:
        """Tick until drained. Raises ``StalledEngine`` on router-wide
        livelock (no replica progressed for ``stall_grace`` ticks with work
        pending) or tick-budget exhaustion, and ``AllReplicasDead`` when a
        failover leaves orphans with no healthy replica to take them."""
        last, idle = None, 0
        for _ in range(max_ticks):
            self.step()
            if not self.pending():
                return self.finished
            if self._orphans and not any(self.alive):
                raise AllReplicasDead(
                    f"{len(self._orphans)} requests orphaned and no "
                    f"healthy replica remains")
            sig = tuple(e._progress_signature() for e in self.engines) \
                + (len(self._orphans),)
            if sig == last:
                idle += 1
                if idle >= stall_grace:
                    reps = {r: e._stall_report()
                            for r, e in enumerate(self.engines)
                            if self.alive[r]}
                    raise StalledEngine(
                        f"router made no progress for {idle} ticks with "
                        f"work pending (alive={self.alive}, "
                        f"orphans={len(self._orphans)})",
                        {"replicas": reps, "orphans": list(self._orphans)})
            else:
                last, idle = sig, 0
        raise StalledEngine(
            f"max_ticks={max_ticks} exhausted with work still pending "
            f"(alive={self.alive})",
            {"replicas": {r: e._stall_report()
                          for r, e in enumerate(self.engines)},
             "orphans": list(self._orphans)})

    @property
    def finished(self) -> list[Request]:
        """Finished requests re-keyed to ROUTER uids (each engine numbers
        its own requests from 0, so replica-local uids collide across the
        pool — callers must never see them). Includes requests that
        finished on a now-dead replica; each uid appears exactly once."""
        by_local = [{req.uid: req for req in e.finished}
                    for e in self.engines]
        out = []
        for uid, (r, local) in sorted(self._by_uid.items()):
            req = by_local[r].get(local)
            if req is not None:
                out.append(dataclasses.replace(req, uid=uid))
        return out

    def result(self, uid: int) -> Optional[Request]:
        entry = self._by_uid.get(uid)
        if entry is None:
            return None
        r, local = entry
        return self.engines[r].requests.get(local)

    def pop_output(self, uid: int) -> list[int]:
        r, local = self._by_uid[uid]
        toks = self.engines[r].pop_output(local)
        skip = self._skip.get(uid, 0)
        if skip:
            # failover replay: drop the regenerated prefix the consumer
            # already received from the dead replica
            drop = min(skip, len(toks))
            toks = toks[drop:]
            self._skip[uid] = skip - drop
        if toks:
            self._delivered[uid] = self._delivered.get(uid, 0) + len(toks)
        return toks

    def cancel(self, uid: int) -> bool:
        entry = self._by_uid.get(uid)
        if entry is None:
            return False
        if uid in self._orphans:
            self._orphans.remove(uid)
            return True
        r, local = entry
        return self.engines[r].cancel(local)

    def stats(self) -> dict:
        per = [e.stats() for e in self.engines]
        toks = sum(p.get("tokens", 0) for p in per)
        return {
            "replicas": len(self.engines),
            "alive": list(self.alive),
            "failovers": len(self._failures),
            "failures": [dict(f) for f in self._failures],
            "requeued": self._requeued,
            "orphans": len(self._orphans),
            "dispatch": self._dispatch.tolist(),
            "devices": [str(m.devices.ravel()[0]) for m in self.meshes],
            "tokens": toks,
            "n": sum(p.get("n", 0) for p in per),
            "queue_hwm": max((p.get("queue_hwm", 0) for p in per), default=0),
            "prefill_fifo_hwm": max((p.get("prefill_fifo_hwm", 0)
                                     for p in per), default=0),
            "per_replica": per,
        }
