"""Data-parallel multi-replica serving: a least-loaded router over N engine
replicas whose slot pools shard across the local devices.

Each replica is a full ``Engine`` (own slot-pool cache, own elastic FIFOs)
placed on one device via the ``models.sharding`` replica-mesh helpers —
weights replicate, slot pools shard: the serving-side data-parallel axis.
Dispatch is least-loaded (queued + prefilling + active), lowest replica
index on ties, so a given arrival trace routes deterministically and
per-request outputs stay bit-identical to a single engine under greedy
decode (each replica's pool math is slot-count-independent).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..models.sharding import replica_meshes, replicate_params
from .engine import Engine, EngineConfig, QueueFull, Request


class ReplicaRouter:
    def __init__(self, model, params, cfg: EngineConfig, n_replicas: int = 2,
                 devices: Optional[list] = None, rng_seed: int = 0):
        assert n_replicas >= 1
        meshes = replica_meshes(n_replicas, devices)
        # per-replica rng offset: temperature sampling must not replay the
        # same stream on every replica (greedy decode is seed-independent)
        self.engines = [
            Engine(model, replicate_params(params, mesh), cfg,
                   rng_seed=rng_seed + i)
            for i, mesh in enumerate(meshes)]
        self.meshes = meshes
        self._dispatch = np.zeros(n_replicas, np.int64)
        self._by_uid: dict[int, tuple[int, int]] = {}   # uid -> (replica, local uid)
        self._uid = 0

    # ------------------------------------------------------------- dispatch
    def submit(self, prompt, max_new: int = 32, temperature: float = 0.0,
               eos_id=None, block: bool = True) -> int:
        """Least-loaded dispatch with router-level backpressure: if the
        chosen replica's admission FIFO is full, try the others before
        falling back to a blocking submit on the least-loaded one."""
        order = list(np.argsort([e.load() for e in self.engines],
                                kind="stable"))
        attempts = [(r, False) for r in order]
        if block:
            # every FIFO full: block on the LEAST-loaded replica — it is
            # the one whose backpressure ticks free a queue slot soonest
            attempts.append((order[0], True))
        for r, blocking in attempts:
            try:
                local = self.engines[r].submit(
                    prompt, max_new=max_new, temperature=temperature,
                    eos_id=eos_id, block=blocking)
            except QueueFull:
                continue
            uid = self._uid
            self._uid += 1
            self._by_uid[uid] = (r, local)
            self._dispatch[r] += 1
            return uid
        raise QueueFull("every replica's admission FIFO is full")

    # ------------------------------------------------------------ lifecycle
    def step(self) -> int:
        return sum(e.step() for e in self.engines)

    def pending(self) -> bool:
        return any(e.pending() for e in self.engines)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            self.step()
            if not self.pending():
                break
        return self.finished

    @property
    def finished(self) -> list[Request]:
        """Finished requests re-keyed to ROUTER uids (each engine numbers
        its own requests from 0, so replica-local uids collide across the
        pool — callers must never see them)."""
        by_local = [{req.uid: req for req in e.finished}
                    for e in self.engines]
        out = []
        for uid, (r, local) in sorted(self._by_uid.items()):
            req = by_local[r].get(local)
            if req is not None:
                out.append(dataclasses.replace(req, uid=uid))
        return out

    def result(self, uid: int) -> Optional[Request]:
        entry = self._by_uid.get(uid)
        if entry is None:
            return None
        r, local = entry
        return self.engines[r].requests.get(local)

    def pop_output(self, uid: int) -> list[int]:
        r, local = self._by_uid[uid]
        return self.engines[r].pop_output(local)

    def stats(self) -> dict:
        per = [e.stats() for e in self.engines]
        toks = sum(p.get("tokens", 0) for p in per)
        return {
            "replicas": len(self.engines),
            "dispatch": self._dispatch.tolist(),
            "devices": [str(m.devices.ravel()[0]) for m in self.meshes],
            "tokens": toks,
            "n": sum(p.get("n", 0) for p in per),
            "queue_hwm": max((p.get("queue_hwm", 0) for p in per), default=0),
            "prefill_fifo_hwm": max((p.get("prefill_fifo_hwm", 0)
                                     for p in per), default=0),
            "per_replica": per,
        }
