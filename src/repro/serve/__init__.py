from .engine import Engine, EngineConfig, QueueFull, Request
from .router import ReplicaRouter
