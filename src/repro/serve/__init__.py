from .engine import Engine, Request, EngineConfig
