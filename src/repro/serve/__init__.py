from .engine import (Engine, EngineConfig, QueueFull, Request,
                     StalledEngine, clear_jit_cache)
from .faults import FaultPlan, ReplicaFailure, demo_chaos_plan
from .router import AllReplicasDead, ReplicaRouter

__all__ = [
    "Engine", "EngineConfig", "QueueFull", "Request", "StalledEngine",
    "clear_jit_cache", "FaultPlan", "ReplicaFailure", "demo_chaos_plan",
    "AllReplicasDead", "ReplicaRouter",
]
