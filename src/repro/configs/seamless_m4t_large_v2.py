"""seamless-m4t-large-v2 [audio] — 24L enc + 24L dec, d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206 — enc-dec, multimodal. [arXiv:2308.11596; hf]

Frontend STUB per the brief: input_specs supplies precomputed audio-frame
embeddings [B, S, d_src]. The window-2 frame downsampling stage is the
paper-C2 hook (spike-count pooling in spiking mode).
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    d_src=1024,
    vision_pool_window=2,       # frame downsampling (C2 stage)
    rope_theta=1e4,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    remat="dots",
)
