"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16) d_ff=1024 (per expert)
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    moe_d_ff=1024,
    n_shared_experts=0,
    capacity_factor=1.25,
    rope_theta=1e4,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    remat="none",
)
