from .base import ModelConfig, SHAPES, ShapeSpec, cells_for
from .registry import ARCHS, build_model, get_config, reduced
