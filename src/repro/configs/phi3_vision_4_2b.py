"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (STUB: precomputed patch
embeddings via input_specs). [hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision patch merge (2x2) is the paper-C2 hook: in spiking mode the patch
embeddings pool by spike-count (W2TTFS / WTFC datapath) instead of averaging.
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    tie_embeddings=False,
    n_img_tokens=1024,          # raw CLIP patches (32x32 grid)
    d_vision=1024,              # CLIP-L hidden size
    vision_pool_window=2,       # 2x2 merge -> 256 image tokens (C2 stage)
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    remat="dots",
)
