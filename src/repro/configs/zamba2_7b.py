"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 backbone + weight-SHARED attention blocks.
[arXiv:2411.15242]

Adaptation note (DESIGN §Arch-applicability): real Zamba2 alternates two
shared blocks roughly every 6 mamba layers; we deploy ONE shared block every
``attn_every=9`` layers so the 81-layer stack divides into 9 homogeneous
scan groups (9 shared-attention sites) — same parameter-sharing idea, scan-
friendly structure.
"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,                 # shared attention block's MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,               # d_inner = 7168 -> 112 heads of 64
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=128,
    ssm_ngroups=1,
    attn_every=9,
    rope_theta=1e4,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    remat="full",
)
