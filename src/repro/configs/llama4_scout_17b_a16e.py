"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                  # shared-expert / dense width
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    capacity_factor=1.25,
    rope_theta=5e5,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    remat="dots",
)
