"""mamba2-130m [ssm] — 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,            # d_inner = 1536 -> 24 heads of 64
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=128,
    ssm_ngroups=1,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    remat="full",
)
