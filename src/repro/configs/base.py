"""Config schema for the model zoo + shape grid.

Every assigned architecture is a ``ModelConfig``; the paper's SNN features
(spiking mode, QK attention, quantization — C1..C4) are first-class flags on
the same config, so any arch can be run as an ANN baseline or a spiking
variant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from ..core.lif import LIFConfig
from ..core.quant import QuantConfig
from ..ops.compat import legacy_flags_policy
from ..ops.policy import REFERENCE, ExecutionPolicy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0        # llama4-style always-on shared expert
    moe_group_size: int = 512        # GShard dispatch group (tokens)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # --- hybrid (zamba2) ---
    attn_every: int = 0              # shared attention applied every k layers
    # --- enc-dec (seamless-m4t) ---
    n_enc_layers: int = 0
    d_src: int = 0                   # precomputed frontend embedding dim
    # --- vlm (phi-3-vision) ---
    n_img_tokens: int = 0
    d_vision: int = 0
    vision_pool_window: int = 0      # >0: W2TTFS patch pooling (C2) applies
    # --- paper technique flags ---
    spiking: bool = False            # LIF activations (C3), KD-student mode
    attention_kind: str = "softmax"  # softmax | qk_spiking (C4)
    # policy: how the qk_spiking path executes (repro.ops.ExecutionPolicy
    # or a preset name). "reference" (the None default) is the pure-jnp
    # path; "fused_dense" routes the LIF projections and binary-activation
    # matmuls through the fused-PE / spike_matmul Pallas kernels
    # (deployed inference); "fused_packed" additionally ships every spike
    # tensor bit-packed (32/int32 lane + popcount vld_cnt, ~8x fewer spike
    # bytes) and caches the per-token spike state packed — all three are
    # bit-identical in emitted spikes. Training works under ANY of them:
    # a differentiable policy (``for_training()`` / a "+grad" preset, what
    # launch/train.py --policy requests) keeps the chosen forward and
    # swaps in the surrogate-gradient custom_vjp backward. Read via
    # ``cfg.exec_policy``.
    policy: Optional[Any] = None     # ExecutionPolicy | preset name | None
    # deprecated flag pair -> policy (repro.ops.compat translates + warns)
    use_event_kernels: Optional[bool] = None
    spike_format: Optional[str] = None
    lif: LIFConfig = LIFConfig()
    quant: QuantConfig = QuantConfig()
    # --- numerics / perf knobs (hillclimb surface) ---
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = jnp.float32
    remat: str = "none"              # none | full | dots
    attn_q_block: int = 1024
    attn_kv_block: int = 1024
    flash_threshold: int = 8192      # use chunked attention above this seq len
    scan_layers: bool = True
    # dp_over_model: batch also shards over the 'model' axis (pure-DP/FSDP
    # regime for small archs — weights become ZeRO-3 shards gathered on use)
    dp_over_model: bool = False
    loss_chunk: int = 0              # >0: compute CE over seq chunks (memory)
    # seq_shard: Megatron-SP — activations at block boundaries shard the
    # SEQUENCE dim over 'model'; GSPMD turns the TP all-reduce into
    # reduce-scatter + all-gather and the saved scan carry shrinks /TP
    seq_shard: bool = False
    # decode_cp_axis: shard the decode KV cache's SEQUENCE dim over this
    # mesh axis ('model' pairs with GQA kv-heads that don't divide TP;
    # 'data' is the long-context batch=1 setting). "" = batch-sharded cache.
    decode_cp_axis: str = ""
    # kv_dtype: "" = activation dtype; "f8_e4m3" stores the KV cache in FP8
    # (2x decode HBM traffic cut — the paper's FP8 deployment theme applied
    # to serving)
    kv_dtype: str = ""

    def __post_init__(self):
        # validate + warn on the deprecated flag pair ONCE at construction
        # (dataclasses.replace round-trips re-run this, which is correct:
        # each construction that still passes legacy flags is a legacy use)
        resolved = legacy_flags_policy(
            "ModelConfig", self.policy, self.use_event_kernels,
            self.spike_format)
        if self.policy is not None:
            # normalize preset names so configs hash/compare consistently
            # (they key jit caches in the serving engine)
            object.__setattr__(self, "policy", resolved)

    @property
    def exec_policy(self) -> ExecutionPolicy:
        """The resolved ExecutionPolicy (legacy flags translated; default
        "reference")."""
        pol = legacy_flags_policy(
            "ModelConfig", self.policy, self.use_event_kernels,
            self.spike_format, warn=False)
        return pol if pol is not None else REFERENCE

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def prefill_chunk_align(self) -> int:
        """Chunked-prefill granularity this family supports while staying
        bit-identical to a blocking prefill. Attention pads are causal-inert
        so any chunk size works; the SSD scan's intra-chunk cumsums change
        with the chunk partition, so ssm/hybrid chunks must land on
        ``ssm_chunk`` boundaries for the chunked scan to decompose exactly
        into the blocking one."""
        return self.ssm_chunk if self.family in ("ssm", "hybrid") else 1

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs whose attention is quadratic-full -> long_500k is skipped (brief rule)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cells_for(cfg: ModelConfig) -> list[str]:
    """The shape cells that apply to an architecture (skips recorded)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in SUBQUADRATIC_FAMILIES or cfg.attention_kind == "qk_spiking":
        out.append("long_500k")
    return out
