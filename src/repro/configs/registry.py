"""Architecture registry: ``--arch <id>`` resolution for every launcher.

``get_config(id)`` returns the full production ModelConfig; ``reduced(cfg)``
derives the family-preserving smoke-test config (small layers/width/experts,
tiny vocab) used by tests/CPU examples — the FULL configs are only exercised
through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .base import ModelConfig, SHAPES, ShapeSpec, cells_for
from . import (llama4_scout_17b_a16e, mamba2_130m, olmoe_1b_7b,
               phi3_vision_4_2b, qwen1_5_32b, qwen2_5_3b, qwen3_1_7b,
               seamless_m4t_large_v2, yi_9b, zamba2_7b)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        qwen1_5_32b.CONFIG,
        qwen3_1_7b.CONFIG,
        qwen2_5_3b.CONFIG,
        yi_9b.CONFIG,
        mamba2_130m.CONFIG,
        phi3_vision_4_2b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        olmoe_1b_7b.CONFIG,
        zamba2_7b.CONFIG,
        seamless_m4t_large_v2.CONFIG,
    ]
}


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def build_model(cfg: ModelConfig):
    """cfg -> model object exposing init/loss/prefill/decode_step/input_specs."""
    from ..models.encdec import EncDecLM
    from ..models.lm import LM
    return EncDecLM(cfg) if cfg.family == "encdec" else LM(cfg)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving miniature for CPU smoke tests."""
    import jax.numpy as jnp
    small = dict(
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
        remat="none",
        attn_q_block=64,
        attn_kv_block=64,
    )
    if cfg.n_heads:
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))  # GQA ratio kept
        small["n_heads"] = 4
        small["n_kv_heads"] = max(1, 4 // ratio)
    if cfg.family == "moe":
        small.update(n_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=64,
                     n_shared_experts=cfg.n_shared_experts)
    if cfg.family in ("ssm", "hybrid"):
        small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16, ssm_conv=4)
    if cfg.family == "hybrid":
        small.update(attn_every=2)
    if cfg.family == "vlm":
        small.update(n_img_tokens=16, d_vision=32, vision_pool_window=2)
    if cfg.family == "encdec":
        small.update(n_enc_layers=2, d_src=32)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
