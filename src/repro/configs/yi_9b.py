"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-style GQA. [arXiv:2403.04652; hf]"""
import jax.numpy as jnp

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=1e4,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    remat="dots",
)
