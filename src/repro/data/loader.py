"""Host-sharded batch loading: numpy on host -> globally-sharded jax.Array.

On a real multi-host pod each process builds only ITS shard
(``jax.make_array_from_process_local_data``); in this single-process
container the same API degrades to a device_put with the target sharding.
The shard-index plumbing is what the elastic runtime re-wires on failure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.sharding import dp_axes


def shard_batch(batch: Any, mesh: Mesh) -> Any:
    """numpy pytree -> jax.Array pytree sharded (batch dim over DP axes)."""
    dp = dp_axes(mesh)

    def put(x):
        x = np.asarray(x)
        spec = [None] * x.ndim
        if x.ndim:
            spec[0] = dp
        sh = NamedSharding(mesh, P(*spec))
        if jax.process_count() > 1:
            return jax.make_array_from_process_local_data(sh, x)
        return jax.device_put(x, sh)

    return jax.tree_util.tree_map(put, batch)


@dataclasses.dataclass
class ShardedLoader:
    """Deterministic per-step loader with elastic shard re-assignment.

    ``shard_of_host`` maps this host to its data shard; after a pod failure
    the elastic runtime calls ``reassign`` with the surviving host set and
    every batch from then on is drawn from the remapped shard — the same
    (step, shard) pairs always produce the same data (replay-safe).
    """
    make_np_batch: Callable[[int, int, int, int], Any]  # (step, bs, shard, n)
    global_batch: int
    mesh: Mesh
    n_shards: int = 1
    shard: int = 0

    def reassign(self, shard: int, n_shards: int) -> None:
        self.shard = shard
        self.n_shards = n_shards

    def __call__(self, step: int) -> Any:
        np_batch = self.make_np_batch(step, self.global_batch, self.shard,
                                      self.n_shards)
        return shard_batch(np_batch, self.mesh)
