from .synthetic import (SyntheticTokenDataset, SyntheticImageDataset,
                        token_batches, image_batches)
from .loader import ShardedLoader, shard_batch
