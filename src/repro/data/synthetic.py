"""Synthetic datasets (offline container — no downloads).

Design requirements these satisfy:
  * DETERMINISTIC as a function of (seed, step, shard) — the elastic runtime
    re-assigns shards after a pod failure and must replay identical data;
    the straggler mitigator re-balances shards the same way.
  * LEARNABLE — both datasets carry real structure (Markov bigram chains for
    tokens; class-conditional means for images) so the CPU examples and the
    KD pipeline show monotone loss curves, not noise.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticTokenDataset:
    """Markov bigram language: next-token depends on current token through a
    fixed random transition table with temperature — compressible structure
    an LM can learn."""
    vocab_size: int
    seq_len: int
    seed: int = 0
    branching: int = 8          # candidate successors per token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.table = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branching))

    def batch(self, step: int, batch_size: int, shard: int = 0,
              n_shards: int = 1) -> np.ndarray:
        """[batch_size, seq_len] int32 — unique per (step, shard)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard * 7)
        toks = np.empty((batch_size, self.seq_len), np.int32)
        cur = rng.integers(0, self.vocab_size, size=batch_size)
        toks[:, 0] = cur
        choices = rng.integers(0, self.branching,
                               size=(batch_size, self.seq_len - 1))
        for t in range(1, self.seq_len):
            cur = self.table[cur, choices[:, t - 1]]
            toks[:, t] = cur
        return toks


@dataclasses.dataclass
class SyntheticImageDataset:
    """CIFAR-like: class-conditional Gaussian blobs + noise. Linearly
    separable enough that the KD pipeline's accuracy ordering (paper Fig 8)
    reproduces on CPU-sized budgets."""
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    seed: int = 0
    noise: float = 0.6

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.means = rng.normal(
            0.0, 1.0, size=(self.num_classes, self.image_size,
                            self.image_size, self.channels)).astype(np.float32)

    def batch(self, step: int, batch_size: int, shard: int = 0,
              n_shards: int = 1) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard * 7 + 13)
        labels = rng.integers(0, self.num_classes, size=batch_size)
        imgs = self.means[labels] + rng.normal(
            0.0, self.noise, size=(batch_size, self.image_size,
                                   self.image_size, self.channels)
        ).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)


def token_batches(ds: SyntheticTokenDataset, batch_size: int,
                  start_step: int = 0, shard: int = 0,
                  n_shards: int = 1) -> Iterator[np.ndarray]:
    step = start_step
    while True:
        yield ds.batch(step, batch_size, shard, n_shards)
        step += 1


def image_batches(ds: SyntheticImageDataset, batch_size: int,
                  start_step: int = 0, shard: int = 0,
                  n_shards: int = 1) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield ds.batch(step, batch_size, shard, n_shards)
        step += 1
