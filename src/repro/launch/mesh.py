"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.

Production topology (TPU v5e numbers):
  single pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
The 'pod' axis only ever carries data parallelism + cross-pod gradient
reduction — model/expert sharding stays intra-pod (ICI), which is what makes
the 2-pod extension DCN-feasible.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def _auto(axes):
    # jax.sharding.AxisType only exists on newer jax; older releases have
    # implicitly-Auto axes and make_mesh has no axis_types kwarg
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * len(axes)


def _make_mesh(shape, axes, devices) -> Mesh:
    kinds = _auto(axes)
    if kinds is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices, axis_types=kinds)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run under launch/dryrun.py (which forces 512 host devices) or "
            "on real hardware")
    return _make_mesh(shape, axes, devices[:need])


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over host devices for unit tests (requires the test to
    set --xla_force_host_platform_device_count)."""
    need = math.prod(shape)
    return _make_mesh(shape, axes, jax.devices()[:need])


def make_elastic_mesh(n_pods_alive: int, *, pod_shape=(16, 16)) -> Mesh:
    """Degraded multi-pod mesh after pod failures (elastic re-mesh): same
    (data, model) inner shape, 'pod' axis shrunk to the surviving pods."""
    shape = (n_pods_alive, *pod_shape)
    axes = ("pod", "data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices for elastic mesh {shape}")
    return _make_mesh(shape, axes, devices[:need])
