import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jit(step).lower(**ShapeDtypeStructs).compile()`` against the production
mesh forces GSPMD to resolve every sharding, insert every collective, and
plan per-device buffers. Failures here (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system.

Per cell we record to JSON:
  * compiled.memory_analysis()  — per-device bytes (proves it fits)
  * compiled.cost_analysis()    — XLA's flops/bytes (while bodies counted 1x)
  * hlo_analysis.analyze()      — trip-count-aware flops / bytes / collective
                                  wire-bytes parsed from compiled.as_text()
  * analytic MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference)

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--outdir experiments/dryrun]
``--all`` runs each cell in a FRESH subprocess (compile-state isolation) and
skips cells whose JSON already exists.
"""
import argparse
import dataclasses
import json
import math
import subprocess
import sys
import time
import traceback
from pathlib import Path

DEFAULT_OUTDIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cells(mesh_modes: list[str]):
    from ..configs import ARCHS, cells_for
    out = []
    for name, cfg in ARCHS.items():
        for shape in cells_for(cfg):
            for mesh in mesh_modes:
                out.append((name, shape, mesh))
    return out


# --------------------------------------------------------------- single cell
def run_cell(arch: str, shape_name: str, mesh_mode: str, outdir: Path,
             overrides: dict | None = None, tag: str = "",
             microbatch: int = 0) -> dict:
    import jax
    import jax.numpy as jnp

    from ..analysis.abstract import module_param_shapes, optimizer_shapes
    from ..configs import SHAPES, get_config, build_model
    from ..models import sharding as shd
    from ..optim import adamw_init, adamw_update, clip_by_global_norm
    from . import hlo_analysis
    from .mesh import make_production_mesh

    t0 = time.time()
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_mode == "multi"))
    shd.set_global_mesh(mesh)
    shd.set_dp_includes_model(cfg.dp_over_model)
    NS = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))

    specs = model.input_specs(shape)
    # shared shape-walking implementation with the static contract verifier
    # (repro.analysis.abstract): failures name the callee + operand avals
    params_shape = module_param_shapes(model.init)
    p_shard = NS(shd.param_specs(params_shape, mesh))

    with mesh:
        if shape.kind == "train":
            from ..optim.adamw import AdamWState
            opt_shape = optimizer_shapes(adamw_init, params_shape)
            z1 = shd.zero1_specs(params_shape, mesh)
            o_shard = NS(AdamWState(step=jax.sharding.PartitionSpec(),
                                    m=z1, v=z1))
            b_shard = NS(shd.batch_specs(specs["batch"], mesh))

            def train_step(params, opt_state, batch):
                if microbatch and microbatch > 1:
                    from ..train.trainer import _split_microbatches
                    micro = _split_microbatches(batch, microbatch)
                    # pin the accumulator to the PARAM sharding — otherwise
                    # GSPMD propagates the optimizer's ZeRO-1 layout into the
                    # loop and reshards the accumulator every microbatch
                    pin = lambda t: jax.lax.with_sharding_constraint(t, p_shard)

                    def body(acc, mb):
                        (loss, metrics), grads = jax.value_and_grad(
                            model.loss, has_aux=True)(params, mb)
                        acc = jax.tree_util.tree_map(
                            lambda a, g: a + g.astype(jnp.float32) / microbatch,
                            acc, grads)
                        return pin(acc), metrics

                    zeros = pin(jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))
                    grads, metricss = jax.lax.scan(body, zeros, micro)
                    metrics = jax.tree_util.tree_map(jnp.mean, metricss)
                else:
                    (loss, metrics), grads = jax.value_and_grad(
                        model.loss, has_aux=True)(params, batch)
                # barrier: stop XLA sinking the optimizer's f32 converts into
                # the backward scan (f32 grad carries + f32 weight gathers)
                grads = jax.lax.optimization_barrier(grads)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                new_p, new_o = adamw_update(grads, opt_state, params,
                                            lr=3e-4, weight_decay=0.1)
                metrics = dict(metrics, grad_norm=gnorm)
                return new_p, new_o, metrics

            met_shard = None
            fn = jax.jit(train_step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, met_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_shape, opt_shape, specs["batch"])
        elif shape.kind == "prefill":
            b_shard = NS(shd.batch_specs(specs["batch"], mesh))
            fn = jax.jit(model.prefill, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(params_shape, specs["batch"])
        else:  # decode
            cache_shape = specs["cache"]
            c_shard = NS(shd.cache_specs(
                cache_shape, mesh, batch=shape.global_batch,
                context_parallel=(shape.name == "long_500k"),
                seq_axis=cfg.decode_cp_axis or None))
            t_shard = NS(shd.batch_specs({"t": specs["tokens"]}, mesh))["t"]
            fn = jax.jit(model.decode_step,
                         in_shardings=(p_shard, t_shard, c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,))
            lowered = fn.lower(params_shape, specs["tokens"], cache_shape)

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_d[f] = getattr(mem, f, None)
    ca = compiled.cost_analysis() or {}
    ca_d = {k: v for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "utilization operand", "optimal_seconds")}

    hlo_text = compiled.as_text()
    hlo = hlo_analysis.analyze(hlo_text)
    # keep the optimized HLO (gzip) so perf iterations can re-analyze
    # without recompiling
    import gzip
    hlo_dir = outdir.parent / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    suffix0 = f"__{tag}" if tag else ""
    (hlo_dir / f"{arch}__{shape_name}__{mesh_mode}{suffix0}.hlo.gz"
     ).write_bytes(gzip.compress(hlo_text.encode()))

    n_chips = math.prod(mesh.devices.shape)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_mode, "tag": tag,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "n_chips": n_chips,
        "mesh_shape": dict(zip(mesh.axis_names,
                               mesh.devices.shape)),
        "lower_s": round(t_lower - t0, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "memory_analysis": mem_d,
        "cost_analysis": ca_d,
        "hlo": hlo,
        "model_flops": analytic_model_flops(cfg, params_shape, shape),
        "param_count": param_count(params_shape),
        "active_param_count": active_param_count(cfg, params_shape),
    }
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = outdir / f"{arch}__{shape_name}__{mesh_mode}{suffix}.json"
    path.write_text(json.dumps(result, indent=1))
    print(f"[dryrun] OK {arch} {shape_name} {mesh_mode} "
          f"lower={result['lower_s']}s compile={result['compile_s']}s "
          f"-> {path}")
    return result


# ----------------------------------------------------------- analytic flops
def param_count(params_shape) -> int:
    import jax
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(params_shape))


def active_param_count(cfg, params_shape) -> int:
    """Non-embedding params, MoE experts scaled by top_k/n_experts."""
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        n = math.prod(leaf.shape)
        if "emb" in ps:
            continue
        if any(w in ps for w in ("w_gate", "w_up", "w_down")):
            n = n * cfg.top_k // max(cfg.n_experts, 1)
        total += n
    return total


def analytic_model_flops(cfg, params_shape, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference),
    GLOBAL (all chips). D = processed tokens."""
    n = active_param_count(cfg, params_shape)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token per seq


# ------------------------------------------------------------------- driver
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--outdir", type=Path, default=DEFAULT_OUTDIR)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="result-file suffix (perf runs)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override k=v (python literal)")
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list or args.all:
        cells = _cells(meshes)
    if args.list:
        for c in cells:
            print(*c)
        return

    if args.all:
        failures = []
        for arch, shape, mesh in cells:
            suffix = f"__{args.tag}" if args.tag else ""
            path = args.outdir / f"{arch}__{shape}__{mesh}{suffix}.json"
            if path.exists() and not args.force:
                print(f"[dryrun] skip (exists) {arch} {shape} {mesh}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--outdir", str(args.outdir)]
            if args.tag:
                cmd += ["--tag", args.tag]
            for ov in args.override:
                cmd += ["--override", ov]
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch, shape, mesh))
                print(f"[dryrun] FAIL {arch} {shape} {mesh}")
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        import ast
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    try:
        run_cell(args.arch, args.shape, args.mesh, args.outdir,
                 overrides or None, args.tag, microbatch=args.microbatch)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
