"""Roofline report: dry-run JSONs -> three-term analysis + markdown table.

Hardware model (TPU v5e, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s

All inputs from hlo_analysis are PER-DEVICE (post-SPMD module), so:

  compute_term    = hlo_flops_per_dev / 197e12                [s]
  memory_term     = hlo_bytes_per_dev / 819e9                 [s]
  collective_term = wire_bytes_per_dev / 50e9                 [s]

``bound`` is the largest term. Two quality ratios:
  useful_ratio      = MODEL_FLOPS / (chips * hlo_flops_per_dev)
                      (how much compiled compute is "useful" — catches
                      remat/redundancy waste)
  roofline_fraction = (MODEL_FLOPS / chips / peak) / max(terms)
                      (fraction of the modeled step spent on useful math if
                      compute/memory/comms overlapped perfectly — the score
                      the perf loop drives UP)

Usage:
  python -m repro.launch.roofline [--dir experiments/dryrun] [--mesh single]
         [--tag TAG] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e
# Per-core VMEM capacity (the Pallas tile budget). The static contract
# verifier (repro.analysis.contracts, rule NL-VMEM-BUDGET) prices every
# kernel family's declared BlockSpec residency against this before
# anything runs on hardware.
VMEM_BYTES = 16 * 2**20      # ~16 MB/core

# -- kernel-level cost-model constants (the sparsity-adaptive autotuner) --
# Fixed per-pallas_call cost (grid setup, scalar prefetch, launch): keeps
# the model honest on tiny shapes, where the reference jnp path wins.
LAUNCH_OVERHEAD_S = 2e-6
# Extra metadata pass for the gated grid (compact_kmap over the vld map) —
# tiny, but nonzero, so "gated" never wins at sparsity ~0 on equal bytes.
GATING_OVERHEAD_S = 0.5e-6
# MXU efficiency of the two-level sub-tile dots: a (128, 32) @ (32, 128)
# stripe underfills the 128x128 systolic pipeline, so per-stripe FLOPs run
# at a fraction of peak. Two-level only wins when word occupancy is LOW.
SUBTILE_MXU_EFF = 0.35

DEFAULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def spike_matmul_traffic(m: int, k: int, n: int, *,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, active_frac: float = 1.0,
                         occ_frac: float = 1.0, packed: bool = False,
                         skip: str = "dense", kernels: str = "fused") -> dict:
    """Streaming HBM-traffic + FLOP model of one spike matmul / fused_pe
    accumulation sweep, per byte-skip strategy.

    This counts bytes AS THE KERNEL STREAMS THEM — one x and one w tile
    DMA'd per visited grid step — not unique tensor bytes: the Pallas grid
    re-fetches an x row-tile for every n-block and a w tile for every
    m-block, which is exactly the traffic the vld-gated grid removes for
    silent blocks. ``active_frac`` is the fraction of non-silent
    (block_m x block_k) tiles (1 - block sparsity); ``occ_frac`` the
    fraction of occupied 32-column stripes within active tiles.

    Returns {"hbm_bytes", "flops", "mxu_eff"} — feed to ``kernel_time_s``.
    """
    gm, gn, gk = -(-m // block_m), -(-n // block_n), -(-k // block_k)
    x_tile = block_m * block_k // 8 if packed else block_m * block_k
    w_tile = block_k * block_n * 4
    out_bytes = gm * gn * block_m * block_n * 4
    if kernels == "reference":
        # XLA fuses the dense matmul: unique bytes, full FLOPs, no launch
        # overhead modeled (but no block skip either)
        x_bytes = gm * gk * (block_m * block_k // 8 if packed
                             else block_m * block_k)
        return {"hbm_bytes": x_bytes + gk * gn * w_tile + out_bytes,
                "flops": 2.0 * m * n * k, "mxu_eff": 1.0,
                "overhead_s": 0.0}
    meta_bytes = 4 * gm * gk                      # vld map
    if skip == "dense":
        steps = gm * gn * gk                      # every tile streams
        flops = 2.0 * m * n * k * active_frac     # MXU still skips
        eff = 1.0
        overhead = LAUNCH_OVERHEAD_S
    else:
        # ≥1 tile per (m-row, n-block): a fully silent row still fetches
        # its revisit target once. Continuous in active_frac so modeled
        # bytes order strictly with sparsity (the CI regression guard).
        steps = gm * gn * max(active_frac * gk, 1.0)
        flops = 2.0 * m * n * k * active_frac
        eff = 1.0
        overhead = LAUNCH_OVERHEAD_S + GATING_OVERHEAD_S
        meta_bytes += 4 * gm * (gk + 1)           # kmap + nact
        if skip == "two_level":
            flops = 2.0 * m * n * k * active_frac * occ_frac
            eff = SUBTILE_MXU_EFF
            meta_bytes += 4 * gm * gk             # occ bitmap
    return {"hbm_bytes": steps * (x_tile + w_tile) + out_bytes + meta_bytes,
            "flops": flops, "mxu_eff": eff, "overhead_s": overhead}


def spike_matmul_grad_traffic(m: int, k: int, n: int, *,
                              block_m: int = 128, block_n: int = 128,
                              block_k: int = 128, active_frac: float = 1.0,
                              occ_frac: float = 1.0, packed: bool = False,
                              skip: str = "dense",
                              kernels: str = "fused") -> dict:
    """Streaming HBM-traffic + FLOP model of the BACKWARD of one spike
    matmul / fused_pe accumulation sweep (the event-skipped custom_vjp),
    per byte-skip strategy.

    Two sweeps, priced together:

      dx = (g ⊙ surr') @ wᵀ   — dense: the incoming cotangent ``g`` is a
           float activation gradient, not a spike map, so no vld grid
           exists on its reduction axis (= the forward's N) and nothing
           pins the schedule — priced at UNIQUE tensor bytes (a
           revisit-minimal tiling, the same convention as the reference
           row), plus one read of the cached membrane-current tile (the
           residual the forward emitted) for the in-kernel surrogate
           factor, in place of the recompute-from-x pass the jnp
           fallback would run.
      dw = xᵀ @ dv            — event-skipped: the forward operand's vld
           map transposes onto dw's REDUCTION axis (m), so silent x
           tiles skip exactly as in the forward. Pinned to the metadata
           grid, hence priced STREAMING like the forward fused model.
           ``skip`` gates this sweep only; ``active_frac`` is the
           forward operand's active-block fraction.

    ``kernels="reference"`` prices the jnp autodiff backward instead:
    unique-byte dense sweeps plus the surrogate recompute's extra read
    of x and w (no residual cache). Returns the same
    {"hbm_bytes", "flops", "mxu_eff", "overhead_s"} dict as the forward
    model plus per-sweep byte splits — feed to ``kernel_time_s``.
    """
    gm, gn, gk = -(-m // block_m), -(-n // block_n), -(-k // block_k)
    g_tile = block_m * block_n * 4
    w_tile = block_k * block_n * 4
    x_tile = block_m * block_k // 8 if packed else block_m * block_k
    cur_bytes = gm * gn * block_m * block_n * 4      # cached residual
    dx_out = gm * gk * block_m * block_k * 4
    dw_out = gk * gn * block_k * block_n * 4
    if kernels == "reference":
        # jnp autodiff: unique bytes, both sweeps dense, plus the
        # surrogate recompute re-streams x and w (no residual cache)
        recompute = gm * gk * x_tile + gk * gn * w_tile
        dx_bytes = m * n * 4 + k * n * 4 + dx_out
        dw_bytes = (gm * gk * x_tile) + m * n * 4 + dw_out
        return {"hbm_bytes": dx_bytes + dw_bytes + recompute,
                "dx_hbm_bytes": dx_bytes + recompute,
                "dw_hbm_bytes": dw_bytes,
                "flops": 4.0 * m * n * k, "mxu_eff": 1.0,
                "overhead_s": 0.0}
    # dx: unique g and w bytes (revisit-minimal schedule — no metadata
    # grid constrains it), plus ONE cached-current read per (m, n) tile
    # for the fused surrogate factor
    dx_bytes = (gm * gn * g_tile + gk * gn * w_tile) + dx_out + cur_bytes
    dx_flops = 2.0 * m * n * k
    overhead = 2 * LAUNCH_OVERHEAD_S                 # two pallas sweeps
    meta_bytes = 4 * gm * gk                         # forward vld map
    if skip == "dense":
        dw_steps = gk * gn * gm
        dw_flops = 2.0 * m * n * k * active_frac     # MXU still skips
        eff = 1.0
    else:
        # ≥1 visited m-tile per (k-row, n-block), continuous in
        # active_frac so modeled bytes order strictly with sparsity
        dw_steps = gk * gn * max(active_frac * gm, 1.0)
        dw_flops = 2.0 * m * n * k * active_frac
        eff = 1.0
        overhead += GATING_OVERHEAD_S
        meta_bytes += 4 * gk * (gm + 1)              # transposed kmap+nact
        if skip == "two_level":
            dw_flops *= occ_frac
            eff = SUBTILE_MXU_EFF
            meta_bytes += 4 * gm * gk                # occ bitmap
    dw_bytes = dw_steps * (x_tile + g_tile) + dw_out + meta_bytes
    # dx always runs full-width tiles; only dw's sub-tile stripes underfill
    # the MXU. Blend into one effective rate so kernel_time_s stays exact:
    # time = dx_flops/peak + dw_flops/(peak*eff) = total/(peak*eff_blend).
    total_flops = dx_flops + dw_flops
    weighted = dx_flops + dw_flops / max(eff, 1e-3)
    return {"hbm_bytes": dx_bytes + dw_bytes,
            "dx_hbm_bytes": dx_bytes, "dw_hbm_bytes": dw_bytes,
            "flops": total_flops, "mxu_eff": total_flops / weighted,
            "overhead_s": overhead}


def qk_chain_traffic(tokens: int, d_model: int, heads: int, head_dim: int,
                     kv_heads: int | None = None, *, packed: bool = False,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 128, active_frac: float = 1.0) -> dict:
    """HBM byte model of the spiking QK attention chain (Fig 5): the FUSED
    head-blocked write-back vs the COMPOSED projections + outside-mask
    path the fusion replaces.

    fused    : two fused_pe passes (wq, wk) whose K pass re-streams the Q
               spike map once for the in-kernel per-head row sums and
               emits the MASKED map directly. Grouped KV (kv_heads <
               heads) expands the K projection's WEIGHT columns, so no
               per-token KV replica exists.
    composed : both projections emit UNMASKED spike maps to HBM, a mask
               pass re-reads Q and K and writes the masked map, and
               grouped KV first materializes the replicated [tokens,
               heads*head_dim] copy (one write + one read — the
               ``_expand_kv`` round trip).

    The composed extras scale with ``tokens`` (per-token spike maps); the
    fused GQA weight expansion streams more WEIGHT tile bytes instead —
    the trade pays whenever the head width stays within the same number
    of n-blocks (every reduced config here) or sparsity gates the sweep.
    ``packed`` prices the spike maps at 1 bit/spike. Returns
    {"fused_hbm_bytes", "composed_hbm_bytes", ...} for BENCH rows.
    """
    hkv = heads if kv_heads is None else kv_heads
    nq = heads * head_dim
    spike_bytes = (1 / 8) if packed else 1.0

    def proj(n_cols: int) -> float:
        return spike_matmul_traffic(
            tokens, d_model, n_cols, block_m=block_m, block_n=block_n,
            block_k=block_k, active_frac=active_frac, packed=packed,
            skip="dense")["hbm_bytes"]

    q_map = tokens * nq * spike_bytes
    k_grouped_map = tokens * hkv * head_dim * spike_bytes
    k_expanded_map = tokens * nq * spike_bytes

    fused = proj(nq) + proj(nq) + q_map
    composed = (proj(nq) + proj(hkv * head_dim)
                + q_map + k_grouped_map + k_expanded_map)
    if hkv != heads:
        composed += 2 * k_expanded_map      # the _expand_kv round trip
    return {"fused_hbm_bytes": fused, "composed_hbm_bytes": composed,
            "tokens": tokens, "d_model": d_model, "heads": heads,
            "head_dim": head_dim, "kv_heads": hkv, "packed": packed}


def kernel_time_s(traffic: dict) -> float:
    """Roofline time of one modeled kernel: max(compute, memory) + fixed
    overhead. The same three-term logic as ``analyze_cell``, at kernel
    granularity (no collectives inside one chip)."""
    compute = traffic["flops"] / (PEAK_FLOPS * max(traffic["mxu_eff"], 1e-3))
    memory = traffic["hbm_bytes"] / HBM_BW
    return max(compute, memory) + traffic.get("overhead_s", 0.0)


def analyze_cell(rec: dict) -> dict:
    n = rec["n_chips"]
    hlo = rec["hlo"]
    compute = hlo["flops"] / PEAK_FLOPS
    memory = hlo["bytes"] / HBM_BW
    collective = hlo["collective_wire_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    bound = max(terms, key=terms.get)
    useful = rec["model_flops"] / max(n * hlo["flops"], 1e-30)
    ideal = rec["model_flops"] / n / PEAK_FLOPS
    frac = ideal / max(max(terms.values()), 1e-30)
    mem = rec.get("memory_analysis", {})
    hbm = (mem.get("argument_size_in_bytes", 0) or 0) + \
          (mem.get("temp_size_in_bytes", 0) or 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "bound": bound,
        "model_flops": rec["model_flops"],
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hbm_bytes": hbm,
        "fits": hbm <= HBM_PER_CHIP,
        "step_time_s": max(terms.values()),
        "collectives": hlo.get("collectives", {}),
    }


def suggestion(cell: dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = cell["bound"]
    colls = cell.get("collectives", {})
    big = max(colls.items(), key=lambda kv: kv[1]["wire_bytes"])[0] \
        if colls else "none"
    if b == "collective":
        return (f"collective-bound (top op: {big}) — reshard to cut {big} "
                "volume (more DP / fewer TP boundaries, or overlap via "
                "collective-matmul)")
    if b == "memory":
        if cell["useful_ratio"] < 0.5:
            return ("memory-bound with low useful-FLOP ratio — remove "
                    "redundant passes (remat policy / fusion) before "
                    "touching layout")
        return ("memory-bound — increase arithmetic intensity: larger "
                "per-device batch, fused kernels, lower-precision "
                "weights/KV (int8)")
    return ("compute-bound — already at the right wall; chase MXU "
            "utilization (tile alignment, bf16 accumulation) and overlap "
            "the remaining comms")


def load(dirpath: Path, mesh: str | None, tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(dirpath.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        if (rec.get("tag") or "") != tag:
            continue
        cells.append(analyze_cell(rec))
    return cells


def markdown(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| bound | useful | roofline frac | fits 16G |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | **{c['bound']}** "
            f"| {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} "
            f"| {'yes' if c['fits'] else 'NO'} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", type=Path, default=DEFAULT_DIR)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", type=Path, default=None)
    ap.add_argument("--suggest", action="store_true")
    args = ap.parse_args()

    cells = load(args.dir, args.mesh, args.tag)
    print(markdown(cells))
    if args.suggest:
        print()
        for c in cells:
            print(f"- {c['arch']} x {c['shape']} ({c['mesh']}): "
                  f"{suggestion(c)}")
    if args.json:
        args.json.write_text(json.dumps(cells, indent=1))


if __name__ == "__main__":
    main()
