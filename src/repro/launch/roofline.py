"""Roofline report: dry-run JSONs -> three-term analysis + markdown table.

Hardware model (TPU v5e, per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s

All inputs from hlo_analysis are PER-DEVICE (post-SPMD module), so:

  compute_term    = hlo_flops_per_dev / 197e12                [s]
  memory_term     = hlo_bytes_per_dev / 819e9                 [s]
  collective_term = wire_bytes_per_dev / 50e9                 [s]

``bound`` is the largest term. Two quality ratios:
  useful_ratio      = MODEL_FLOPS / (chips * hlo_flops_per_dev)
                      (how much compiled compute is "useful" — catches
                      remat/redundancy waste)
  roofline_fraction = (MODEL_FLOPS / chips / peak) / max(terms)
                      (fraction of the modeled step spent on useful math if
                      compute/memory/comms overlapped perfectly — the score
                      the perf loop drives UP)

Usage:
  python -m repro.launch.roofline [--dir experiments/dryrun] [--mesh single]
         [--tag TAG] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e

DEFAULT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def analyze_cell(rec: dict) -> dict:
    n = rec["n_chips"]
    hlo = rec["hlo"]
    compute = hlo["flops"] / PEAK_FLOPS
    memory = hlo["bytes"] / HBM_BW
    collective = hlo["collective_wire_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    bound = max(terms, key=terms.get)
    useful = rec["model_flops"] / max(n * hlo["flops"], 1e-30)
    ideal = rec["model_flops"] / n / PEAK_FLOPS
    frac = ideal / max(max(terms.values()), 1e-30)
    mem = rec.get("memory_analysis", {})
    hbm = (mem.get("argument_size_in_bytes", 0) or 0) + \
          (mem.get("temp_size_in_bytes", 0) or 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "bound": bound,
        "model_flops": rec["model_flops"],
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hbm_bytes": hbm,
        "fits": hbm <= HBM_PER_CHIP,
        "step_time_s": max(terms.values()),
        "collectives": hlo.get("collectives", {}),
    }


def suggestion(cell: dict) -> str:
    """One sentence on what would move the dominant term down."""
    b = cell["bound"]
    colls = cell.get("collectives", {})
    big = max(colls.items(), key=lambda kv: kv[1]["wire_bytes"])[0] \
        if colls else "none"
    if b == "collective":
        return (f"collective-bound (top op: {big}) — reshard to cut {big} "
                "volume (more DP / fewer TP boundaries, or overlap via "
                "collective-matmul)")
    if b == "memory":
        if cell["useful_ratio"] < 0.5:
            return ("memory-bound with low useful-FLOP ratio — remove "
                    "redundant passes (remat policy / fusion) before "
                    "touching layout")
        return ("memory-bound — increase arithmetic intensity: larger "
                "per-device batch, fused kernels, lower-precision "
                "weights/KV (int8)")
    return ("compute-bound — already at the right wall; chase MXU "
            "utilization (tile alignment, bf16 accumulation) and overlap "
            "the remaining comms")


def load(dirpath: Path, mesh: str | None, tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(dirpath.glob("*.json")):
        rec = json.loads(p.read_text())
        if mesh and rec["mesh"] != mesh:
            continue
        if (rec.get("tag") or "") != tag:
            continue
        cells.append(analyze_cell(rec))
    return cells


def markdown(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| bound | useful | roofline frac | fits 16G |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | **{c['bound']}** "
            f"| {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} "
            f"| {'yes' if c['fits'] else 'NO'} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", type=Path, default=DEFAULT_DIR)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", type=Path, default=None)
    ap.add_argument("--suggest", action="store_true")
    args = ap.parse_args()

    cells = load(args.dir, args.mesh, args.tag)
    print(markdown(cells))
    if args.suggest:
        print()
        for c in cells:
            print(f"- {c['arch']} x {c['shape']} ({c['mesh']}): "
                  f"{suggestion(c)}")
    if args.json:
        args.json.write_text(json.dumps(cells, indent=1))


if __name__ == "__main__":
    main()
