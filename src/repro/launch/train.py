"""End-to-end training driver.

Two regimes from one entry point:
  * CPU / laptop:  ``--reduced`` trains a miniature of any assigned arch on
    synthetic data and prints a real loss curve (examples use this).
  * Cluster:       full config on the production mesh (the dry-run proves
    the program compiles; this driver is what you'd actually launch).

Features wired in: microbatching, checkpoint/restart (+async), straggler
monitoring, elastic re-mesh on failure (--simulate-failure exercises the
whole failure path end-to-end), optional spiking/QKFormer modes.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --batch 8 --seq 128 [--spiking] [--simulate-failure 20]
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--spiking", action="store_true")
    ap.add_argument("--qk-attention", action="store_true",
                    help="paper C4: spiking QKFormer attention")
    ap.add_argument("--policy", default=None,
                    choices=["reference", "fused_dense", "fused_packed"],
                    help="execution policy for the spiking layers "
                         "(repro.ops.ExecutionPolicy); the training step "
                         "resolves it through its gradient axis, so "
                         "--policy fused_dense trains the forward on the "
                         "event-driven kernels it deploys on")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="inject a device failure at this step (elastic path)")
    ap.add_argument("--compress", action="store_true",
                    help="int8+error-feedback DP gradient compression "
                         "(pure-DP shard_map path, no elastic runner)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    from ..configs import get_config, reduced as reduce_cfg, build_model
    from ..data import ShardedLoader, SyntheticTokenDataset
    from ..models import sharding as shd
    from ..optim import linear_warmup_cosine
    from ..train import (ElasticRunner, make_train_step, train_state_init,
                         TrainState)
    from ..train.elastic import ElasticConfig
    from jax.sharding import Mesh

    overrides = {}
    if args.spiking:
        overrides["spiking"] = True
    if args.qk_attention:
        overrides["attention_kind"] = "qk_spiking"
    if args.policy:
        if not args.spiking:
            ap.error("--policy requires --spiking (execution policies "
                     "govern the spiking layers)")
        # a training driver always wants the gradient axis: forward runs
        # the chosen kernels, backward gets the surrogate custom_vjp
        overrides["policy"] = args.policy + "+grad"
    cfg = get_config(args.arch, **overrides)
    if args.reduced:
        cfg = reduce_cfg(cfg, **overrides)
    model = build_model(cfg)
    schedule = linear_warmup_cosine(args.lr, args.warmup, args.steps)

    n_dev = len(jax.devices())

    def mesh_full():
        return jax.make_mesh((n_dev,), ("data",))

    def mesh_half():
        return jax.make_mesh((max(n_dev // 2, 1),), ("data",),
                             devices=jax.devices()[:max(n_dev // 2, 1)])

    ds = SyntheticTokenDataset(cfg.vocab_size, args.seq + 1)

    def make_np_batch(step, bs, shard, n_shards):
        return {"tokens": ds.batch(step, bs, shard, n_shards)}

    if args.compress:
        import jax.numpy as jnp
        from ..optim import error_feedback_init
        from ..train import make_compressed_train_step
        mesh = mesh_full()
        params = model.init(jax.random.PRNGKey(0))
        from ..train import train_state_init
        step_fn = jax.jit(make_compressed_train_step(model, mesh,
                                                     schedule=schedule))
        carry = (train_state_init(params), error_feedback_init(params))
        t0 = time.time()
        with mesh:
            for i in range(args.steps):
                batch = {"tokens": jnp.asarray(make_np_batch(
                    i, args.batch, 0, 1)["tokens"])}
                carry, m = step_fn(carry, batch)
                if i % args.log_every == 0:
                    print(f"step {i}: loss={float(m['loss']):.4f} "
                          f"(int8+EF compressed DP)")
        dt = time.time() - t0
        print(f"[train] compressed-DP done: {args.steps} steps in {dt:.1f}s")
        return

    def make_step(mesh):
        step = make_train_step(model, schedule=schedule,
                               microbatch=args.microbatch)
        return jax.jit(step, donate_argnums=(0,))

    def make_state(mesh):
        params = model.init(jax.random.PRNGKey(0))
        return train_state_init(params)

    def state_shardings(state_shape, mesh):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), state_shape)

    loader = ShardedLoader(make_np_batch, args.batch, mesh_full())
    runner = ElasticRunner(
        [mesh_full, mesh_half], make_step, make_state, state_shardings,
        loader, ElasticConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every))
    if args.simulate_failure:
        runner.inject_failure(args.simulate_failure)

    t0 = time.time()
    state, events = runner.run(args.steps)
    dt = time.time() - t0
    print(f"[train] {args.arch} done: {int(state.step)} steps in {dt:.1f}s "
          f"({int(state.step) / dt:.2f} steps/s)")
    for e in events:
        print("[event]", e)


if __name__ == "__main__":
    main()
