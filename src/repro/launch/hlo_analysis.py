"""Static analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but our models scan over layers — a 64-layer model's per-step FLOPs would be
undercounted 64x. This module parses ``compiled.as_text()``, recovers the
computation call graph (while bodies x trip count, fusion bodies x1), and
produces trip-count-aware totals:

  * flops             — 2*M*N*K summed over every dot (+conv approx)
  * bytes             — HBM-traffic proxy: sum of (operands + result) sizes
                        over materializing top-level ops (fusion internals
                        excluded — they live in registers/VMEM)
  * collectives       — per-op kind / wire-bytes / group size, using ring
                        cost models (all-reduce moves 2(n-1)/n bytes, etc.)

All shapes in a post-SPMD module are PER-DEVICE, so every number reported
here is per-device per-step; the roofline layer divides by per-chip peak
rates directly.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s4": 1, "u4": 1, "f8e3m4": 1,
    "f4e2m1fn": 1, "f8e8m0fnu": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]\{\},]+)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_TRIP_RE = re.compile(r'known_trip_count[\\\"]*:\s*\{[\\\"]*n[\\\"]*:\s*[\\\"]*(\d+)')

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "opt-barrier", "partition-id",
              "replica-id", "iota", "while", "conditional", "reshape",
              "transpose"}
# ops that READ only a slice / write in place — counting their full operands
# would overcount HBM traffic by the stacked-layer factor
_SLICING_OPS = {"dynamic-slice", "gather", "slice"}
_INPLACE_OPS = {"dynamic-update-slice", "scatter"}
# unary elementwise ops chased through when resolving slice/DUS chains
_UNARY_PASS = {"convert", "bitcast", "copy", "reshape", "transpose",
               "negate"}


def shape_dims(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) arrays inside a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def type_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(s) for dt, s in shape_dims(type_str))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list
    sig_types: dict                 # param name -> type string
    param_overrides: dict = dataclasses.field(default_factory=dict)
    root_override: Optional[float] = None


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith(("HloModule", "//", "#")):
            continue
        if line == "}":
            cur = None
            continue
        if "= " not in line.split("(")[0] and line.endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                sig = {}
                for part in m.group(3).split(","):
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        sig[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(m.group(2), bool(m.group(1)), [], sig)
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4),
                                    is_root=line.startswith("ROOT ")))
    return comps


def _callee(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(instr: Instr, comps: dict) -> int:
    m = _TRIP_RE.search(instr.rest)
    if m:
        return int(m.group(1))
    cond = _callee(instr.rest, "condition")
    if cond and cond in comps:
        consts = []
        for i in comps[cond].instrs:
            if i.opcode == "constant":
                m = re.match(r"(\d+)\)", i.rest)
                if m:
                    consts.append(int(m.group(1)))
        if consts:
            return max(consts)
    return 1


def _multiplicities(comps: dict) -> tuple[dict, set]:
    """Times each computation executes per step + the set of 'fused'
    computations (fusion/to_apply bodies — no HBM traffic of their own)."""
    mult: dict[str, float] = defaultdict(float)
    fused: set[str] = set()
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {}, set()
    mult[entry.name] = 1.0
    # topological-ish worklist
    work = [entry.name]
    seen_edges = set()
    while work:
        cname = work.pop()
        comp = comps[cname]
        base = mult[cname]
        for ins in comp.instrs:
            callees = []
            if ins.opcode == "while":
                body = _callee(ins.rest, "body")
                cond = _callee(ins.rest, "condition")
                t = _trip_count(ins, comps)
                if body:
                    callees.append((body, t, False))
                if cond:
                    callees.append((cond, t + 1, True))
            else:
                for key in ("calls", "to_apply"):
                    cal = _callee(ins.rest, key)
                    if cal:
                        callees.append((cal, 1, True))
            for cal, k, is_fused in callees:
                if cal not in comps:
                    continue
                edge = (cname, cal, ins.name)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                mult[cal] += base * k
                if is_fused and ins.opcode != "while":
                    fused.add(cal)
                work.append(cal)
    return dict(mult), fused


def _dot_flops(ins: Instr, name2type: dict) -> float:
    out_elems = sum(math.prod(s) for _, s in shape_dims(ins.type_str))
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    ops = re.findall(r"%([\w\.\-]+)", ins.rest.split(")", 1)[0])
    k = 1
    if m and ops:
        lhs_type = name2type.get(ops[0])
        if lhs_type:
            dims = shape_dims(lhs_type)
            if dims:
                shape = dims[0][1]
                for d in (int(x) for x in m.group(1).split(",") if x):
                    if d < len(shape):
                        k *= shape[d]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, name2type: dict) -> float:
    out_elems = sum(math.prod(s) for _, s in shape_dims(ins.type_str))
    ops = re.findall(r"%([\w\.\-]+)", ins.rest.split(")", 1)[0])
    k = 1
    if len(ops) >= 2:
        rhs = name2type.get(ops[1])
        if rhs:
            dims = shape_dims(rhs)
            if dims:
                shape = dims[0][1]
                # kernel = [..spatial.., Cin, Cout]-ish; divide out Cout≈last
                k = max(1, math.prod(shape) // max(shape[-1], 1))
    return 2.0 * out_elems * k


def _collective_wire_bytes(kind: str, out_bytes: int, n: int) -> float:
    """Per-device wire bytes under ring algorithms."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * out_bytes
    if kind == "all-gather":
        return (n - 1) / n * out_bytes          # out is the gathered size
    if kind == "reduce-scatter":
        return (n - 1) * out_bytes              # out is the shard
    if kind == "all-to-all":
        return (n - 1) / n * out_bytes
    return float(out_bytes)                     # collective-permute


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _operands(ins: Instr) -> list[str]:
    return re.findall(r"%([\w\.\-]+)", ins.rest.split(")", 1)[0])


def _param_read_overrides(comp: Computation) -> dict[int, float]:
    """For a fusion body: parameters whose ONLY uses are slicing ops read
    just the slices (not the full tensor); parameters consumed only as the
    in-place target of dynamic-update-slice are aliased (0 read bytes).
    Returns {param_index: bytes}."""
    pidx: dict[str, int] = {}
    uses: dict[str, list[Instr]] = defaultdict(list)
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            # rest is everything after "parameter(" — the index leads it
            m = re.match(r"(\d+)\)", ins.rest)
            if m:
                pidx[ins.name] = int(m.group(1))
        else:
            for o in _operands(ins):
                uses[o].append(ins)

    def terminal_uses(name, depth=0):
        """Chase through unary elementwise ops to the real consumers,
        keeping track of which name reaches each consumer."""
        outs = []
        for u in uses.get(name, []):
            if u.opcode in _UNARY_PASS and depth < 6:
                outs.extend(terminal_uses(u.name, depth + 1))
            else:
                outs.append((u, name))
        return outs

    out: dict[int, float] = {}
    for name, idx in pidx.items():
        tus = terminal_uses(name)
        if not tus:
            continue
        if all(u.opcode in _SLICING_OPS for u, _ in tus):
            out[idx] = float(sum(type_bytes(u.type_str) for u, _ in tus))
        elif all(u.opcode == "dynamic-update-slice"
                 and _operands(u) and _operands(u)[0] == via
                 for u, via in tus):
            out[idx] = 0.0                    # aliased in-place target
    return out


def _root_write_override(comp: Computation) -> Optional[float]:
    """If a fusion's root is (a unary-elementwise chain over) a
    dynamic-update-slice, the write traffic is the UPDATE size, not the
    whole (aliased) output buffer."""
    local_types = dict(comp.sig_types)
    defs: dict[str, Instr] = {}
    root: Optional[Instr] = None
    for ins in comp.instrs:
        local_types[ins.name] = ins.type_str
        defs[ins.name] = ins
        if ins.is_root:
            root = ins
    if root is None:
        return None
    r = root
    hops = 0
    while r.opcode in _UNARY_PASS and hops < 6:
        ops = _operands(r)
        if not ops or ops[0] not in defs:
            return None
        r = defs[ops[0]]
        hops += 1
    if r.opcode == "dynamic-update-slice":
        ops = _operands(r)
        if len(ops) >= 2 and ops[1] in local_types:
            return float(type_bytes(local_types[ops[1]]))
        return 0.0
    return None


def _instr_bytes(ins: Instr, name2type: dict, comps: dict) -> float:
    """HBM-traffic estimate for one top-level instruction."""
    op = ins.opcode
    out_b = type_bytes(ins.type_str)
    if op in _SLICING_OPS:
        return 2.0 * out_b                   # read slice + write result
    if op in _INPLACE_OPS:
        ops = _operands(ins)
        upd = ops[-1] if ops else None       # updates = last operand
        ub = type_bytes(name2type.get(upd, "")) if upd else out_b
        return 2.0 * ub                      # read update + write in place
    if op == "broadcast":
        return float(out_b)
    b = float(out_b)
    overrides: dict[int, float] = {}
    if op == "fusion":
        cal = _callee(ins.rest, "calls")
        if cal and cal in comps:
            overrides = comps[cal].param_overrides
            if comps[cal].root_override is not None:
                b = comps[cal].root_override     # DUS root: write update only
    for i, opnd in enumerate(_operands(ins)):
        if i in overrides:
            b += overrides[i]
        else:
            t = name2type.get(opnd)
            if t:
                b += type_bytes(t)
    return b


def analyze(text: str, *, default_group: int = 1) -> dict:
    """Full analysis -> dict with flops/bytes/collective totals + breakdown."""
    comps = parse_hlo(text)
    mult, fused = _multiplicities(comps)
    for comp in comps.values():              # precompute slice-read overrides
        comp.param_overrides = _param_read_overrides(comp)
        comp.root_override = _root_write_override(comp)
    flops = 0.0
    bytes_accessed = 0.0
    coll_raw = 0.0
    coll_wire = 0.0
    per_coll: dict[str, dict] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
    per_comp_flops: dict[str, float] = defaultdict(float)

    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        name2type = dict(comp.sig_types)
        for ins in comp.instrs:
            name2type[ins.name] = ins.type_str
        count_bytes = cname not in fused
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                out_b = type_bytes(ins.type_str)
                if op.endswith("-start"):
                    out_b //= 2                 # start result carries (in, out)
                n = _group_size(ins.rest, default_group)
                wire = _collective_wire_bytes(base, out_b, n)
                coll_raw += k * out_b
                coll_wire += k * wire
                d = per_coll[base]
                d["count"] += k
                d["bytes"] += k * out_b
                d["wire_bytes"] += k * wire
            if op == "dot":
                f = _dot_flops(ins, name2type)
                flops += k * f
                per_comp_flops[cname] += k * f
            elif op == "convolution":
                f = _conv_flops(ins, name2type)
                flops += k * f
                per_comp_flops[cname] += k * f
            if count_bytes and op not in _ZERO_COST \
                    and not op.endswith("-done"):
                bytes_accessed += k * _instr_bytes(ins, name2type, comps)

    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collective_bytes": coll_raw,
        "collective_wire_bytes": coll_wire,
        "collectives": {k: dict(v) for k, v in per_coll.items()},
        "top_flop_computations": dict(sorted(
            per_comp_flops.items(), key=lambda kv: -kv[1])[:8]),
    }
