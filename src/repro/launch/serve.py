"""Serving driver: batched requests through the continuous-batching engine,
optionally chunk-prefilled (elastic-FIFO pipeline) and data-parallel across
replica shards.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 [--qk-attention] [--prefill-chunk 16] [--replicas 2]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--spiking", action="store_true")
    ap.add_argument("--qk-attention", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: tokens per chunk interleaved "
                         "with decode ticks (0 = blocking prefill)")
    ap.add_argument("--chunks-per-tick", type=int, default=1)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="admission FIFO bound; submit applies "
                         "backpressure when full (0 = unbounded)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas; slot pools shard "
                         "across local devices, least-loaded dispatch")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="per-request deadline in engine ticks; requests "
                         "that exceed it finish with status "
                         "'deadline_miss' (0 = no deadline)")
    ap.add_argument("--integrity-every", type=int, default=0,
                    help="run the numeric/packed-state integrity guard "
                         "every N decode ticks; flagged slots are "
                         "quarantined and replayed (0 = off)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the canned deterministic fault plan "
                         "(replica kill + NaN injections + fused-kernel "
                         "fault) against the trace — demo of the "
                         "self-healing path; implies --integrity-every 1")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config, reduced as reduce_cfg, build_model
    from ..serve import (Engine, EngineConfig, ReplicaRouter,
                         demo_chaos_plan)

    overrides = {}
    if args.spiking:
        overrides["spiking"] = True
    if args.qk_attention:
        overrides["attention_kind"] = "qk_spiking"
    cfg = get_config(args.arch, **overrides)
    if args.reduced:
        cfg = reduce_cfg(cfg, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    integrity = args.integrity_every or (1 if args.chaos else 0)
    ecfg = EngineConfig(max_slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.prefill_chunk,
                        prefill_chunks_per_tick=args.chunks_per_tick,
                        max_queue=args.max_queue,
                        integrity_every=integrity,
                        deadline_ticks=args.deadline_ticks)
    faults = None
    if args.chaos:
        faults = demo_chaos_plan(args.chaos_seed, n_replicas=args.replicas)
        print(f"[serve] chaos plan: {faults.summary()['events']}")
    if args.replicas > 1:
        eng = ReplicaRouter(model, params, ecfg, n_replicas=args.replicas,
                            faults=faults)
    else:
        eng = Engine(model, params, ecfg, faults=faults)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=args.max_new, temperature=args.temperature)
    eng.run_until_drained()
    print("[serve]", eng.stats())


if __name__ == "__main__":
    main()
