"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 [--qk-attention]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--spiking", action="store_true")
    ap.add_argument("--qk-attention", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from ..configs import get_config, reduced as reduce_cfg, build_model
    from ..serve import Engine, EngineConfig

    overrides = {}
    if args.spiking:
        overrides["spiking"] = True
    if args.qk_attention:
        overrides["attention_kind"] = "qk_spiking"
    cfg = get_config(args.arch, **overrides)
    if args.reduced:
        cfg = reduce_cfg(cfg, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = Engine(model, params,
                 EngineConfig(max_slots=args.slots, max_len=args.max_len))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=args.max_new, temperature=args.temperature)
    eng.run_until_drained()
    print("[serve]", eng.stats())


if __name__ == "__main__":
    main()
