"""Checkpointing: per-leaf .npy shards + JSON manifest, async save, and
restore-with-resharding (the elastic re-mesh path).

Layout:
  <dir>/step_000042/
    manifest.json        {tree: flattened key paths, shapes, dtypes, step}
    0000.npy ... NNNN.npy  one file per leaf (host-gathered)

On a real cluster each host writes only its process-local shards; here the
single process gathers everything (jax.device_get densifies the global
array). Restore takes a TARGET sharding tree — restoring onto a DIFFERENT
mesh (e.g. after losing a pod) is just device_put with the new shardings,
which is exactly what ElasticRunner does.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
import warnings
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A checkpoint shard failed integrity verification (shape / dtype /
    CRC32 vs the manifest). The message names the bad leaf."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> Path:
    """Blocking save. Returns the checkpoint path."""
    ckpt_dir = Path(ckpt_dir)
    path = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, paths, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(h.shape) for h in host],
        "dtypes": [str(h.dtype) for h in host],
        # per-leaf CRC32 of the raw array bytes: restore verifies each
        # shard against this before handing the state back
        "crc32": [_crc(h) for h in host],
        "metadata": metadata or {},
        "time": time.time(),
    }
    for i, h in enumerate(host):
        np.save(tmp / f"{i:04d}.npy", h)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)                    # atomic publish
    return path


def latest_checkpoint(ckpt_dir: str | Path) -> Optional[Path]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    stale = sorted(p.name for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith(".tmp_step_"))
    if stale:
        # a .tmp dir means a writer died mid-save (AsyncCheckpointer
        # crash / SIGKILL): its contents are partial and must never be
        # restored. The atomic-rename publish protocol already keeps them
        # un-selectable; warn so operators clean them up.
        warnings.warn(
            f"{ckpt_dir}: skipping {len(stale)} leftover partial "
            f"checkpoint dir(s) from a crashed save: {stale}",
            RuntimeWarning, stacklevel=2)
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(path: str | Path, like: Any,
                       shardings: Optional[Any] = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; ``shardings`` (same tree)
    places each leaf — pass shardings built on the NEW mesh to reshard.

    Every shard is verified against the manifest (shape, dtype, and — for
    checkpoints written since CRC support — CRC32 of the raw bytes);
    a mismatch raises ``CheckpointCorrupt`` naming the bad leaf instead of
    silently restoring garbage into the training state."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, paths, treedef = _flatten(like)
    assert len(leaves) == len(manifest["paths"]), \
        f"tree mismatch: {len(leaves)} leaves vs {len(manifest['paths'])}"
    crcs = manifest.get("crc32") or [None] * len(leaves)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        name = manifest["paths"][i]
        arr = np.load(path / f"{i:04d}.npy")
        if list(arr.shape) != list(manifest["shapes"][i]):
            raise CheckpointCorrupt(
                f"{path}/{i:04d}.npy (leaf {name!r}): shard shape "
                f"{list(arr.shape)} != manifest {manifest['shapes'][i]}")
        if str(arr.dtype) != manifest["dtypes"][i]:
            raise CheckpointCorrupt(
                f"{path}/{i:04d}.npy (leaf {name!r}): shard dtype "
                f"{arr.dtype} != manifest {manifest['dtypes'][i]}")
        if crcs[i] is not None and _crc(arr) != crcs[i]:
            raise CheckpointCorrupt(
                f"{path}/{i:04d}.npy (leaf {name!r}): CRC32 mismatch — "
                f"shard bytes corrupted on disk")
        if hasattr(leaf, "shape") and list(arr.shape) != list(leaf.shape):
            raise CheckpointCorrupt(
                f"{path}/{i:04d}.npy (leaf {name!r}): checkpoint shape "
                f"{list(arr.shape)} != restore-target shape "
                f"{list(leaf.shape)}")
        arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out), manifest["step"]


def prune_checkpoints(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncCheckpointer:
    """Non-blocking save: device->host copy happens on the caller thread
    (cheap, avoids racing live donated buffers), disk IO on a worker."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[Path] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            self.last_path = save_checkpoint(self.ckpt_dir, step, host_tree,
                                             metadata)
            prune_checkpoints(self.ckpt_dir, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
