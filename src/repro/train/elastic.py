"""Elastic training runtime: checkpoint/restart + pod-loss re-meshing.

Failure model (what actually happens at 1000-node scale): a pod (or host)
drops; the job must (1) notice, (2) rebuild a smaller mesh from surviving
devices, (3) reshard params/optimizer from the last checkpoint onto the new
mesh, (4) re-assign data shards, (5) continue — without a human in the loop.

``ElasticRunner`` implements that loop. Failures are injected by tests /
examples through ``inject_failure`` (we cannot kill real pods in this
container); everything downstream of the detection — re-mesh, reshard,
shard re-assignment, step-function rebuild — is the real mechanism, running
on however many host devices exist.

The runner is mesh-shape-agnostic: it takes an ordered list of candidate
mesh builders (largest first) and falls back down the list as device sets
shrink — 2 pods -> 1 pod -> half-pod ... (elastic scaling DOWN and UP: on
``restore_capacity`` it climbs back to the biggest buildable mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from ..models import sharding as shd
from .checkpoint import (AsyncCheckpointer, latest_checkpoint,
                         restore_checkpoint)
from .straggler import StragglerMonitor
from .trainer import TrainState


@dataclasses.dataclass
class ElasticConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_threshold: float = 1.5


class ElasticRunner:
    """Drives (step_fn, state, loader) through failures.

    Parameters
    ----------
    mesh_builders : list of () -> Mesh, ordered largest-first. On failure the
        runner drops to the next buildable mesh.
    make_step : (mesh) -> jitted step(state, batch) -> (state, metrics);
        rebuilt per mesh because shardings differ.
    make_state : (mesh) -> fresh TrainState with the mesh's shardings
        (used only when no checkpoint exists).
    state_shardings : (state_shape, mesh) -> sharding pytree for restore.
    """

    def __init__(self, mesh_builders: list, make_step, make_state,
                 state_shardings, loader, cfg: ElasticConfig):
        self.mesh_builders = mesh_builders
        self.make_step = make_step
        self.make_state = make_state
        self.state_shardings = state_shardings
        self.loader = loader
        self.cfg = cfg
        self.level = 0                       # index into mesh_builders
        self._failed_at: Optional[int] = None
        self.events: list[dict] = []
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
        self._build()

    # ------------------------------------------------------------- plumbing
    def _build(self) -> None:
        while True:
            try:
                self.mesh = self.mesh_builders[self.level]()
                break
            except Exception as e:          # not enough devices -> degrade
                self.events.append({"kind": "mesh_unavailable",
                                    "level": self.level, "err": str(e)})
                self.level += 1
                if self.level >= len(self.mesh_builders):
                    raise RuntimeError("no buildable mesh left") from e
        shd.set_global_mesh(self.mesh)
        self.step_fn = self.make_step(self.mesh)
        self.monitor = StragglerMonitor(
            n_workers=max(1, self.mesh.devices.size // 16),
            threshold=self.cfg.straggler_threshold)

    def _restore_or_init(self) -> TrainState:
        path = latest_checkpoint(self.cfg.ckpt_dir)
        if path is None:
            return self.make_state(self.mesh)
        fresh = self.make_state(self.mesh)   # structure + shardings template
        shape = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), fresh)
        sh = self.state_shardings(shape, self.mesh)
        state, step = restore_checkpoint(path, shape, sh)
        self.events.append({"kind": "restore", "step": step,
                            "path": str(path)})
        return state

    # ------------------------------------------------------------- failures
    def inject_failure(self, at_step: int) -> None:
        """Simulate losing enough devices that the current mesh dies."""
        self._failed_at = at_step

    def restore_capacity(self) -> None:
        """Devices came back: climb to the largest buildable mesh."""
        if self.level > 0:
            self.level = 0
            self.events.append({"kind": "capacity_restored"})

    # ------------------------------------------------------------------ run
    def run(self, n_steps: int) -> tuple[TrainState, list[dict]]:
        state = self._restore_or_init()
        metrics = None
        while int(state.step) < n_steps:
            step = int(state.step)
            if self._failed_at is not None and step >= self._failed_at:
                # ---- failure path: degrade mesh, reshard from checkpoint
                self.ckpt.wait()
                self.events.append({"kind": "failure", "step": step})
                self._failed_at = None
                self.level = min(self.level + 1, len(self.mesh_builders) - 1)
                self._build()
                if hasattr(self.loader, "reassign"):
                    self.loader.reassign(0, max(1, self.mesh.devices.size // 16))
                if hasattr(self.loader, "mesh"):
                    self.loader.mesh = self.mesh
                state = self._restore_or_init()
                self.events.append({"kind": "remesh",
                                    "mesh": dict(zip(self.mesh.axis_names,
                                                     self.mesh.devices.shape)),
                                    "resume_step": int(state.step)})
                continue
            t0 = time.time()
            batch = self.loader(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            self.monitor.record(0, time.time() - t0)
            if step > 0 and step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, state)
                self.events.append({"kind": "checkpoint", "step": step})
        self.ckpt.wait()
        return state, self.events
