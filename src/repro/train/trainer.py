"""Training loop construction: microbatching, remat, KD, grad compression.

Three step builders, all returning jit-ready pure functions over a
``TrainState`` pytree:

  make_train_step            — LM causal training (the dry-run step):
                               optional MICROBATCHING (gradient accumulation
                               via lax.scan — divides activation memory by
                               n_micro at zero FLOP cost)
  make_kd_train_step         — the paper's KD pipeline (C1): student(+QAT)
                               vs frozen teacher, logit KD loss
  make_compressed_train_step — DP-axis int8+error-feedback gradient
                               compression under shard_map (4x less DP
                               all-reduce traffic; see optim.compression)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.kd import KDConfig, kd_loss
from ..optim import (adamw_init, adamw_update, clip_by_global_norm,
                     compressed_psum_grads, error_feedback_init)

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: Array
    params: Any
    opt_state: Any


def train_state_init(params: Any) -> TrainState:
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=adamw_init(params))


def _split_microbatches(batch: Any, n: int) -> Any:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(r, batch)


def make_train_step(model, *, schedule: Callable[[Array], Array],
                    weight_decay: float = 0.1, clip_norm: float = 1.0,
                    microbatch: int = 0) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatch and microbatch > 1:
            micro = _split_microbatches(batch, microbatch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(state.params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatch,
                    acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, metricss) = jax.lax.scan(body, zeros, micro)
            metrics = jax.tree_util.tree_map(jnp.mean, metricss)
        else:
            _, metrics, grads = grads_of(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(state.step)
        new_p, new_o = adamw_update(grads, state.opt_state, state.params,
                                    lr=lr, weight_decay=weight_decay)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(step=state.step + 1, params=new_p,
                          opt_state=new_o), metrics

    return step


# ------------------------------------------------------------------ KD (C1)
def make_kd_train_step(student_apply: Callable, teacher_apply: Callable,
                       teacher_params: Any, *,
                       kd: KDConfig = KDConfig(),
                       schedule: Callable[[Array], Array],
                       optimizer: str = "sgd", momentum: float = 0.9,
                       weight_decay: float = 5e-4,
                       policy: Any = None) -> Callable:
    """The paper's KD training step (Fig 2(b)).

    ``student_apply(params, state, images) -> (logits, new_state)`` — the
    state carries BN running stats (threaded, not differentiated); the
    params must already encode quantization (KD-QAT stage) when enabled.
    ``teacher_apply(teacher_params, images) -> logits`` (frozen, eval mode).

    ``policy``: an optional ``repro.ops.ExecutionPolicy`` (or preset name)
    for the student's training forward. When given, it is resolved through
    its gradient axis (``for_training()``) and passed to ``student_apply``
    as a ``policy=`` kwarg — so a policy-driven student (e.g.
    ``snn_cnn.forward``) trains through the SAME kernels it deploys on
    ("train what you serve"); the surrogate custom_vjp supplies the
    backward. When None, ``student_apply`` keeps its 3-arg signature and
    its own execution default.

    Returns step((params, opt, state), batch={'images','labels'}) ->
    ((params, opt, new_state), metrics). SGD-momentum per paper §V.A.
    """
    from ..optim import sgd_update, adamw_update

    if policy is not None:
        from .. import ops

        pol = ops.as_policy(policy).for_training()
        _student = student_apply

        def student_apply(params, state, images):  # noqa: F811
            return _student(params, state, images, policy=pol)

    def loss_fn(params, state, batch):
        out = student_apply(params, state, batch["images"])
        # students may return (logits, state) or (logits, state, aux);
        # an aux carrying "active_frac" (snn_cnn's mean firing rate over
        # the spike layers) surfaces as a metric — the measured per-step
        # sparsity signal ``observe_train_sparsity`` feeds the autotuner
        s_logits, new_state = out[0], out[1]
        aux = out[2] if len(out) > 2 else None
        t_logits = teacher_apply(teacher_params, batch["images"])
        loss, metrics = kd_loss(s_logits, t_logits, batch["labels"], kd)
        if isinstance(aux, dict) and "active_frac" in aux:
            metrics = dict(metrics, active_frac=aux["active_frac"])
        return loss, (metrics, new_state)

    def step(carry, batch):
        params, opt, state = carry
        (loss, (metrics, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, batch)
        lr = schedule(opt.step)
        if optimizer == "sgd":
            new_p, new_o = sgd_update(grads, opt, params, lr=lr,
                                      momentum=momentum,
                                      weight_decay=weight_decay)
        else:
            new_p, new_o = adamw_update(grads, opt, params, lr=lr,
                                        weight_decay=weight_decay)
        return (new_p, new_o, new_state), dict(metrics, lr=lr)

    return step


def observe_train_sparsity(metrics: dict) -> None:
    """Feed one training step's measured spike sparsity into the roofline
    autotuner — the host-side half of the ``"auto+grad"`` loop.

    Call on the (device or host) metrics dict a ``make_kd_train_step``
    step returned: when the student surfaced an ``active_frac`` (snn_cnn's
    mean firing rate), it EWMA-feeds ``AutoTuner.observe``, so the next
    trace's backward plans price the dw event skip at the sparsity the
    model actually runs at instead of the dense-safe default.  The rate is
    a neuron-level proxy for the active-BLOCK fraction the byte model
    wants; the tuner's bucket quantization absorbs the gap.  No-op when
    the metric is absent."""
    frac = metrics.get("active_frac")
    if frac is None:
        return
    from ..ops.autotune import get_tuner

    get_tuner().observe(float(frac))


# -------------------------------------------- compressed DP grads (shard_map)
def make_compressed_train_step(model, mesh, *, schedule, dp_axis: str = "data",
                               weight_decay: float = 0.1,
                               clip_norm: float = 1.0) -> Callable:
    """Data-parallel train step with int8+EF gradient compression.

    Params must be REPLICATED over ``dp_axis`` (pure-DP regime): inside
    shard_map each replica computes grads on its batch shard, quantizes them
    int8 (plus carried error feedback), and the psum runs on the compressed
    payload — 4x less DP traffic than f32 gradients.

    Returns step((state, err), batch) -> ((state, err), metrics).
    """
    from jax.experimental.shard_map import shard_map

    def local_step(params, opt_state, step_ct, err, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        grads, new_err = compressed_psum_grads(grads, err, dp_axis)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(step_ct)
        new_p, new_o = adamw_update(grads, opt_state, params, lr=lr,
                                    weight_decay=weight_decay)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.pmean(m, dp_axis), metrics)
        return new_p, new_o, new_err, dict(metrics, grad_norm=gnorm, lr=lr)

    rep = P()

    def batch_spec(batch):
        return jax.tree_util.tree_map(
            lambda x: P(dp_axis, *([None] * (x.ndim - 1))), batch)

    def step(carry, batch):
        state, err = carry
        sm = shard_map(
            local_step, mesh=mesh,
            in_specs=(rep, rep, rep, rep, batch_spec(batch)),
            out_specs=(rep, rep, rep, rep),
            check_rep=False)
        new_p, new_o, new_err, metrics = sm(state.params, state.opt_state,
                                            state.step, err, batch)
        return (TrainState(step=state.step + 1, params=new_p,
                           opt_state=new_o), new_err), metrics

    return step
