"""Straggler detection + deterministic work re-assignment.

At pod scale, one slow host throttles every synchronous step. The monitor
keeps an EWMA of step times per worker; a worker whose EWMA exceeds
``threshold`` x the fleet median is flagged and the data-shard permutation
is rotated so its shard moves to a healthy host (deterministically — every
host computes the same permutation from the same flags, no coordinator).

The paper connection is the ELASTIC part of NEURAL: the elastic FIFO absorbs
producer/consumer rate mismatch at PE granularity; at cluster granularity
the same role is played by re-assigning stream shards away from slow nodes.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    n_workers: int
    ewma_alpha: float = 0.3
    threshold: float = 1.5          # x median EWMA
    warmup_steps: int = 5

    def __post_init__(self):
        self._ewma = np.zeros(self.n_workers)
        self._count = np.zeros(self.n_workers, np.int64)

    def record(self, worker: int, step_time_s: float) -> None:
        if self._count[worker] == 0:
            self._ewma[worker] = step_time_s
        else:
            a = self.ewma_alpha
            self._ewma[worker] = a * step_time_s + (1 - a) * self._ewma[worker]
        self._count[worker] += 1

    def stragglers(self) -> list[int]:
        if (self._count < self.warmup_steps).any():
            return []
        med = float(np.median(self._ewma))
        if med <= 0:
            return []
        return [int(i) for i in range(self.n_workers)
                if self._ewma[i] > self.threshold * med]

    def shard_assignment(self) -> list[int]:
        """worker -> shard permutation that parks flagged workers' shards on
        the fastest workers. Deterministic given the flag set + EWMAs."""
        order = np.argsort(self._ewma)          # fastest first
        bad = set(self.stragglers())
        shards = list(range(self.n_workers))
        if not bad:
            return shards
        # fastest healthy workers absorb the heaviest (straggler) shards:
        # swap each straggler's shard with the fastest non-straggler's.
        healthy = [int(w) for w in order if int(w) not in bad]
        for s, h in zip(sorted(bad), healthy):
            shards[s], shards[h] = shards[h], shards[s]
        return shards

    def summary(self) -> dict:
        return {"ewma": self._ewma.tolist(),
                "stragglers": self.stragglers()}
