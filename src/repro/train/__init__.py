from .trainer import (TrainState, make_train_step, make_kd_train_step,
                      make_compressed_train_step, observe_train_sparsity,
                      train_state_init)
from .checkpoint import (save_checkpoint, restore_checkpoint,
                         latest_checkpoint, AsyncCheckpointer)
from .elastic import ElasticRunner
from .straggler import StragglerMonitor
