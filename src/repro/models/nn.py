"""Minimal functional NN layer library (no flax): init fns return param/state
pytrees, apply fns are pure. NHWC / HWIO layouts (TPU-native).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------- init utils
def kaiming(rng: Array, shape: tuple[int, ...], fan_in: int,
            dtype=jnp.float32) -> Array:
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(rng, shape, dtype) * std


def xavier(rng: Array, shape: tuple[int, ...], fan_in: int, fan_out: int,
           dtype=jnp.float32) -> Array:
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, dtype, -lim, lim)


# -------------------------------------------------------------------- conv2d
def conv_init(rng: Array, kh: int, kw: int, cin: int, cout: int,
              bias: bool = False, dtype=jnp.float32) -> dict:
    p = {"w": kaiming(rng, (kh, kw, cin, cout), kh * kw * cin, dtype)}
    if bias:
        p["b"] = jnp.zeros((cout,), dtype)
    return p


def conv_apply(p: dict, x: Array, stride: int = 1, padding: str = "SAME") -> Array:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----------------------------------------------------- conv-as-matmul (im2col)
def im2col(x: Array, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME") -> Array:
    """Patch extraction: [B, H, W, C] -> [B, Ho, Wo, kh*kw*C].

    Feature ordering is (kh, kw, C) row-major, so a conv weight
    [kh, kw, Cin, Cout] reshaped to [kh*kw*Cin, Cout] gives
    ``im2col(x) @ w2d == conv_apply`` exactly. This is how the deployed
    event path turns every conv into a spike matmul: patches of a binary
    spike map are themselves binary, so the fused PE kernel's per-block
    vld_cnt skip applies to convolutions unchanged.
    """
    b, h, w, c = x.shape
    if padding == "SAME":
        ho = -(-h // stride)
        wo = -(-w // stride)
        ph = max((ho - 1) * stride + kh - h, 0)
        pw = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    elif padding == "VALID":
        ho = (h - kh) // stride + 1
        wo = (w - kw) // stride + 1
    else:
        raise ValueError(f"unknown padding {padding!r}")
    cols = [x[:, i:i + (ho - 1) * stride + 1:stride,
              j:j + (wo - 1) * stride + 1:stride, :]
            for i in range(kh) for j in range(kw)]
    return jnp.concatenate(cols, axis=-1)


def conv_weights_as_matmul(w: Array) -> Array:
    """[kh, kw, Cin, Cout] HWIO conv weight -> [kh*kw*Cin, Cout] matmul
    weight matching ``im2col``'s feature ordering."""
    kh, kw, cin, cout = w.shape
    return w.reshape(kh * kw * cin, cout)


# ------------------------------------------------- packed-spike conv support
#
# ``im2col`` is channel-preserving per (i, j) tap: each concatenated slice
# carries a pixel's FULL channel vector. So when the channel axis is padded
# to a multiple of 32 and bit-packed (core.events: 32 spikes per int32
# lane), patch extraction works on the WORD tensor unchanged — the words of
# im2col(packed) ARE the packing of im2col(dense). Convolutions over spike
# maps therefore never need the dense representation: patches, pooling, and
# the matmul operand all stay event-compressed.

def im2col_packed(words: Array, kh: int, kw: int, stride: int = 1,
                  padding: str = "SAME") -> Array:
    """Patch extraction on channel-packed spike words.

    words: [B, H, W, Cp/32] int32 (Cp = padded channels). Returns
    [B, Ho, Wo, kh*kw*Cp/32] int32 — bit-for-bit the packed form of
    ``im2col`` on the dense map, because zero words ARE zero spikes (SAME
    padding stays silent).
    """
    assert words.dtype == jnp.int32, words.dtype
    return im2col(words, kh, kw, stride, padding)


def conv_weights_as_matmul_packed(w: Array, c_padded: int) -> Array:
    """[kh, kw, Cin, Cout] -> [kh*kw*c_padded, Cout] with zero rows for the
    pad channels interleaved per (i, j) tap, matching ``im2col_packed``'s
    feature ordering (the pad lanes carry zero spikes AND zero weights, so
    the packed matmul is exact)."""
    kh, kw, cin, cout = w.shape
    assert c_padded >= cin, (c_padded, cin)
    if c_padded != cin:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, c_padded - cin), (0, 0)))
    return w.reshape(kh * kw * c_padded, cout)


def max_pool_packed(words: Array, window: int = 2,
                    stride: Optional[int] = None) -> Array:
    """Max-pool of BINARY spike maps == per-window OR == bitwise OR of the
    packed words: the pooled map never exists dense."""
    assert words.dtype == jnp.int32, words.dtype
    stride = stride or window
    return jax.lax.reduce_window(
        words, jnp.int32(0), jax.lax.bitwise_or,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


# ---------------------------------------------------------------- batch norm
def bn_init(c: int, dtype=jnp.float32) -> tuple[dict, dict]:
    params = {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}
    state = {"mean": jnp.zeros((c,), dtype), "var": jnp.ones((c,), dtype)}
    return params, state


def bn_apply(p: dict, s: dict, x: Array, train: bool, momentum: float = 0.9,
             eps: float = 1e-5) -> tuple[Array, dict]:
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    inv = jax.lax.rsqrt(var + eps) * p["scale"]
    return (x - mean) * inv + p["bias"], new_s


# -------------------------------------------------------------------- linear
def linear_init(rng: Array, din: int, dout: int, bias: bool = True,
                dtype=jnp.float32) -> dict:
    p = {"w": xavier(rng, (din, dout), din, dout, dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def linear_apply(p: dict, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ------------------------------------------------------------------- pooling
def max_pool(x: Array, window: int = 2, stride: Optional[int] = None) -> Array:
    stride = stride or window
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


def avg_pool(x: Array, window: int = 2, stride: Optional[int] = None) -> Array:
    stride = stride or window
    summed = jax.lax.reduce_window(
        x, 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
    return summed / (window * window)
