"""The paper's deployed SNN models: VGG-11, ResNet-11, QKFResNet-11 (Fig 2a).

Execution contract (matches the NEURAL pipeline):
  * multi-timestep tensors are [T, B, H, W, C]; the paper's deployed mode is
    T=1 (single-timestep, C1) and T>1 is the baseline it beats;
  * every activation between layers is a BINARY SPIKE map (LIF outputs);
  * the classifier head is W2TTFS (C2) — ``head="avgpool"`` gives the
    non-spiking ANN-style head used by the F&Q ablation;
  * QKFResNet-11 = ResNet-11 + spiking QKFormer block(s) (C4) on the final
    feature map tokens;
  * ``fuse_model`` folds BN into conv and applies fixed-point quantization —
    the paper's F&Q stage producing the hardware deployment artifact.

Models are list-of-layer-descriptor driven so init / forward / fuse walk
the same structure — and there is ONE forward (``forward``): a single
layer-walk parameterized by the parameter graph (unfused conv+BN training
variables vs the BN-folded deployment artifact from ``fuse_model``) and an
``ExecutionPolicy``. The KD pipeline trains, evaluates, and deploys the
SAME body; the policy's gradient axis decides whether the walk runs the
surrogate-gradient ops (train-what-you-serve) or the event-driven
inference kernels.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .. import ops
from ..core.lif import LIFConfig
from ..core.quant import QuantConfig, fake_quant, fuse_bn_into_conv, fuse_bn_into_linear, quantize_fixed
from ..core.w2ttfs import avgpool_classifier
from ..ops import SpikeTensor
from . import nn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SNNCNNConfig:
    arch: str = "vgg11"             # vgg11 | resnet11 | qkfresnet11
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    width_mult: float = 1.0
    timesteps: int = 1              # T=1 is the paper's deployed mode
    lif: LIFConfig = LIFConfig()
    quant: QuantConfig = QuantConfig()
    head: str = "w2ttfs"            # w2ttfs | avgpool
    qk_blocks: int = 1
    qk_mask_mode: str = "threshold"  # threshold | or  (Fig 5 atten_reg = "or")
    # BN-folded TRAINING forward: fold BN (frozen running stats) into the
    # conv/linear weights on the fly each step, so the unfused training
    # graph runs the SAME fused-PE layer bodies the deployed artifact runs
    # (conv+bias+LIF in one pass, no separate BN/LIF stages). Gradients
    # flow through the fold into conv weights AND BN scale/bias; running
    # stats are frozen (passed through unchanged) — standard fold-BN QAT
    # semantics, applied uniformly under ANY differentiable policy so
    # reference and fused policies stay numerically comparable.
    bn_fold: bool = False
    dtype: Any = jnp.float32
    # policy: how ``forward`` executes — "reference" (the None default;
    # pure jnp), "fused_dense" (event-driven Pallas kernels, int8 maps
    # between layers), or "fused_packed" (event kernels + bit-packed
    # inter-layer spike tensors, ~8x fewer spike bytes). All three emit
    # bit-identical spikes; on the unfused training graph the policy is
    # resolved through its gradient axis (surrogate-vjp forward). See
    # repro.ops.ExecutionPolicy.
    policy: Optional[Any] = None    # ExecutionPolicy | preset name | None
    # deprecated flag pair -> policy (repro.ops.compat translates + warns);
    # this model's historical default spike format was "packed", so a bare
    # legacy event-kernel flag maps to "fused_packed"
    use_event_kernels: Optional[bool] = None
    spike_format: Optional[str] = None

    def __post_init__(self):
        resolved = ops.legacy_flags_policy(
            "SNNCNNConfig", self.policy, self.use_event_kernels,
            self.spike_format, default_format="packed")
        if self.policy is not None:
            object.__setattr__(self, "policy", resolved)

    @property
    def exec_policy(self) -> ops.ExecutionPolicy:
        pol = ops.legacy_flags_policy(
            "SNNCNNConfig", self.policy, self.use_event_kernels,
            self.spike_format, default_format="packed", warn=False)
        return pol if pol is not None else ops.REFERENCE


# --------------------------------------------------------------- arch tables
_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512]
_RESNET11_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]


def _c(ch: int, cfg: SNNCNNConfig) -> int:
    return max(8, int(ch * cfg.width_mult))


def build_layers(cfg: SNNCNNConfig) -> list[tuple]:
    """Layer descriptor list: (kind, meta...)."""
    layers: list[tuple] = []
    cin = cfg.in_channels
    size = cfg.image_size
    if cfg.arch == "vgg11":
        for item in _VGG11:
            if item == "M":
                layers.append(("maxpool",))
                size //= 2
            else:
                cout = _c(item, cfg)
                layers.append(("conv_bn_lif", cin, cout, 1))
                cin = cout
    elif cfg.arch in ("resnet11", "qkfresnet11"):
        stem = _c(64, cfg)
        layers.append(("conv_bn_lif", cin, stem, 1))
        cin = stem
        for ch, stride in _RESNET11_STAGES:
            cout = _c(ch, cfg)
            layers.append(("resblock", cin, cout, stride))
            cin = cout
            size //= stride
        if cfg.arch == "qkfresnet11":
            for _ in range(cfg.qk_blocks):
                layers.append(("qkformer", cin))
    else:
        raise ValueError(f"unknown snn-cnn arch {cfg.arch!r}")
    layers.append(("head", cin, size))
    return layers


# ----------------------------------------------------------------------- init
def init(rng: Array, cfg: SNNCNNConfig) -> dict:
    params: list = []
    state: list = []
    layers = build_layers(cfg)
    rngs = jax.random.split(rng, len(layers) + 1)
    for r, layer in zip(rngs, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            _, cin, cout, stride = layer
            bn_p, bn_s = nn.bn_init(cout, cfg.dtype)
            params.append({"conv": nn.conv_init(r, 3, 3, cin, cout, dtype=cfg.dtype),
                           "bn": bn_p})
            state.append({"bn": bn_s})
        elif kind == "maxpool":
            params.append({})
            state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            r1, r2, r3 = jax.random.split(r, 3)
            bn1p, bn1s = nn.bn_init(cout, cfg.dtype)
            bn2p, bn2s = nn.bn_init(cout, cfg.dtype)
            p = {"conv1": nn.conv_init(r1, 3, 3, cin, cout, dtype=cfg.dtype), "bn1": bn1p,
                 "conv2": nn.conv_init(r2, 3, 3, cout, cout, dtype=cfg.dtype), "bn2": bn2p}
            s = {"bn1": bn1s, "bn2": bn2s}
            if stride != 1 or cin != cout:
                bnsp, bnss = nn.bn_init(cout, cfg.dtype)
                p["conv_sc"] = nn.conv_init(r3, 1, 1, cin, cout, dtype=cfg.dtype)
                p["bn_sc"] = bnsp
                s["bn_sc"] = bnss
            params.append(p)
            state.append(s)
        elif kind == "qkformer":
            _, d = layer
            rq, rk, rp, rm1, rm2 = jax.random.split(r, 5)
            bnq_p, bnq_s = nn.bn_init(d, cfg.dtype)
            bnk_p, bnk_s = nn.bn_init(d, cfg.dtype)
            bnp_p, bnp_s = nn.bn_init(d, cfg.dtype)
            bnm1_p, bnm1_s = nn.bn_init(d, cfg.dtype)
            bnm2_p, bnm2_s = nn.bn_init(d, cfg.dtype)
            params.append({"q": nn.linear_init(rq, d, d, bias=False, dtype=cfg.dtype), "bn_q": bnq_p,
                           "k": nn.linear_init(rk, d, d, bias=False, dtype=cfg.dtype), "bn_k": bnk_p,
                           "proj": nn.linear_init(rp, d, d, bias=False, dtype=cfg.dtype), "bn_proj": bnp_p,
                           "mlp1": nn.linear_init(rm1, d, d, bias=False, dtype=cfg.dtype), "bn_mlp1": bnm1_p,
                           "mlp2": nn.linear_init(rm2, d, d, bias=False, dtype=cfg.dtype), "bn_mlp2": bnm2_p})
            state.append({"bn_q": bnq_s, "bn_k": bnk_s, "bn_proj": bnp_s,
                          "bn_mlp1": bnm1_s, "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, cin, size = layer
            # W2TTFS head pools the full (size x size) map -> FC input dim = C
            params.append({"fc": nn.linear_init(r, cin, cfg.num_classes, dtype=cfg.dtype)})
            state.append({})
    return {"params": params, "state": state}


# -------------------------------------------------------------- apply helpers
def _per_step(fn, x: Array) -> Array:
    """Apply a per-image fn over [T, B, ...] by folding T into batch."""
    t, b = x.shape[0], x.shape[1]
    y = fn(x.reshape(t * b, *x.shape[2:]))
    return y.reshape(t, b, *y.shape[1:])


def _qw(w: Array, cfg: SNNCNNConfig) -> Array:
    return fake_quant(w, cfg.quant, is_weight=True)


def _conv_bn(p, s, x, cfg, train, stride=1):
    """conv + BN over [T,B,H,W,C] (BN stats pooled over T*B), returns current."""
    conv_p = {"w": _qw(p["conv"]["w"], cfg)}
    cur = _per_step(lambda z: nn.conv_apply(conv_p, z, stride), x)
    t, b = cur.shape[0], cur.shape[1]
    flat = cur.reshape(t * b, *cur.shape[2:])
    y, new_bn = nn.bn_apply(p["bn"] if "bn" in p else p, s, flat, train)
    return y.reshape(t, b, *cur.shape[2:]), new_bn


# ----------------------------------------------------------------- F&Q fusion
def fuse_model(variables: dict, cfg: SNNCNNConfig) -> list:
    """Paper F&Q stage: fold BN into conv/linear, fixed-point-quantize weights.

    Returns the fused param list ``forward`` deploys (conv+bias, no BN).
    """
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    fused: list = []
    bits = cfg.quant.bits if cfg.quant.enabled else None

    def q(w):
        return quantize_fixed(w, bits, axis=None) if bits else w

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            w, b = fuse_bn_into_conv(p["conv"]["w"], None, p["bn"]["scale"],
                                     p["bn"]["bias"], s["bn"]["mean"], s["bn"]["var"])
            fused.append({"conv": {"w": q(w), "b": b}})
        elif kind == "resblock":
            f = {}
            for c, bn in (("conv1", "bn1"), ("conv2", "bn2")):
                w, b = fuse_bn_into_conv(p[c]["w"], None, p[bn]["scale"],
                                         p[bn]["bias"], s[bn]["mean"], s[bn]["var"])
                f[c] = {"w": q(w), "b": b}
            if "conv_sc" in p:
                w, b = fuse_bn_into_conv(p["conv_sc"]["w"], None, p["bn_sc"]["scale"],
                                         p["bn_sc"]["bias"], s["bn_sc"]["mean"], s["bn_sc"]["var"])
                f["conv_sc"] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "qkformer":
            f = {}
            for name in ("q", "k", "proj", "mlp1", "mlp2"):
                w, b = fuse_bn_into_linear(p[name]["w"], None, p[f"bn_{name}"]["scale"],
                                           p[f"bn_{name}"]["bias"], s[f"bn_{name}"]["mean"],
                                           s[f"bn_{name}"]["var"])
                f[name] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "head":
            fused.append({"fc": {"w": q(p["fc"]["w"]), "b": p["fc"]["b"]}})
        else:
            fused.append({})
    return fused


def fold_train_params(params: list, state: list, cfg: SNNCNNConfig) -> list:
    """BN-fold of the LIVE training variables — the differentiable twin of
    ``fuse_model``.

    Folds each layer's BN (FROZEN running stats from ``state``) into its
    conv/linear weights with ``fuse_bn_into_conv``/``_linear`` and applies
    the straight-through ``fake_quant`` to the folded weight, yielding the
    same ``{"w", "b"}`` per-layer shape as the F&Q deployment artifact.
    Unlike ``fuse_model`` this runs INSIDE the training graph every step:
    gradients flow through the fold into the conv weights and the BN
    scale/bias, so ``forward(..., bn_fold=True)`` trains the exact layer
    bodies (fused conv+bias+LIF passes) that deployment executes."""
    layers = build_layers(cfg)
    folded: list = []

    def fq(w):
        return fake_quant(w, cfg.quant, is_weight=True)

    def fold_conv(cp, bp, bs):
        w, b = fuse_bn_into_conv(cp["w"], None, bp["scale"], bp["bias"],
                                 jax.lax.stop_gradient(bs["mean"]),
                                 jax.lax.stop_gradient(bs["var"]))
        return {"w": fq(w), "b": b}

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            folded.append({"conv": fold_conv(p["conv"], p["bn"], s["bn"])})
        elif kind == "resblock":
            f = {c: fold_conv(p[c], p[bn], s[bn])
                 for c, bn in (("conv1", "bn1"), ("conv2", "bn2"))}
            if "conv_sc" in p:
                f["conv_sc"] = fold_conv(p["conv_sc"], p["bn_sc"],
                                         s["bn_sc"])
            folded.append(f)
        elif kind == "qkformer":
            f = {}
            for name in ("q", "k", "proj", "mlp1", "mlp2"):
                w, b = fuse_bn_into_linear(
                    p[name]["w"], None, p[f"bn_{name}"]["scale"],
                    p[f"bn_{name}"]["bias"],
                    jax.lax.stop_gradient(s[f"bn_{name}"]["mean"]),
                    jax.lax.stop_gradient(s[f"bn_{name}"]["var"]))
                f[name] = {"w": fq(w), "b": b}
            folded.append(f)
        elif kind == "head":
            folded.append({"fc": {"w": fq(p["fc"]["w"]),
                                  "b": p["fc"]["b"]}})
        else:
            folded.append({})
    return folded


def _account(aux: dict, st: SpikeTensor, packed: bool) -> SpikeTensor:
    """HBM accounting for every spike tensor shipped between kernels, in
    whatever format it shipped."""
    aux["spike_hbm_bytes"] += st.hbm_bytes
    if packed:
        aux["spike_hbm_packed_bytes"] += st.hbm_bytes
        aux["spike_hbm_dense_bytes"] += st.dense_bytes
    return st


def forward(variables, images: Array, cfg: SNNCNNConfig, *,
            train: bool = False, policy=None
            ) -> tuple[Array, Optional[list], dict]:
    """THE forward pass — one layer-walk for the whole train/deploy matrix.

    ``variables`` selects the parameter GRAPH:
      * the ``{"params", "state"}`` dict from ``init`` — the unfused
        conv+BN graph (``train`` switches BN batch stats + running-stat
        updates vs running stats). The policy is resolved through its
        gradient axis (``policy.for_training()``), so ``jax.grad`` always
        sees the surrogate pseudo-derivative — with the reference policy
        this is the classic pure-jnp KD training forward, with a fused
        policy the SAME graph runs its forward through the event-driven
        Pallas kernels (train what you serve).
      * the list from ``fuse_model`` — the BN-folded F&Q deployment
        artifact (what NEURAL's EPA executes). "reference" runs the
        pure-jnp oracle; "fused_dense"/"fused_packed" run every
        binary-activation layer through the fused PE dataflow kernels
        with int8 / bit-packed spike tensors between layers, bit-identical
        logits across all three.

    ``policy`` (or ``cfg.exec_policy`` when None) is the
    ``repro.ops.ExecutionPolicy``. images: [B, H, W, C] analog input
    (direct encoding: repeated across T; the first conv+LIF enters the
    spiking domain).

    Returns (logits [B, classes], new_state, aux): ``new_state`` is the
    updated BN state list for the unfused graph and None for the deployed
    graph; ``aux`` carries per-layer spike counts (Total Spikes, paper
    Table II), spike rates, and — on the event path — the spike-HBM
    accounting and on-the-fly metadata reuse counters.
    """
    layers = build_layers(cfg)
    fused_graph = not (isinstance(variables, dict) and "params" in variables)
    pol = ops.as_policy(policy, cfg.exec_policy)
    if not fused_graph:
        pol = pol.for_training()
    event = fused_graph and pol.fused and not pol.differentiable

    params = variables if fused_graph else variables["params"]
    state = [None] * len(layers) if fused_graph else variables["state"]
    # BN-folded training walk: fold BN into the weights on the fly and run
    # the DEPLOYED layer bodies (fused conv+bias+LIF passes) under the
    # differentiable policy — train what you serve, including BN. Running
    # stats are frozen (state passes through unchanged).
    folded = (not fused_graph) and cfg.bn_fold
    fparams = fold_train_params(params, state, cfg) if folded else params
    t = cfg.timesteps
    x0 = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)

    aux: dict = {"spikes": {}, "rates": {}, "vld_reused": 0}
    if event:
        aux["spike_hbm_bytes"] = 0
        if pol.packed:
            aux["spike_hbm_packed_bytes"] = 0
            aux["spike_hbm_dense_bytes"] = 0
    # the hardware atten_reg ("or") gates the deployed graph; the unfused
    # graph uses the config's (surrogate-trainable) mask mode
    qk_mode = "or" if fused_graph else cfg.qk_mask_mode
    new_state: list = []
    st: Optional[SpikeTensor] = None   # [T, B*H*W, C] once the net spikes
    spatial = None                     # (B, H, W, C)
    logits = None
    li = 0

    # ------------------------------------------------------ shared helpers
    def account(s_: SpikeTensor) -> SpikeTensor:
        return _account(aux, s_, pol.packed) if event else s_

    def to_tokens(spk5: Array) -> tuple[SpikeTensor, tuple]:
        """[T, B, H, W, C] spikes -> (token SpikeTensor, spatial); the
        event path enters the policy's HBM format here."""
        b, h, w_, c = spk5.shape[1:]
        flat = spk5.reshape(t, b * h * w_, c)
        if event:
            flat = flat.astype(jnp.int8)
            s_ = ops.pack(flat) if pol.packed else SpikeTensor.dense(flat)
            return account(s_), (b, h, w_, c)
        return SpikeTensor.dense(flat), (b, h, w_, c)

    def lif_chain(cur: Array) -> Array:
        """Multi-timestep LIF over [T, ...] currents through ``ops.lif``.
        The carry holds post-reset state with ``s_prev = 0``, which makes
        the chain bit- AND gradient-identical to ``core.lif.lif_multistep``
        under the reference policy."""
        v = jnp.zeros_like(cur[0])
        z = jnp.zeros_like(cur[0])
        outs = []
        for ti in range(t):
            s_, v = ops.lif(cur[ti], v, z, lif_cfg=cfg.lif, policy=pol)
            outs.append(s_)
        return jnp.stack(outs).astype(cur.dtype)

    # ------------------------------------------ float-cell (non-event) ops
    def conv_current(pc: dict, s_in: SpikeTensor, sp: tuple, stride: int
                     ) -> tuple[Array, tuple]:
        """conv current over token spikes -> ([T, B, Ho, Wo, Cout] f32,
        (Ho, Wo)): lax.conv under reference kernels (the classic training
        numerics), conv-as-matmul through the differentiable ``ops.matmul``
        when the policy runs the fused kernels."""
        b, h, w_, c = sp
        if pol.fused:
            kh, kw = pc["w"].shape[:2]
            pat, (ho, wo) = ops.im2col(s_in, sp, kh, kw, stride, t=t,
                                       policy=pol)
            w2d = ops.conv_matmul_weights(pc["w"], pat)
            cur = ops.matmul(pat.data.reshape(t, b, ho * wo, -1), w2d,
                             policy=pol).reshape(t, b, ho, wo, -1)
            if "b" in pc:
                cur = cur + pc["b"].astype(cur.dtype)
            return cur, (ho, wo)
        x5 = s_in.data.reshape(t * b, h, w_, c).astype(cfg.dtype)
        y = nn.conv_apply(pc, x5, stride)
        ho, wo = y.shape[1], y.shape[2]
        return y.reshape(t, b, ho, wo, y.shape[3]), (ho, wo)

    def bn5(cur: Array, p_l: dict, s_l: dict, key: str, ns: dict) -> Array:
        """BN over [T, B, Ho, Wo, C] currents (stats pooled over T*B, the
        unfused graph only); records the updated running stats in ``ns``."""
        yb, ns[key] = nn.bn_apply(p_l[key], s_l[key],
                                  cur.reshape(cur.shape[0] * cur.shape[1],
                                              *cur.shape[2:]), train)
        return yb.reshape(cur.shape)

    def conv_block(names: tuple, p_l, s_l, s_in, sp, stride, ns) -> tuple:
        """One conv (+BN on the unfused graph) current."""
        conv_name, bn_name = names
        if fused_graph:
            return conv_current(p_l[conv_name], s_in, sp, stride)
        cur, hw2 = conv_current({"w": _qw(p_l[conv_name]["w"], cfg)},
                                s_in, sp, stride)
        return bn5(cur, p_l, s_l, bn_name, ns), hw2

    # ------------------------------------------------- event-cell ops (C3)
    def conv_lif(pc: dict, s_in: SpikeTensor, sp: tuple, stride: int,
                 residual=None) -> tuple[SpikeTensor, tuple]:
        """conv(spikes) + bias + LIF as ONE fused PE pass (conv-as-matmul),
        emitting in the policy's format."""
        kh, kw = pc["w"].shape[:2]
        pat, (ho, wo) = ops.im2col(s_in, sp, kh, kw, stride, t=t,
                                   policy=pol)
        w2d = ops.conv_matmul_weights(pc["w"], pat)
        out = ops.fused_pe_layer(pat, w2d, bias=pc.get("b"),
                                 residual=residual, lif_cfg=cfg.lif,
                                 policy=pol)
        return account(out.spikes), (sp[0], ho, wo, w2d.shape[1])

    def conv_cur_event(pc: dict, s_in: SpikeTensor, sp: tuple,
                       stride: int) -> Array:
        """Shortcut conv: event-skipped matmul -> f32 membrane current
        (no LIF — it joins conv2's fused pass as the residual operand)."""
        kh, kw = pc["w"].shape[:2]
        pat, _ = ops.im2col(s_in, sp, kh, kw, stride, t=t, policy=pol)
        w2d = ops.conv_matmul_weights(pc["w"], pat)
        cur = jnp.stack([ops.matmul(pat[ti], w2d, policy=pol)
                         for ti in range(t)])
        return cur + pc["b"].astype(jnp.float32)

    # ----------------------------------------------------- the layer walk
    for p, fp, s, layer in zip(params, fparams, state, layers):
        kind = layer[0]
        ns: dict = {}
        if kind == "conv_bn_lif":
            stride = layer[3]
            if st is None:
                # analog input: dense conv (+BN on the unfused graph), then
                # the first LIF enters the spiking domain
                if fused_graph or folded:
                    cur = _per_step(
                        lambda z: nn.conv_apply(fp["conv"], z, stride), x0)
                else:
                    cur, bn_s = _conv_bn({"conv": p["conv"], "bn": p["bn"]},
                                         s["bn"], x0, cfg, train, stride)
                    ns["bn"] = bn_s
                st, spatial = to_tokens(lif_chain(cur))
            elif event or folded:
                st, spatial = conv_lif(fp["conv"], st, spatial, stride)
            else:
                cur, (ho, wo) = conv_block(("conv", "bn"), p, s, st,
                                           spatial, stride, ns)
                st, spatial = to_tokens(lif_chain(cur))
        elif kind == "maxpool":
            st, (h2, w2) = ops.pool(st, spatial, t=t, policy=pol)
            st = account(st)
            spatial = (spatial[0], h2, w2, spatial[3])
        elif kind == "resblock":
            stride = layer[3]
            if event or folded:
                s1, sp1 = conv_lif(fp["conv1"], st, spatial, stride)
                if "conv_sc" in fp:
                    res = conv_cur_event(fp["conv_sc"], st, spatial, stride)
                else:
                    res = st            # identity: binary spike shortcut
                aux["spikes"][f"res{li}_s1"] = s1.count()
                st, spatial = conv_lif(fp["conv2"], s1, sp1, 1, residual=res)
            else:
                cur1, hw1 = conv_block(("conv1", "bn1"), p, s, st, spatial,
                                       stride, ns)
                s1 = lif_chain(cur1)
                st1, sp1 = to_tokens(s1)
                cur2, _ = conv_block(("conv2", "bn2"), p, s, st1, sp1, 1,
                                     ns)
                if "conv_sc" in p:
                    sc, _ = conv_block(("conv_sc", "bn_sc"), p, s, st,
                                       spatial, stride, ns)
                else:
                    b, h, w_, c = spatial
                    sc = st.data.reshape(t, b, h, w_, c).astype(cur2.dtype)
                # MS-ResNet shortcut: add membrane currents, then fire
                aux["spikes"][f"res{li}_s1"] = s1.sum()
                st, spatial = to_tokens(lif_chain(cur2 + sc))
        elif kind == "qkformer":
            d = layer[1]
            if event or folded:
                # five fused passes, format-agnostic: each consumes the vld
                # map its producer emitted in-kernel (the on-the-fly
                # dataflow), the K pass applies the QK token mask on
                # write-back (Fig 5), and spike maps cross HBM in the
                # policy's format throughout. The BN-folded training walk
                # runs this SAME body (hard "or" mask, surrogate-masked
                # backward) under the differentiable policy.
                tok = st
                lifkw = dict(lif_cfg=cfg.lif, policy=pol)
                q3 = ops.fused_pe_layer(tok, fp["q"]["w"], bias=fp["q"]["b"],
                                        **lifkw).spikes
                # atten_reg "or" mode == rowsum >= 1 on integer counts
                attn3 = ops.fused_pe_layer(tok, fp["k"]["w"],
                                           bias=fp["k"]["b"], q=q3,
                                           qk_threshold=1.0, **lifkw).spikes
                y3 = ops.fused_pe_layer(attn3, fp["proj"]["w"],
                                        bias=fp["proj"]["b"], residual=tok,
                                        **lifkw).spikes
                m13 = ops.fused_pe_layer(y3, fp["mlp1"]["w"],
                                         bias=fp["mlp1"]["b"], **lifkw).spikes
                y23 = ops.fused_pe_layer(m13, fp["mlp2"]["w"],
                                         bias=fp["mlp2"]["b"], residual=y3,
                                         **lifkw).spikes
                for s_ in (q3, attn3, y3, m13, y23):
                    account(s_)
                aux["vld_reused"] += sum(
                    1 for s_ in (tok, tok, attn3, y3, m13)
                    if s_.vld_cnt is not None)
                aux["spikes"][f"qkf{li}_q"] = q3.count()
                st = y23
            else:
                b, h, w_, _ = spatial
                hw = h * w_
                tok4 = st.data.reshape(t, b, hw, d)

                def lin_bn(name, inp4):
                    """linear (+bias on the fused graph / +BN on the
                    unfused graph) -> [T, B, hw, d] current."""
                    if fused_graph:
                        cur = ops.matmul(inp4, p[name]["w"], policy=pol)
                        return cur + p[name]["b"].astype(cur.dtype)
                    cur = ops.matmul(inp4, _qw(p[name]["w"], cfg),
                                     policy=pol)
                    yb, bns = nn.bn_apply(p[f"bn_{name}"], s[f"bn_{name}"],
                                          cur.reshape(-1, d), train)
                    ns[f"bn_{name}"] = bns
                    return yb.reshape(t, b, hw, d)

                q4 = lif_chain(lin_bn("q", tok4))
                k4 = lif_chain(lin_bn("k", tok4))
                attn4 = ops.qk_mask(q4, k4, mode=qk_mode,
                                    surrogate=cfg.lif.surrogate,
                                    alpha=cfg.lif.alpha,
                                    policy=pol).data            # QKTA
                y4 = lif_chain(lin_bn("proj", attn4.astype(cfg.dtype))
                               + tok4)          # membrane shortcut
                m1 = lif_chain(lin_bn("mlp1", y4))
                y2 = lif_chain(lin_bn("mlp2", m1) + y4)
                aux["spikes"][f"qkf{li}_q"] = q4.sum()
                aux["spikes"][f"qkf{li}_mask_on"] = \
                    (q4.sum(axis=-1) > 0).sum()
                st = SpikeTensor.dense(y2.reshape(t, b * hw, d))
        elif kind == "head":
            _, cin, size = layer
            b, h, w_, c = spatial
            if fused_graph or folded:
                fc_w, fc_b = fp["fc"]["w"], fp["fc"]["b"]
            else:
                fc_w, fc_b = _qw(p["fc"]["w"], cfg), p["fc"]["b"]
            xd = ops.unpack(st, policy=pol) if event else st.data
            xd = xd.astype(cfg.dtype).reshape(t, b, h, w_, c)

            def head_one(s_t):
                if cfg.head == "w2ttfs":
                    return ops.w2ttfs_head(s_t, fc_w, fc_b, window=size,
                                           policy=pol)
                return avgpool_classifier(s_t, fc_w, fc_b, size)

            # rate-decode over T
            logits = jnp.mean(jnp.stack([head_one(xd[ti])
                                         for ti in range(t)]), axis=0)
        if kind != "head":
            aux["spikes"][f"layer{li}"] = st.count()
            aux["rates"][f"layer{li}"] = st.count() / math.prod(st.shape)
        if not fused_graph:
            # folded walk: BN running stats are frozen — thread them
            # through unchanged so the carry keeps one tree structure
            new_state.append(s if folded else ns)
        li += 1

    aux["total_spikes"] = sum(v for k_, v in aux["spikes"].items()
                              if k_.startswith("layer"))
    # measured per-step event density (mean spike rate over the layer
    # maps): the training loop feeds this to the autotuner so "+grad"
    # plans price the REAL sparsity of the net being trained, not a prior
    if aux["rates"]:
        aux["active_frac"] = (sum(aux["rates"].values())
                              / len(aux["rates"]))
    return logits, (None if fused_graph else new_state), aux
