"""The paper's deployed SNN models: VGG-11, ResNet-11, QKFResNet-11 (Fig 2a).

Execution contract (matches the NEURAL pipeline):
  * multi-timestep tensors are [T, B, H, W, C]; the paper's deployed mode is
    T=1 (single-timestep, C1) and T>1 is the baseline it beats;
  * every activation between layers is a BINARY SPIKE map (LIF outputs);
  * the classifier head is W2TTFS (C2) — ``head="avgpool"`` gives the
    non-spiking ANN-style head used by the F&Q ablation;
  * QKFResNet-11 = ResNet-11 + spiking QKFormer block(s) (C4) on the final
    feature map tokens;
  * ``fuse_model`` folds BN into conv and applies fixed-point quantization —
    the paper's F&Q stage producing the hardware deployment artifact.

Models are list-of-layer-descriptor driven so init / apply / fuse walk the
same structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.lif import LIFConfig, lif_multistep
from ..core.quant import QuantConfig, fake_quant, fuse_bn_into_conv, fuse_bn_into_linear, quantize_fixed
from ..core.qk_attention import qk_token_mask, qk_channel_mask
from ..core.w2ttfs import w2ttfs_classifier, avgpool_classifier
from . import nn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SNNCNNConfig:
    arch: str = "vgg11"             # vgg11 | resnet11 | qkfresnet11
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    width_mult: float = 1.0
    timesteps: int = 1              # T=1 is the paper's deployed mode
    lif: LIFConfig = LIFConfig()
    quant: QuantConfig = QuantConfig()
    head: str = "w2ttfs"            # w2ttfs | avgpool
    qk_blocks: int = 1
    qk_mask_mode: str = "threshold"  # threshold | or  (Fig 5 atten_reg = "or")
    dtype: Any = jnp.float32
    # route binary-activation matmuls through the event-driven Pallas
    # kernel (C3): deployed-inference path only (apply_fused)
    use_event_kernels: bool = False
    # HBM format for inter-layer spike tensors on the event path:
    # "packed" ships every spike map bit-packed (32/int32 lane + popcount
    # vld_cnt, core.events.PackedSpikes — ~8x fewer spike bytes, bit-
    # identical spikes); "dense" keeps the int8 maps of the pre-compression
    # pipeline
    spike_format: str = "packed"


# --------------------------------------------------------------- arch tables
_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512]
_RESNET11_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]


def _c(ch: int, cfg: SNNCNNConfig) -> int:
    return max(8, int(ch * cfg.width_mult))


def build_layers(cfg: SNNCNNConfig) -> list[tuple]:
    """Layer descriptor list: (kind, meta...)."""
    layers: list[tuple] = []
    cin = cfg.in_channels
    size = cfg.image_size
    if cfg.arch == "vgg11":
        for item in _VGG11:
            if item == "M":
                layers.append(("maxpool",))
                size //= 2
            else:
                cout = _c(item, cfg)
                layers.append(("conv_bn_lif", cin, cout, 1))
                cin = cout
    elif cfg.arch in ("resnet11", "qkfresnet11"):
        stem = _c(64, cfg)
        layers.append(("conv_bn_lif", cin, stem, 1))
        cin = stem
        for ch, stride in _RESNET11_STAGES:
            cout = _c(ch, cfg)
            layers.append(("resblock", cin, cout, stride))
            cin = cout
            size //= stride
        if cfg.arch == "qkfresnet11":
            for _ in range(cfg.qk_blocks):
                layers.append(("qkformer", cin))
    else:
        raise ValueError(f"unknown snn-cnn arch {cfg.arch!r}")
    layers.append(("head", cin, size))
    return layers


# ----------------------------------------------------------------------- init
def init(rng: Array, cfg: SNNCNNConfig) -> dict:
    params: list = []
    state: list = []
    layers = build_layers(cfg)
    rngs = jax.random.split(rng, len(layers) + 1)
    for r, layer in zip(rngs, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            _, cin, cout, stride = layer
            bn_p, bn_s = nn.bn_init(cout, cfg.dtype)
            params.append({"conv": nn.conv_init(r, 3, 3, cin, cout, dtype=cfg.dtype),
                           "bn": bn_p})
            state.append({"bn": bn_s})
        elif kind == "maxpool":
            params.append({})
            state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            r1, r2, r3 = jax.random.split(r, 3)
            bn1p, bn1s = nn.bn_init(cout, cfg.dtype)
            bn2p, bn2s = nn.bn_init(cout, cfg.dtype)
            p = {"conv1": nn.conv_init(r1, 3, 3, cin, cout, dtype=cfg.dtype), "bn1": bn1p,
                 "conv2": nn.conv_init(r2, 3, 3, cout, cout, dtype=cfg.dtype), "bn2": bn2p}
            s = {"bn1": bn1s, "bn2": bn2s}
            if stride != 1 or cin != cout:
                bnsp, bnss = nn.bn_init(cout, cfg.dtype)
                p["conv_sc"] = nn.conv_init(r3, 1, 1, cin, cout, dtype=cfg.dtype)
                p["bn_sc"] = bnsp
                s["bn_sc"] = bnss
            params.append(p)
            state.append(s)
        elif kind == "qkformer":
            _, d = layer
            rq, rk, rp, rm1, rm2 = jax.random.split(r, 5)
            bnq_p, bnq_s = nn.bn_init(d, cfg.dtype)
            bnk_p, bnk_s = nn.bn_init(d, cfg.dtype)
            bnp_p, bnp_s = nn.bn_init(d, cfg.dtype)
            bnm1_p, bnm1_s = nn.bn_init(d, cfg.dtype)
            bnm2_p, bnm2_s = nn.bn_init(d, cfg.dtype)
            params.append({"q": nn.linear_init(rq, d, d, bias=False, dtype=cfg.dtype), "bn_q": bnq_p,
                           "k": nn.linear_init(rk, d, d, bias=False, dtype=cfg.dtype), "bn_k": bnk_p,
                           "proj": nn.linear_init(rp, d, d, bias=False, dtype=cfg.dtype), "bn_proj": bnp_p,
                           "mlp1": nn.linear_init(rm1, d, d, bias=False, dtype=cfg.dtype), "bn_mlp1": bnm1_p,
                           "mlp2": nn.linear_init(rm2, d, d, bias=False, dtype=cfg.dtype), "bn_mlp2": bnm2_p})
            state.append({"bn_q": bnq_s, "bn_k": bnk_s, "bn_proj": bnp_s,
                          "bn_mlp1": bnm1_s, "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, cin, size = layer
            # W2TTFS head pools the full (size x size) map -> FC input dim = C
            params.append({"fc": nn.linear_init(r, cin, cfg.num_classes, dtype=cfg.dtype)})
            state.append({})
    return {"params": params, "state": state}


# -------------------------------------------------------------- apply helpers
def _per_step(fn, x: Array) -> Array:
    """Apply a per-image fn over [T, B, ...] by folding T into batch."""
    t, b = x.shape[0], x.shape[1]
    y = fn(x.reshape(t * b, *x.shape[2:]))
    return y.reshape(t, b, *y.shape[1:])


def _qw(w: Array, cfg: SNNCNNConfig) -> Array:
    return fake_quant(w, cfg.quant, is_weight=True)


def _conv_bn(p, s, x, cfg, train, stride=1):
    """conv + BN over [T,B,H,W,C] (BN stats pooled over T*B), returns current."""
    conv_p = {"w": _qw(p["conv"]["w"], cfg)}
    cur = _per_step(lambda z: nn.conv_apply(conv_p, z, stride), x)
    t, b = cur.shape[0], cur.shape[1]
    flat = cur.reshape(t * b, *cur.shape[2:])
    y, new_bn = nn.bn_apply(p["bn"] if "bn" in p else p, s, flat, train)
    return y.reshape(t, b, *cur.shape[2:]), new_bn


def apply(variables: dict, images: Array, cfg: SNNCNNConfig,
          train: bool = False) -> tuple[Array, dict, dict]:
    """Forward pass. images: [B, H, W, C] analog input (direct encoding:
    repeated across T; the first conv+LIF converts it to spikes).

    Returns (logits [B, classes], new_state, aux) where aux carries per-layer
    spike counts (Total Spikes, paper Table II) and spike rates.
    """
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    new_state: list = []
    aux = {"spikes": {}, "rates": {}}
    li = 0

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            cur, bn_s = _conv_bn({"conv": p["conv"], "bn": p["bn"]}, s["bn"], x, cfg, train, stride)
            x = lif_multistep(cur, cfg.lif)
            new_state.append({"bn": bn_s})
        elif kind == "maxpool":
            x = _per_step(nn.max_pool, x)
            new_state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            cur1, bn1_s = _conv_bn({"conv": p["conv1"], "bn": p["bn1"]}, s["bn1"], x, cfg, train, stride)
            s1 = lif_multistep(cur1, cfg.lif)
            cur2, bn2_s = _conv_bn({"conv": p["conv2"], "bn": p["bn2"]}, s["bn2"], s1, cfg, train, 1)
            ns = {"bn1": bn1_s, "bn2": bn2_s}
            if "conv_sc" in p:
                sc, bnsc_s = _conv_bn({"conv": p["conv_sc"], "bn": p["bn_sc"]}, s["bn_sc"], x, cfg, train, stride)
                ns["bn_sc"] = bnsc_s
            else:
                sc = x
            # MS-ResNet shortcut: add membrane currents, then fire
            x = lif_multistep(cur2 + sc, cfg.lif)
            aux["spikes"][f"res{li}_s1"] = s1.sum()
            new_state.append(ns)
        elif kind == "qkformer":
            d = layer[1]
            tb = x.shape[:2]
            hw = x.shape[2] * x.shape[3]
            tok = x.reshape(*tb, hw, d)

            def _lin_bn(name, inp, st):
                w = _qw(p[name]["w"], cfg)
                cur = inp @ w
                flat = cur.reshape(tb[0] * tb[1], hw, d)
                y, bns = nn.bn_apply(p[f"bn_{name}"], st[f"bn_{name}"],
                                     flat.reshape(-1, d), train)
                return y.reshape(*tb, hw, d), bns

            qc, bnq_s = _lin_bn("q", tok, s)
            q = lif_multistep(qc, cfg.lif)
            kc, bnk_s = _lin_bn("k", tok, s)
            k = lif_multistep(kc, cfg.lif)
            mask = qk_token_mask(q, cfg.qk_mask_mode, surrogate=cfg.lif.surrogate,
                                 alpha=cfg.lif.alpha)
            attn = mask * k                                 # QKTA (Fig 5 (4))
            pc, bnp_s = _lin_bn("proj", attn, s)
            y = lif_multistep(pc + tok, cfg.lif)            # membrane shortcut
            m1c, bnm1_s = _lin_bn("mlp1", y, s)
            m1 = lif_multistep(m1c, cfg.lif)
            m2c, bnm2_s = _lin_bn("mlp2", m1, s)
            y2 = lif_multistep(m2c + y, cfg.lif)
            x = y2.reshape(*tb, x.shape[2], x.shape[3], d)
            aux["spikes"][f"qkf{li}_q"] = q.sum()
            aux["spikes"][f"qkf{li}_mask_on"] = mask.sum()
            new_state.append({"bn_q": bnq_s, "bn_k": bnk_s, "bn_proj": bnp_s,
                              "bn_mlp1": bnm1_s, "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, cin, size = layer
            fc_w = _qw(p["fc"]["w"], cfg)
            fc_b = p["fc"]["b"]
            window = size
            # spatial-mean over channels: FC input dim == channels (global pool)
            def head_one(spikes_t):
                if cfg.head == "w2ttfs":
                    return w2ttfs_classifier(spikes_t, fc_w, fc_b, window)
                return avgpool_classifier(spikes_t, fc_w, fc_b, window)
            logits = jnp.mean(jax.vmap(head_one)(x), axis=0)  # rate-decode over T
            new_state.append({})
        aux["spikes"][f"layer{li}"] = x.sum() if kind != "head" else aux["spikes"].get(f"layer{li}", jnp.array(0.0))
        if kind != "head":
            aux["rates"][f"layer{li}"] = x.mean()
        li += 1

    aux["total_spikes"] = sum(v for k, v in aux["spikes"].items() if k.startswith("layer"))
    return logits, new_state, aux


# ----------------------------------------------------------------- F&Q fusion
def fuse_model(variables: dict, cfg: SNNCNNConfig) -> list:
    """Paper F&Q stage: fold BN into conv/linear, fixed-point-quantize weights.

    Returns fused param list usable by ``apply_fused`` (inference only).
    """
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    fused: list = []
    bits = cfg.quant.bits if cfg.quant.enabled else None

    def q(w):
        return quantize_fixed(w, bits, axis=None) if bits else w

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            w, b = fuse_bn_into_conv(p["conv"]["w"], None, p["bn"]["scale"],
                                     p["bn"]["bias"], s["bn"]["mean"], s["bn"]["var"])
            fused.append({"conv": {"w": q(w), "b": b}})
        elif kind == "resblock":
            f = {}
            for c, bn in (("conv1", "bn1"), ("conv2", "bn2")):
                w, b = fuse_bn_into_conv(p[c]["w"], None, p[bn]["scale"],
                                         p[bn]["bias"], s[bn]["mean"], s[bn]["var"])
                f[c] = {"w": q(w), "b": b}
            if "conv_sc" in p:
                w, b = fuse_bn_into_conv(p["conv_sc"]["w"], None, p["bn_sc"]["scale"],
                                         p["bn_sc"]["bias"], s["bn_sc"]["mean"], s["bn_sc"]["var"])
                f["conv_sc"] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "qkformer":
            f = {}
            for name in ("q", "k", "proj", "mlp1", "mlp2"):
                w, b = fuse_bn_into_linear(p[name]["w"], None, p[f"bn_{name}"]["scale"],
                                           p[f"bn_{name}"]["bias"], s[f"bn_{name}"]["mean"],
                                           s[f"bn_{name}"]["var"])
                f[name] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "head":
            fused.append({"fc": {"w": q(p["fc"]["w"]), "b": p["fc"]["b"]}})
        else:
            fused.append({})
    return fused


def _fused_conv_lif(p: dict, x_spk: Array, stride: int, cfg: SNNCNNConfig,
                    *, residual: Array | None = None) -> tuple[Array, Array]:
    """conv(spikes) + bias + LIF as ONE fused PE pass (conv-as-matmul).

    x_spk: [T, B, H, W, C] binary spike maps. The 3x3/1x1 conv becomes an
    im2col spike matmul — patches of binary maps are binary, so silent
    VMEM blocks are skipped on the vld_cnt metadata, the LIF threshold is
    applied in-register, and the layer's output count map is emitted on the
    fly. ``residual`` (f32 membrane current or spikes, [T, B, Ho, Wo, Cout])
    is added before the threshold (MS-ResNet shortcut).

    Returns (spikes [T, B, Ho, Wo, Cout], vld_next [T, Mo/bm, Cout/bn]).
    """
    from ..kernels.fused_pe import fused_pe_layer

    t, b, h, w, c = x_spk.shape
    kh, kw = p["conv"]["w"].shape[:2]
    pat = nn.im2col(x_spk.reshape(t * b, h, w, c).astype(jnp.int8),
                    kh, kw, stride)
    tb2, ho, wo, kdim = pat.shape
    pat = pat.reshape(t, b * ho * wo, kdim)
    res = None
    if residual is not None:
        res = residual.reshape(t, b * ho * wo, -1).astype(jnp.float32)
    w2d = nn.conv_weights_as_matmul(p["conv"]["w"])
    spikes, vld_next = fused_pe_layer(
        pat, w2d, bias=p["conv"].get("b"), residual=res,
        tau=cfg.lif.tau, v_th=cfg.lif.v_th, soft_reset=cfg.lif.soft_reset)
    cout = w2d.shape[1]
    return spikes.reshape(t, b, ho, wo, cout).astype(cfg.dtype), vld_next


def _apply_fused_packed(fused_params: list, images: Array,
                        cfg: SNNCNNConfig) -> tuple[Array, dict]:
    """Deployed inference with the event kernels AND event compression:
    every inter-layer spike tensor lives in HBM bit-packed (PackedSpikes —
    32 spikes per int32 lane + the popcount-derived vld_cnt map), and no
    unpacked spike tensor is ever materialized between layers:

      * fused convs consume ``im2col_packed`` patches of the previous
        layer's WORDS (patch extraction is channel-preserving, so the word
        tensor im2cols unchanged) against channel-padded weights, and emit
        their spike output packed (``pack_out``);
      * max-pools are bitwise ORs of the words (pool of binary == OR);
      * the QKFormer block chains five packed-in/packed-out fused passes,
        with the Q operand's row sums taken by popcount in-kernel;
      * metadata boundaries (im2col, pooling) rebuild vld_cnt by popcount
        over the WORDS — 1/32nd of the bytes a dense re-read would touch;
      * only the W2TTFS head unpacks (it needs dense window counts).

    ``aux["spike_hbm_packed_bytes"]`` / ``aux["spike_hbm_dense_bytes"]``
    account every spike tensor shipped between kernels in each format.
    """
    from ..core.events import packed_from_words
    from ..kernels.fused_pe import fused_pe_layer
    from ..kernels.packed import pack_spikes, unpack_spikes
    from ..kernels.spike_matmul import spike_matmul

    layers = build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    aux = {"spikes": {}, "vld_reused": 0,
           "spike_hbm_packed_bytes": 0, "spike_hbm_dense_bytes": 0}
    lifkw = dict(tau=cfg.lif.tau, v_th=cfg.lif.v_th,
                 soft_reset=cfg.lif.soft_reset)
    xps = None                  # PackedSpikes [T, B*H*W, C] once spiking
    spatial = None              # (B, H, W, C)
    li = 0

    def account(ps):
        aux["spike_hbm_packed_bytes"] += ps.packed_bytes
        aux["spike_hbm_dense_bytes"] += ps.dense_bytes
        return ps

    def spatial_words(ps, sp):
        b, h, w_, _ = sp
        cw = ps.words.shape[-1]
        return ps.words[:, :b * h * w_].reshape(t * b, h, w_, cw)

    def packed_patches(ps, sp, kh, kw, stride):
        """im2col on the word tensor -> kernel-ready packed patch matrix."""
        b = sp[0]
        pat = nn.im2col_packed(spatial_words(ps, sp), kh, kw, stride)
        _, ho, wo, kww = pat.shape
        pat3 = pat.reshape(t, b * ho * wo, kww)
        return packed_from_words(pat3, (t, b * ho * wo, kww * 32)), (ho, wo)

    def conv_packed(pc, ps, sp, stride, residual=None):
        """conv(packed spikes) + bias + LIF, packed in AND out."""
        kh, kw = pc["w"].shape[:2]
        cw = ps.words.shape[-1]
        ps_pat, (ho, wo) = packed_patches(ps, sp, kh, kw, stride)
        w2d = nn.conv_weights_as_matmul_packed(pc["w"], cw * 32)
        spikes, _ = fused_pe_layer(ps_pat, w2d, bias=pc.get("b"),
                                   residual=residual, pack_out=True, **lifkw)
        return account(spikes), (sp[0], ho, wo, w2d.shape[1])

    def conv_current_packed(pc, ps, sp, stride):
        """Shortcut conv: packed patches -> event matmul -> f32 current."""
        kh, kw = pc["w"].shape[:2]
        cw = ps.words.shape[-1]
        ps_pat, _ = packed_patches(ps, sp, kh, kw, stride)
        w2d = nn.conv_weights_as_matmul_packed(pc["w"], cw * 32)
        cur = jnp.stack([spike_matmul(ps_pat[ti], w2d) for ti in range(t)])
        return cur + pc["b"].astype(jnp.float32)

    for p, layer in zip(fused_params, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            if xps is not None:
                xps, spatial = conv_packed(p["conv"], xps, spatial, stride)
            else:
                # analog input: dense conv + LIF, then enter the packed
                # domain (the first binary map is the first compressible one)
                cur = _per_step(lambda z: nn.conv_apply(p["conv"], z, stride),
                                x)
                spk = lif_multistep(cur, cfg.lif)
                b, h, w_, c = spk.shape[1:]
                xps = account(pack_spikes(
                    spk.reshape(t, b * h * w_, c).astype(jnp.int8)))
                spatial = (b, h, w_, c)
        elif kind == "maxpool":
            b, h, w_, c = spatial
            pooled = nn.max_pool_packed(spatial_words(xps, spatial))
            h2, w2 = pooled.shape[1], pooled.shape[2]
            xps = account(packed_from_words(
                pooled.reshape(t, b * h2 * w2, pooled.shape[3]),
                (t, b * h2 * w2, c)))
            spatial = (b, h2, w2, c)
        elif kind == "resblock":
            stride = layer[3]
            s1, sp1 = conv_packed(p["conv1"], xps, spatial, stride)
            if "conv_sc" in p:
                sc = conv_current_packed(p["conv_sc"], xps, spatial, stride)
            else:
                sc = xps            # identity: packed binary shortcut
            xps, spatial = conv_packed(p["conv2"], s1, sp1, 1, residual=sc)
        elif kind == "qkformer":
            # five packed-in/packed-out fused passes; every pass consumes
            # the vld map its producer emitted in-kernel (and the packed Q
            # operand's row sums are popcounts — no unpack anywhere)
            tok = xps
            q3, _ = fused_pe_layer(tok, p["q"]["w"], bias=p["q"]["b"],
                                   pack_out=True, **lifkw)
            attn3, _ = fused_pe_layer(tok, p["k"]["w"], bias=p["k"]["b"],
                                      q=q3, qk_threshold=1.0,
                                      pack_out=True, **lifkw)
            y3, _ = fused_pe_layer(attn3, p["proj"]["w"], bias=p["proj"]["b"],
                                   residual=tok, pack_out=True, **lifkw)
            m13, _ = fused_pe_layer(y3, p["mlp1"]["w"], bias=p["mlp1"]["b"],
                                    pack_out=True, **lifkw)
            y23, _ = fused_pe_layer(m13, p["mlp2"]["w"], bias=p["mlp2"]["b"],
                                    residual=y3, pack_out=True, **lifkw)
            for ps in (q3, attn3, y3, m13, y23):
                account(ps)
            aux["vld_reused"] += 5
            xps = y23
        elif kind == "head":
            _, cin, size = layer
            b, h, w_, c = spatial
            xd = unpack_spikes(xps).astype(cfg.dtype)
            xd = xd.reshape(t, b, h, w_, c)
            logits = jnp.mean(jax.vmap(
                lambda st: w2ttfs_classifier(st, p["fc"]["w"], p["fc"]["b"],
                                             size)
                if cfg.head == "w2ttfs" else
                avgpool_classifier(st, p["fc"]["w"], p["fc"]["b"], size))(xd),
                axis=0)
        if kind != "head":
            aux["spikes"][f"layer{li}"] = xps.vld_cnt.sum().astype(
                jnp.float32)
        li += 1
    aux["total_spikes"] = sum(aux["spikes"].values())
    return logits, aux


def apply_fused(fused_params: list, images: Array, cfg: SNNCNNConfig) -> tuple[Array, dict]:
    """Inference with the fused+quantized (deployment) model — conv+bias+LIF,
    no BN. This is the computation NEURAL's EPA executes.

    With ``cfg.use_event_kernels`` every binary-activation layer runs the
    fused PE dataflow kernel (C3 + C4 in one Pallas pass): conv-as-matmul
    spike matmul with vld_cnt block skipping, in-register LIF, QK token mask
    on write-back, and on-the-fly emission of the NEXT layer's vld_cnt map.
    The emitted metadata is chained layer-to-layer wherever the flattened
    [tokens, channels] layout is preserved (resblock -> QKFormer -> QKFormer
    chains); im2col and pooling reshuffle the layout, so those boundaries
    recompute the map. ``aux["vld_reused"]`` counts the chained hand-offs.

    With ``cfg.spike_format == "packed"`` (the default) the event path also
    ships every inter-layer spike tensor bit-packed — see
    ``_apply_fused_packed``; ``spike_format="dense"`` keeps int8 maps.
    """
    if cfg.use_event_kernels and cfg.spike_format == "packed":
        return _apply_fused_packed(fused_params, images, cfg)
    layers = build_layers(cfg)
    t = cfg.timesteps
    ev = cfg.use_event_kernels
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    aux = {"spikes": {}, "vld_reused": 0}
    li = 0
    spiking_input = False       # first conv consumes the analog image
    vld = None                  # on-the-fly metadata for x as [T, M, C]
    for p, layer in zip(fused_params, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            if ev and spiking_input:
                x, vld = _fused_conv_lif(p, x, stride, cfg)
            else:
                cur = _per_step(lambda z: nn.conv_apply(p["conv"], z, stride), x)
                x = lif_multistep(cur, cfg.lif)
                vld = None
            spiking_input = True
        elif kind == "maxpool":
            x = _per_step(nn.max_pool, x)
            vld = None          # pooling reshuffles the token layout
        elif kind == "resblock":
            stride = layer[3]
            if ev and spiking_input:
                s1, _ = _fused_conv_lif({"conv": p["conv1"]}, x, stride, cfg)
                if "conv_sc" in p:
                    # 1x1 shortcut conv: binary input -> event matmul; its
                    # output is a membrane CURRENT (no LIF), added as the
                    # residual operand of conv2's fused pass
                    from ..kernels.spike_matmul import spike_matmul
                    tb_, h_, w_, c_ = x.shape[1:]
                    scp = nn.im2col(
                        x.reshape(t * tb_, h_, w_, c_).astype(jnp.int8),
                        *p["conv_sc"]["w"].shape[:2], stride)
                    sc = (spike_matmul(
                        scp.reshape(-1, scp.shape[-1]),
                        nn.conv_weights_as_matmul(p["conv_sc"]["w"]))
                        + p["conv_sc"]["b"]).reshape(t, tb_, *scp.shape[1:3],
                                                     -1)
                else:
                    sc = x
                x, vld = _fused_conv_lif({"conv": p["conv2"]}, s1, 1, cfg,
                                         residual=sc)
            else:
                cur1 = _per_step(lambda z: nn.conv_apply(p["conv1"], z, stride), x)
                s1 = lif_multistep(cur1, cfg.lif)
                cur2 = _per_step(lambda z: nn.conv_apply(p["conv2"], z, 1), s1)
                sc = _per_step(lambda z: nn.conv_apply(p["conv_sc"], z, stride), x) if "conv_sc" in p else x
                x = lif_multistep(cur2 + sc, cfg.lif)
                vld = None
            spiking_input = True
        elif kind == "qkformer":
            d = layer[1]
            tb = x.shape[:2]
            hw = x.shape[2] * x.shape[3]
            tok = x.reshape(*tb, hw, d)

            if ev:
                # fully fused event path (C3+C4): each linear+LIF is ONE
                # fused PE pass; the K pass applies the QK token mask on
                # write-back (Fig 5) and every pass emits the next pass's
                # vld_cnt metadata — zero standalone reduction passes
                from ..kernels.fused_pe import fused_pe_layer

                tok3 = tok.reshape(t, tb[1] * hw, d).astype(jnp.int8)
                tok_vld = vld   # previous layer's on-the-fly metadata
                lifkw = dict(tau=cfg.lif.tau, v_th=cfg.lif.v_th,
                             soft_reset=cfg.lif.soft_reset)

                q3, _ = fused_pe_layer(tok3, p["q"]["w"], bias=p["q"]["b"],
                                       vld_cnt=tok_vld, **lifkw)
                # atten_reg "or" mode == rowsum >= 1 on integer spike counts
                attn3, vld_a = fused_pe_layer(
                    tok3, p["k"]["w"], bias=p["k"]["b"], vld_cnt=tok_vld,
                    q=q3, qk_threshold=1.0, **lifkw)
                y3, vld_y = fused_pe_layer(
                    attn3, p["proj"]["w"], bias=p["proj"]["b"],
                    residual=tok3, vld_cnt=vld_a, **lifkw)
                m13, vld_m = fused_pe_layer(y3, p["mlp1"]["w"],
                                            bias=p["mlp1"]["b"],
                                            vld_cnt=vld_y, **lifkw)
                y23, vld = fused_pe_layer(m13, p["mlp2"]["w"],
                                          bias=p["mlp2"]["b"], residual=y3,
                                          vld_cnt=vld_m, **lifkw)
                # q+k consumed the inbound map; proj/mlp1/mlp2 consumed maps
                # emitted by the pass right before them
                aux["vld_reused"] += 3 + (2 if tok_vld is not None else 0)
                x = y23.reshape(*tb, x.shape[2], x.shape[3], d
                                ).astype(cfg.dtype)
            else:
                def smm(spk, w):
                    return spk @ w

                q = lif_multistep(smm(tok, p["q"]["w"]) + p["q"]["b"], cfg.lif)
                k = lif_multistep(smm(tok, p["k"]["w"]) + p["k"]["b"], cfg.lif)
                mask = qk_token_mask(q, "or")    # hardware atten_reg mode
                attn = mask * k                  # still binary (mask x spikes)
                y = lif_multistep(smm(attn, p["proj"]["w"]) + p["proj"]["b"] + tok,
                                  cfg.lif)
                m1 = lif_multistep(smm(y, p["mlp1"]["w"]) + p["mlp1"]["b"], cfg.lif)
                y2 = lif_multistep(smm(m1, p["mlp2"]["w"]) + p["mlp2"]["b"] + y,
                                   cfg.lif)
                x = y2.reshape(*tb, x.shape[2], x.shape[3], d)
                vld = None
        elif kind == "head":
            _, cin, size = layer
            logits = jnp.mean(jax.vmap(
                lambda st: w2ttfs_classifier(st, p["fc"]["w"], p["fc"]["b"], size)
                if cfg.head == "w2ttfs" else
                avgpool_classifier(st, p["fc"]["w"], p["fc"]["b"], size))(x), axis=0)
        if kind != "head":
            aux["spikes"][f"layer{li}"] = x.sum()
        li += 1
    aux["total_spikes"] = sum(aux["spikes"].values())
    return logits, aux
