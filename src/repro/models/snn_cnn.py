"""The paper's deployed SNN models: VGG-11, ResNet-11, QKFResNet-11 (Fig 2a).

Execution contract (matches the NEURAL pipeline):
  * multi-timestep tensors are [T, B, H, W, C]; the paper's deployed mode is
    T=1 (single-timestep, C1) and T>1 is the baseline it beats;
  * every activation between layers is a BINARY SPIKE map (LIF outputs);
  * the classifier head is W2TTFS (C2) — ``head="avgpool"`` gives the
    non-spiking ANN-style head used by the F&Q ablation;
  * QKFResNet-11 = ResNet-11 + spiking QKFormer block(s) (C4) on the final
    feature map tokens;
  * ``fuse_model`` folds BN into conv and applies fixed-point quantization —
    the paper's F&Q stage producing the hardware deployment artifact.

Models are list-of-layer-descriptor driven so init / apply / fuse walk the
same structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .. import ops
from ..core.lif import LIFConfig, lif_multistep
from ..core.quant import QuantConfig, fake_quant, fuse_bn_into_conv, fuse_bn_into_linear, quantize_fixed
from ..core.qk_attention import qk_token_mask, qk_channel_mask
from ..core.w2ttfs import w2ttfs_classifier, avgpool_classifier
from ..ops import SpikeTensor
from . import nn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SNNCNNConfig:
    arch: str = "vgg11"             # vgg11 | resnet11 | qkfresnet11
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    width_mult: float = 1.0
    timesteps: int = 1              # T=1 is the paper's deployed mode
    lif: LIFConfig = LIFConfig()
    quant: QuantConfig = QuantConfig()
    head: str = "w2ttfs"            # w2ttfs | avgpool
    qk_blocks: int = 1
    qk_mask_mode: str = "threshold"  # threshold | or  (Fig 5 atten_reg = "or")
    dtype: Any = jnp.float32
    # policy: how apply_fused (the deployed-inference path) executes —
    # "reference" (the None default; pure jnp), "fused_dense" (event-driven
    # Pallas kernels, int8 maps between layers), or "fused_packed" (event
    # kernels + bit-packed inter-layer spike tensors, ~8x fewer spike
    # bytes). All three emit bit-identical spikes; see
    # repro.ops.ExecutionPolicy.
    policy: Optional[Any] = None    # ExecutionPolicy | preset name | None
    # deprecated flag pair -> policy (repro.ops.compat translates + warns);
    # this model's historical default spike format was "packed", so a bare
    # legacy event-kernel flag maps to "fused_packed"
    use_event_kernels: Optional[bool] = None
    spike_format: Optional[str] = None

    def __post_init__(self):
        resolved = ops.legacy_flags_policy(
            "SNNCNNConfig", self.policy, self.use_event_kernels,
            self.spike_format, default_format="packed")
        if self.policy is not None:
            object.__setattr__(self, "policy", resolved)

    @property
    def exec_policy(self) -> ops.ExecutionPolicy:
        pol = ops.legacy_flags_policy(
            "SNNCNNConfig", self.policy, self.use_event_kernels,
            self.spike_format, default_format="packed", warn=False)
        return pol if pol is not None else ops.REFERENCE


# --------------------------------------------------------------- arch tables
_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512]
_RESNET11_STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]


def _c(ch: int, cfg: SNNCNNConfig) -> int:
    return max(8, int(ch * cfg.width_mult))


def build_layers(cfg: SNNCNNConfig) -> list[tuple]:
    """Layer descriptor list: (kind, meta...)."""
    layers: list[tuple] = []
    cin = cfg.in_channels
    size = cfg.image_size
    if cfg.arch == "vgg11":
        for item in _VGG11:
            if item == "M":
                layers.append(("maxpool",))
                size //= 2
            else:
                cout = _c(item, cfg)
                layers.append(("conv_bn_lif", cin, cout, 1))
                cin = cout
    elif cfg.arch in ("resnet11", "qkfresnet11"):
        stem = _c(64, cfg)
        layers.append(("conv_bn_lif", cin, stem, 1))
        cin = stem
        for ch, stride in _RESNET11_STAGES:
            cout = _c(ch, cfg)
            layers.append(("resblock", cin, cout, stride))
            cin = cout
            size //= stride
        if cfg.arch == "qkfresnet11":
            for _ in range(cfg.qk_blocks):
                layers.append(("qkformer", cin))
    else:
        raise ValueError(f"unknown snn-cnn arch {cfg.arch!r}")
    layers.append(("head", cin, size))
    return layers


# ----------------------------------------------------------------------- init
def init(rng: Array, cfg: SNNCNNConfig) -> dict:
    params: list = []
    state: list = []
    layers = build_layers(cfg)
    rngs = jax.random.split(rng, len(layers) + 1)
    for r, layer in zip(rngs, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            _, cin, cout, stride = layer
            bn_p, bn_s = nn.bn_init(cout, cfg.dtype)
            params.append({"conv": nn.conv_init(r, 3, 3, cin, cout, dtype=cfg.dtype),
                           "bn": bn_p})
            state.append({"bn": bn_s})
        elif kind == "maxpool":
            params.append({})
            state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            r1, r2, r3 = jax.random.split(r, 3)
            bn1p, bn1s = nn.bn_init(cout, cfg.dtype)
            bn2p, bn2s = nn.bn_init(cout, cfg.dtype)
            p = {"conv1": nn.conv_init(r1, 3, 3, cin, cout, dtype=cfg.dtype), "bn1": bn1p,
                 "conv2": nn.conv_init(r2, 3, 3, cout, cout, dtype=cfg.dtype), "bn2": bn2p}
            s = {"bn1": bn1s, "bn2": bn2s}
            if stride != 1 or cin != cout:
                bnsp, bnss = nn.bn_init(cout, cfg.dtype)
                p["conv_sc"] = nn.conv_init(r3, 1, 1, cin, cout, dtype=cfg.dtype)
                p["bn_sc"] = bnsp
                s["bn_sc"] = bnss
            params.append(p)
            state.append(s)
        elif kind == "qkformer":
            _, d = layer
            rq, rk, rp, rm1, rm2 = jax.random.split(r, 5)
            bnq_p, bnq_s = nn.bn_init(d, cfg.dtype)
            bnk_p, bnk_s = nn.bn_init(d, cfg.dtype)
            bnp_p, bnp_s = nn.bn_init(d, cfg.dtype)
            bnm1_p, bnm1_s = nn.bn_init(d, cfg.dtype)
            bnm2_p, bnm2_s = nn.bn_init(d, cfg.dtype)
            params.append({"q": nn.linear_init(rq, d, d, bias=False, dtype=cfg.dtype), "bn_q": bnq_p,
                           "k": nn.linear_init(rk, d, d, bias=False, dtype=cfg.dtype), "bn_k": bnk_p,
                           "proj": nn.linear_init(rp, d, d, bias=False, dtype=cfg.dtype), "bn_proj": bnp_p,
                           "mlp1": nn.linear_init(rm1, d, d, bias=False, dtype=cfg.dtype), "bn_mlp1": bnm1_p,
                           "mlp2": nn.linear_init(rm2, d, d, bias=False, dtype=cfg.dtype), "bn_mlp2": bnm2_p})
            state.append({"bn_q": bnq_s, "bn_k": bnk_s, "bn_proj": bnp_s,
                          "bn_mlp1": bnm1_s, "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, cin, size = layer
            # W2TTFS head pools the full (size x size) map -> FC input dim = C
            params.append({"fc": nn.linear_init(r, cin, cfg.num_classes, dtype=cfg.dtype)})
            state.append({})
    return {"params": params, "state": state}


# -------------------------------------------------------------- apply helpers
def _per_step(fn, x: Array) -> Array:
    """Apply a per-image fn over [T, B, ...] by folding T into batch."""
    t, b = x.shape[0], x.shape[1]
    y = fn(x.reshape(t * b, *x.shape[2:]))
    return y.reshape(t, b, *y.shape[1:])


def _qw(w: Array, cfg: SNNCNNConfig) -> Array:
    return fake_quant(w, cfg.quant, is_weight=True)


def _conv_bn(p, s, x, cfg, train, stride=1):
    """conv + BN over [T,B,H,W,C] (BN stats pooled over T*B), returns current."""
    conv_p = {"w": _qw(p["conv"]["w"], cfg)}
    cur = _per_step(lambda z: nn.conv_apply(conv_p, z, stride), x)
    t, b = cur.shape[0], cur.shape[1]
    flat = cur.reshape(t * b, *cur.shape[2:])
    y, new_bn = nn.bn_apply(p["bn"] if "bn" in p else p, s, flat, train)
    return y.reshape(t, b, *cur.shape[2:]), new_bn


def apply(variables: dict, images: Array, cfg: SNNCNNConfig,
          train: bool = False) -> tuple[Array, dict, dict]:
    """Forward pass. images: [B, H, W, C] analog input (direct encoding:
    repeated across T; the first conv+LIF converts it to spikes).

    Returns (logits [B, classes], new_state, aux) where aux carries per-layer
    spike counts (Total Spikes, paper Table II) and spike rates.
    """
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    new_state: list = []
    aux = {"spikes": {}, "rates": {}}
    li = 0

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            cur, bn_s = _conv_bn({"conv": p["conv"], "bn": p["bn"]}, s["bn"], x, cfg, train, stride)
            x = lif_multistep(cur, cfg.lif)
            new_state.append({"bn": bn_s})
        elif kind == "maxpool":
            x = _per_step(nn.max_pool, x)
            new_state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            cur1, bn1_s = _conv_bn({"conv": p["conv1"], "bn": p["bn1"]}, s["bn1"], x, cfg, train, stride)
            s1 = lif_multistep(cur1, cfg.lif)
            cur2, bn2_s = _conv_bn({"conv": p["conv2"], "bn": p["bn2"]}, s["bn2"], s1, cfg, train, 1)
            ns = {"bn1": bn1_s, "bn2": bn2_s}
            if "conv_sc" in p:
                sc, bnsc_s = _conv_bn({"conv": p["conv_sc"], "bn": p["bn_sc"]}, s["bn_sc"], x, cfg, train, stride)
                ns["bn_sc"] = bnsc_s
            else:
                sc = x
            # MS-ResNet shortcut: add membrane currents, then fire
            x = lif_multistep(cur2 + sc, cfg.lif)
            aux["spikes"][f"res{li}_s1"] = s1.sum()
            new_state.append(ns)
        elif kind == "qkformer":
            d = layer[1]
            tb = x.shape[:2]
            hw = x.shape[2] * x.shape[3]
            tok = x.reshape(*tb, hw, d)

            def _lin_bn(name, inp, st):
                w = _qw(p[name]["w"], cfg)
                cur = inp @ w
                flat = cur.reshape(tb[0] * tb[1], hw, d)
                y, bns = nn.bn_apply(p[f"bn_{name}"], st[f"bn_{name}"],
                                     flat.reshape(-1, d), train)
                return y.reshape(*tb, hw, d), bns

            qc, bnq_s = _lin_bn("q", tok, s)
            q = lif_multistep(qc, cfg.lif)
            kc, bnk_s = _lin_bn("k", tok, s)
            k = lif_multistep(kc, cfg.lif)
            mask = qk_token_mask(q, cfg.qk_mask_mode, surrogate=cfg.lif.surrogate,
                                 alpha=cfg.lif.alpha)
            attn = mask * k                                 # QKTA (Fig 5 (4))
            pc, bnp_s = _lin_bn("proj", attn, s)
            y = lif_multistep(pc + tok, cfg.lif)            # membrane shortcut
            m1c, bnm1_s = _lin_bn("mlp1", y, s)
            m1 = lif_multistep(m1c, cfg.lif)
            m2c, bnm2_s = _lin_bn("mlp2", m1, s)
            y2 = lif_multistep(m2c + y, cfg.lif)
            x = y2.reshape(*tb, x.shape[2], x.shape[3], d)
            aux["spikes"][f"qkf{li}_q"] = q.sum()
            aux["spikes"][f"qkf{li}_mask_on"] = mask.sum()
            new_state.append({"bn_q": bnq_s, "bn_k": bnk_s, "bn_proj": bnp_s,
                              "bn_mlp1": bnm1_s, "bn_mlp2": bnm2_s})
        elif kind == "head":
            _, cin, size = layer
            fc_w = _qw(p["fc"]["w"], cfg)
            fc_b = p["fc"]["b"]
            window = size
            # spatial-mean over channels: FC input dim == channels (global pool)
            def head_one(spikes_t):
                if cfg.head == "w2ttfs":
                    return w2ttfs_classifier(spikes_t, fc_w, fc_b, window)
                return avgpool_classifier(spikes_t, fc_w, fc_b, window)
            logits = jnp.mean(jax.vmap(head_one)(x), axis=0)  # rate-decode over T
            new_state.append({})
        aux["spikes"][f"layer{li}"] = x.sum() if kind != "head" else aux["spikes"].get(f"layer{li}", jnp.array(0.0))
        if kind != "head":
            aux["rates"][f"layer{li}"] = x.mean()
        li += 1

    aux["total_spikes"] = sum(v for k, v in aux["spikes"].items() if k.startswith("layer"))
    return logits, new_state, aux


# ----------------------------------------------------------------- F&Q fusion
def fuse_model(variables: dict, cfg: SNNCNNConfig) -> list:
    """Paper F&Q stage: fold BN into conv/linear, fixed-point-quantize weights.

    Returns fused param list usable by ``apply_fused`` (inference only).
    """
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    fused: list = []
    bits = cfg.quant.bits if cfg.quant.enabled else None

    def q(w):
        return quantize_fixed(w, bits, axis=None) if bits else w

    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            w, b = fuse_bn_into_conv(p["conv"]["w"], None, p["bn"]["scale"],
                                     p["bn"]["bias"], s["bn"]["mean"], s["bn"]["var"])
            fused.append({"conv": {"w": q(w), "b": b}})
        elif kind == "resblock":
            f = {}
            for c, bn in (("conv1", "bn1"), ("conv2", "bn2")):
                w, b = fuse_bn_into_conv(p[c]["w"], None, p[bn]["scale"],
                                         p[bn]["bias"], s[bn]["mean"], s[bn]["var"])
                f[c] = {"w": q(w), "b": b}
            if "conv_sc" in p:
                w, b = fuse_bn_into_conv(p["conv_sc"]["w"], None, p["bn_sc"]["scale"],
                                         p["bn_sc"]["bias"], s["bn_sc"]["mean"], s["bn_sc"]["var"])
                f["conv_sc"] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "qkformer":
            f = {}
            for name in ("q", "k", "proj", "mlp1", "mlp2"):
                w, b = fuse_bn_into_linear(p[name]["w"], None, p[f"bn_{name}"]["scale"],
                                           p[f"bn_{name}"]["bias"], s[f"bn_{name}"]["mean"],
                                           s[f"bn_{name}"]["var"])
                f[name] = {"w": q(w), "b": b}
            fused.append(f)
        elif kind == "head":
            fused.append({"fc": {"w": q(p["fc"]["w"]), "b": p["fc"]["b"]}})
        else:
            fused.append({})
    return fused


def _account(aux: dict, st: SpikeTensor, packed: bool) -> SpikeTensor:
    """HBM accounting for every spike tensor shipped between kernels, in
    whatever format it shipped."""
    aux["spike_hbm_bytes"] += st.hbm_bytes
    if packed:
        aux["spike_hbm_packed_bytes"] += st.hbm_bytes
        aux["spike_hbm_dense_bytes"] += st.dense_bytes
    return st


def _apply_fused_event(fused_params: list, images: Array, cfg: SNNCNNConfig,
                       policy: "ops.ExecutionPolicy") -> tuple[Array, dict]:
    """Deployed inference on the event-driven kernels — ONE format-agnostic
    body for both HBM formats (this used to be two hand-maintained forks).

    Every inter-layer activation is a ``SpikeTensor`` in token layout
    [T, B*H*W, C]; the format (int8 maps vs bit-packed words) comes from
    the policy and every format-sensitive step is an ``ops.*`` call:

      * convs are ``ops.im2col`` patches (channel-preserving, so the packed
        variant im2cols the WORD tensor) driven through
        ``ops.fused_pe_layer`` — conv + bias + LIF threshold in one fused
        PE pass, with the emitted spikes leaving in the policy's format;
      * max-pools are ``ops.pool`` (packed: bitwise OR of the words);
      * the QKFormer block chains five fused passes; each consumes the
        ``vld_cnt`` its producer emitted in-kernel (``aux["vld_reused"]``
        counts the hand-offs) and the Q operand's row sums are popcounts
        when packed;
      * only the W2TTFS head materializes a dense map (``ops.unpack``).

    ``aux["spike_hbm_bytes"]`` accounts every spike tensor shipped between
    kernels in its shipped format (plus the packed/dense pair of keys for
    the compression ratio when the policy is packed). Bit-identical spikes
    and logits across "fused_packed" / "fused_dense" / "reference".
    """
    layers = build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    aux = {"spikes": {}, "vld_reused": 0, "spike_hbm_bytes": 0}
    if policy.packed:
        aux["spike_hbm_packed_bytes"] = 0
        aux["spike_hbm_dense_bytes"] = 0
    st: Optional[SpikeTensor] = None   # [T, B*H*W, C] once the net spikes
    spatial = None                     # (B, H, W, C)
    li = 0

    def conv_lif(pc: dict, s_in: SpikeTensor, sp: tuple, stride: int,
                 residual=None) -> tuple[SpikeTensor, tuple]:
        """conv(spikes) + bias + LIF as ONE fused PE pass (conv-as-matmul),
        emitting in the policy's format."""
        kh, kw = pc["w"].shape[:2]
        pat, (ho, wo) = ops.im2col(s_in, sp, kh, kw, stride, t=t,
                                   policy=policy)
        w2d = ops.conv_matmul_weights(pc["w"], pat)
        out = ops.fused_pe_layer(pat, w2d, bias=pc.get("b"),
                                 residual=residual, lif_cfg=cfg.lif,
                                 policy=policy)
        return (_account(aux, out.spikes, policy.packed),
                (sp[0], ho, wo, w2d.shape[1]))

    def conv_current(pc: dict, s_in: SpikeTensor, sp: tuple,
                     stride: int) -> Array:
        """Shortcut conv: event-skipped matmul -> f32 membrane current
        (no LIF — it joins conv2's fused pass as the residual operand)."""
        kh, kw = pc["w"].shape[:2]
        pat, _ = ops.im2col(s_in, sp, kh, kw, stride, t=t, policy=policy)
        w2d = ops.conv_matmul_weights(pc["w"], pat)
        cur = jnp.stack([ops.matmul(pat[ti], w2d, policy=policy)
                         for ti in range(t)])
        return cur + pc["b"].astype(jnp.float32)

    for p, layer in zip(fused_params, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            if st is not None:
                st, spatial = conv_lif(p["conv"], st, spatial, stride)
            else:
                # analog input: dense conv + LIF, then enter the spiking
                # domain (the first binary map is the first event tensor)
                cur = _per_step(lambda z: nn.conv_apply(p["conv"], z, stride),
                                x)
                spk = lif_multistep(cur, cfg.lif)
                b, h, w_, c = spk.shape[1:]
                flat = spk.reshape(t, b * h * w_, c).astype(jnp.int8)
                st = _account(aux,
                              ops.pack(flat) if policy.packed
                              else SpikeTensor.dense(flat), policy.packed)
                spatial = (b, h, w_, c)
        elif kind == "maxpool":
            st, (h2, w2) = ops.pool(st, spatial, t=t, policy=policy)
            st = _account(aux, st, policy.packed)
            spatial = (spatial[0], h2, w2, spatial[3])
        elif kind == "resblock":
            stride = layer[3]
            s1, sp1 = conv_lif(p["conv1"], st, spatial, stride)
            if "conv_sc" in p:
                res = conv_current(p["conv_sc"], st, spatial, stride)
            else:
                res = st            # identity: binary spike shortcut
            st, spatial = conv_lif(p["conv2"], s1, sp1, 1, residual=res)
        elif kind == "qkformer":
            # five fused passes, format-agnostic: each consumes the vld map
            # its producer emitted in-kernel (the on-the-fly dataflow), the
            # K pass applies the QK token mask on write-back (Fig 5), and
            # spike maps cross HBM in the policy's format throughout
            tok = st
            lifkw = dict(lif_cfg=cfg.lif, policy=policy)
            q3 = ops.fused_pe_layer(tok, p["q"]["w"], bias=p["q"]["b"],
                                    **lifkw).spikes
            # atten_reg "or" mode == rowsum >= 1 on integer spike counts
            attn3 = ops.fused_pe_layer(tok, p["k"]["w"], bias=p["k"]["b"],
                                       q=q3, qk_threshold=1.0,
                                       **lifkw).spikes
            y3 = ops.fused_pe_layer(attn3, p["proj"]["w"],
                                    bias=p["proj"]["b"], residual=tok,
                                    **lifkw).spikes
            m13 = ops.fused_pe_layer(y3, p["mlp1"]["w"], bias=p["mlp1"]["b"],
                                     **lifkw).spikes
            y23 = ops.fused_pe_layer(m13, p["mlp2"]["w"],
                                     bias=p["mlp2"]["b"], residual=y3,
                                     **lifkw).spikes
            for s_ in (q3, attn3, y3, m13, y23):
                _account(aux, s_, policy.packed)
            aux["vld_reused"] += sum(
                1 for s_ in (tok, tok, attn3, y3, m13)
                if s_.vld_cnt is not None)
            st = y23
        elif kind == "head":
            _, cin, size = layer
            b, h, w_, c = spatial
            xd = ops.unpack(st, policy=policy).astype(cfg.dtype)
            xd = xd.reshape(t, b, h, w_, c)
            logits = jnp.mean(jax.vmap(
                lambda s_t: w2ttfs_classifier(s_t, p["fc"]["w"],
                                              p["fc"]["b"], size)
                if cfg.head == "w2ttfs" else
                avgpool_classifier(s_t, p["fc"]["w"], p["fc"]["b"],
                                   size))(xd), axis=0)
        if kind != "head":
            aux["spikes"][f"layer{li}"] = st.count()
        li += 1
    aux["total_spikes"] = sum(aux["spikes"].values())
    return logits, aux


def _apply_fused_reference(fused_params: list, images: Array,
                           cfg: SNNCNNConfig) -> tuple[Array, dict]:
    """Pure-jnp oracle for the deployed model (no Pallas kernels): the
    numerics-debugging path and the parity baseline for the event body."""
    layers = build_layers(cfg)
    t = cfg.timesteps
    x = jnp.broadcast_to(images[None], (t, *images.shape)).astype(cfg.dtype)
    aux = {"spikes": {}, "vld_reused": 0}
    li = 0
    for p, layer in zip(fused_params, layers):
        kind = layer[0]
        if kind == "conv_bn_lif":
            stride = layer[3]
            cur = _per_step(lambda z: nn.conv_apply(p["conv"], z, stride), x)
            x = lif_multistep(cur, cfg.lif)
        elif kind == "maxpool":
            x = _per_step(nn.max_pool, x)
        elif kind == "resblock":
            stride = layer[3]
            cur1 = _per_step(lambda z: nn.conv_apply(p["conv1"], z, stride),
                             x)
            s1 = lif_multistep(cur1, cfg.lif)
            cur2 = _per_step(lambda z: nn.conv_apply(p["conv2"], z, 1), s1)
            sc = _per_step(lambda z: nn.conv_apply(p["conv_sc"], z, stride),
                           x) if "conv_sc" in p else x
            x = lif_multistep(cur2 + sc, cfg.lif)
        elif kind == "qkformer":
            d = layer[1]
            tb = x.shape[:2]
            hw = x.shape[2] * x.shape[3]
            tok = x.reshape(*tb, hw, d)
            q = lif_multistep(tok @ p["q"]["w"] + p["q"]["b"], cfg.lif)
            k = lif_multistep(tok @ p["k"]["w"] + p["k"]["b"], cfg.lif)
            mask = qk_token_mask(q, "or")    # hardware atten_reg mode
            attn = mask * k                  # still binary (mask x spikes)
            y = lif_multistep(attn @ p["proj"]["w"] + p["proj"]["b"] + tok,
                              cfg.lif)
            m1 = lif_multistep(y @ p["mlp1"]["w"] + p["mlp1"]["b"], cfg.lif)
            y2 = lif_multistep(m1 @ p["mlp2"]["w"] + p["mlp2"]["b"] + y,
                               cfg.lif)
            x = y2.reshape(*tb, x.shape[2], x.shape[3], d)
        elif kind == "head":
            _, cin, size = layer
            logits = jnp.mean(jax.vmap(
                lambda s_t: w2ttfs_classifier(s_t, p["fc"]["w"],
                                              p["fc"]["b"], size)
                if cfg.head == "w2ttfs" else
                avgpool_classifier(s_t, p["fc"]["w"], p["fc"]["b"],
                                   size))(x), axis=0)
        if kind != "head":
            aux["spikes"][f"layer{li}"] = x.sum()
        li += 1
    aux["total_spikes"] = sum(aux["spikes"].values())
    return logits, aux


def apply_fused(fused_params: list, images: Array, cfg: SNNCNNConfig,
                policy=None) -> tuple[Array, dict]:
    """Inference with the fused+quantized (deployment) model — conv+bias+LIF,
    no BN. This is the computation NEURAL's EPA executes.

    ``policy`` (or ``cfg.exec_policy`` when None) selects the execution
    mode: "reference" runs the pure-jnp oracle; "fused_dense" runs every
    binary-activation layer through the fused PE dataflow kernel (C3 + C4
    in one Pallas pass: conv-as-matmul spike matmul with vld_cnt block
    skipping, in-register LIF, QK token mask on write-back, on-the-fly
    emission of the next layer's metadata); "fused_packed" additionally
    ships every inter-layer spike tensor bit-packed. All three are
    bit-identical in spikes and logits — the whole point of the hybrid
    flow is one computation, many execution formats.
    """
    pol = ops.as_policy(policy, cfg.exec_policy)
    if not pol.fused:
        return _apply_fused_reference(fused_params, images, cfg)
    return _apply_fused_event(fused_params, images, cfg, pol)
