"""Feed-forward layers: dense SwiGLU and expert-parallel MoE.

MoE uses the GShard/MaxText "dropping" formulation — two one-hot einsums
around batched expert matmuls — because it shards cleanly under GSPMD:
tokens grouped on the ('pod','data') axes, experts on 'model' (EP == TP
axis). The combine einsum contracts the expert axis, which GSPMD lowers to
the expected all-reduce over 'model' — that IS the EP combine collective.

The paper's spiking mode (C3) replaces the SiLU gate with a LIF spike: the
hidden activation becomes a binary event map, which is what the event-driven
``spike_matmul`` kernel consumes (block-sparse skip on silent tiles).

Router details follow OLMoE/llama4: softmax router, top-k selection,
optional renormalization, auxiliary load-balance loss (Switch-style) and
router-z loss for logit control.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_apply, dense_init, maybe_spike
from .sharding import shard_act

Array = jax.Array


# ------------------------------------------------------------- dense SwiGLU
def mlp_init(rng: Array, cfg: ModelConfig, d: Optional[int] = None,
             d_ff: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    f = d_ff or cfg.d_ff
    rg, ru, rd = jax.random.split(rng, 3)
    return {
        "gate": dense_init(rg, d, f, dtype=cfg.param_dtype),
        "up": dense_init(ru, d, f, dtype=cfg.param_dtype),
        "down": dense_init(rd, f, d, dtype=cfg.param_dtype),
    }


def mlp_apply(p: dict, cfg: ModelConfig, x: Array) -> Array:
    g = dense_apply(p["gate"], x)
    u = dense_apply(p["up"], x)
    if cfg.spiking:
        h = maybe_spike(g, True, cfg.lif) * u     # LIF gate: binary event map
    else:
        h = jax.nn.silu(g) * u
    return dense_apply(p["down"], h)


# --------------------------------------------------------------------- MoE
def moe_init(rng: Array, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    rr, rg, ru, rd, rs = jax.random.split(rng, 5)
    std = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(rr, d, e, dtype=jnp.float32),  # router in f32
        "w_gate": jax.random.truncated_normal(rg, -2, 2, (e, d, f), jnp.float32).astype(cfg.param_dtype) * std,
        "w_up": jax.random.truncated_normal(ru, -2, 2, (e, d, f), jnp.float32).astype(cfg.param_dtype) * std,
        "w_down": jax.random.truncated_normal(rd, -2, 2, (e, f, d), jnp.float32).astype(cfg.param_dtype) * (1.0 / f ** 0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(rs, cfg, d, (cfg.d_ff or f) * cfg.n_shared_experts)
    return p


def moe_capacity(cfg: ModelConfig, group_tokens: int) -> int:
    cap = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, min(group_tokens, -(-cap // 8) * 8))   # mult of 8, bounded


def router_probs(p: dict, x: Array) -> Array:
    """[.., D] -> [.., E] f32 softmax router probabilities."""
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    return jax.nn.softmax(logits, axis=-1), logits


def moe_apply(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, dict]:
    """x: [B, S, D] -> (y, aux_losses).

    Token grouping: each batch row is a dispatch group (G=B, S_g=S) — groups
    stay aligned with the data shards so dispatch never crosses the 'data'
    axis; only the combine reduces over 'model' (EP combine all-reduce).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, s)

    probs, logits = router_probs(p, x)                   # [B,S,E] f32
    topv, topi = jax.lax.top_k(probs, k)                 # [B,S,k]
    if cfg.top_k > 1:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)    # [B,S,k,E]
    # rank tokens per expert by arrival order (cumsum over flattened S*k)
    flat = onehot.reshape(b, s * k, e)
    ranks = jnp.cumsum(flat, axis=1) - flat              # [B,S*k,E]
    rank_of_choice = (ranks * flat).sum(-1).reshape(b, s, k)
    keep = rank_of_choice < cap                          # capacity drop mask
    weight = topv * keep.astype(topv.dtype)              # [B,S,k]

    # dispatch one-hot [B,S,E,cap] (bf16 so the einsums hit the MXU)
    pos_onehot = jax.nn.one_hot(jnp.where(keep, rank_of_choice, cap), cap + 1,
                                dtype=x.dtype)[..., :cap]     # [B,S,k,cap]
    disp = jnp.einsum("bske,bskc->bsec",
                      onehot.astype(x.dtype), pos_onehot)     # [B,S,E,cap]
    comb = jnp.einsum("bsk,bske,bskc->bsec",
                      weight.astype(x.dtype), onehot.astype(x.dtype), pos_onehot)

    xe = jnp.einsum("bsd,bsec->becd", x, disp)           # [B,E,cap,D]
    # pin the dispatched tokens EXPERT-sharded: the (sharded-seq) dispatch
    # contraction then lowers to reduce-scatter onto expert shards instead
    # of all-reduce + re-slice (EXPERIMENTS §Perf B2)
    xe = shard_act(xe, "dp", "model", None, None)
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    if cfg.spiking:
        h = maybe_spike(g, True, cfg.lif) * u
    else:
        h = jax.nn.silu(g) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("becd,bsec->bsd", ye, comb)           # EP combine (psum)

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], cfg, x)

    # Switch aux loss: E * sum_e f_e * P_e  (f = fraction routed, P = mean prob)
    f_e = jnp.mean(onehot.sum(2).astype(jnp.float32), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance": e * jnp.sum(f_e * p_e),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux


def moe_apply_dense_ref(p: dict, cfg: ModelConfig, x: Array) -> Array:
    """Oracle: run EVERY expert on every token, combine with (dropless) top-k
    weights. O(E) FLOPs — tests only. Dispatch impl must match this wherever
    no token is capacity-dropped."""
    probs, _ = router_probs(p, x)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    w_full = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None], topi].set(topv)
    g = jnp.einsum("bsd,edf->besf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->besf", x, p["w_up"].astype(x.dtype))
    if cfg.spiking:
        h = maybe_spike(g, True, cfg.lif) * u
    else:
        h = jax.nn.silu(g) * u
    ye = jnp.einsum("besf,efd->besd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("besd,bse->bsd", ye, w_full.astype(x.dtype))
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], cfg, x)
    return y
