"""Shared transformer layer primitives for the LM model zoo.

Pure-functional (init returns pytrees, apply is pure), NHWC-free — LM tensors
are [batch, seq, d]. All matmul-bearing params are 2-D+ with a deterministic
TP-sharding rule (see ``shardings.py``): *column*-parallel weights put
'model' on the LAST dim, *row*-parallel weights put 'model' on the FIRST dim.

The paper's spiking mode (C1/C3) plugs in here: ``maybe_spike`` converts a
pre-activation ("membrane current") into a binary spike train with a
surrogate gradient — the LM analogue of the LIF unit in NEURAL's PEs.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.lif import LIFConfig, lif_forward

Array = jax.Array


# ------------------------------------------------------------------- helpers
def truncated_normal(rng: Array, shape: tuple[int, ...], std: float,
                     dtype=jnp.float32) -> Array:
    return jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32).astype(dtype) * std


def dense_init(rng: Array, din: int, dout: int, *, bias: bool = False,
               std: Optional[float] = None, dtype=jnp.float32) -> dict:
    std = std if std is not None else 1.0 / math.sqrt(din)
    p = {"w": truncated_normal(rng, (din, dout), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def dense_apply(p: dict, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ------------------------------------------------------------------- rmsnorm
def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: Array, eps: float = 1e-6) -> Array:
    # norm statistics in f32 for stability regardless of activation dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_gated_apply(p: dict, x: Array, z: Array, eps: float = 1e-6) -> Array:
    """Mamba2 output norm: RMSNorm(x * silu(z)) (normformer-style gate)."""
    g = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    y = g * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embedding_init(rng: Array, vocab: int, d: int, dtype=jnp.float32) -> dict:
    # scaled init: keeps tied-readout logits O(1) at init
    return {"emb": truncated_normal(rng, (vocab, d), d ** -0.5, dtype)}


def embedding_lookup(p: dict, tokens: Array, compute_dtype=jnp.bfloat16) -> Array:
    return jnp.take(p["emb"], tokens, axis=0).astype(compute_dtype)


def embedding_logits(p: dict, x: Array) -> Array:
    """Tied read-out: x @ emb^T -> [.., vocab] in f32 (loss-stable)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["emb"].astype(jnp.float32))


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, Dh]; positions: [B, S] (or [S]) int32.

    Angles/cos/sin are computed in f32 (position precision), but the
    rotation itself runs in x's dtype — so no full-size f32 q/k tensor ever
    exists (GSPMD would otherwise gather the f32 version at TP boundaries:
    2x the wire for nothing; see EXPERIMENTS §Perf A7)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,Dh/2]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # [B,S,1,Dh/2]
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ------------------------------------------------------------- spiking hook
def maybe_spike(x: Array, spiking: bool, lif: LIFConfig) -> Array:
    """The paper's LIF activation as an LM drop-in (C3): binary spikes with a
    surrogate gradient when ``spiking``; identity otherwise."""
    if not spiking:
        return x
    return lif_forward(x, lif)


def fused_dense_lif(p: dict, x: Array, lif: LIFConfig, *,
                    q=None, qk_threshold: float = 1.0,
                    policy=None, pack_out: bool | None = None):
    """dense(x) -> LIF spikes as ONE fused PE pass (deployed inference).

    The LM analogue of NEURAL's PE dataflow: the projection's f32
    pre-activation never round-trips HBM — the LIF threshold fires
    in-register and int8 spikes are written back (optionally gated by the
    QK token mask from ``q``'s row sums, the Fig 5 write-back fusion; a
    packed ``q``'s row sums are popcounts). Forward-exact vs
    ``maybe_spike(dense_apply(p, x), True, lif)``; no surrogate gradient —
    inference only.

    Thin veneer over ``repro.ops.dense_lif``: returns a 2-D ``SpikeTensor``
    over the flattened [tokens, Dout] layout in the policy's format (the
    deprecated boolean form routes through ``repro.ops.compat``).
    """
    from .. import ops

    if pack_out is not None:
        assert policy is None, "pass policy= or the deprecated flag, not both"
        fmt = ops.resolve_out_format(pack_out, None, owner="fused_dense_lif")
        policy = ops.ExecutionPolicy("fused", fmt)
    elif policy is None:
        policy = ops.FUSED_DENSE
    return ops.dense_lif(p, x, lif, q=q, qk_threshold=qk_threshold,
                         policy=policy)


# ------------------------------------------------------------- misc numerics
def soft_cap(x: Array, cap: float) -> Array:
    return cap * jnp.tanh(x / cap)


def causal_mask(sq: int, sk: int, q_offset: int = 0, dtype=jnp.float32) -> Array:
    """[sq, sk] additive mask; query i attends to keys <= i + q_offset."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    return jnp.where(ki <= qi, 0.0, -1e30).astype(dtype)
