"""Unified LM engine for the assigned architecture zoo.

One parameterized decoder stack covers five families:

  dense   (qwen1.5-32b, qwen3-1.7b, qwen2.5-3b, yi-9b) — GQA attn + SwiGLU
  moe     (llama4-scout-17b-a16e, olmoe-1b-7b)         — GQA attn + MoE FFN
  ssm     (mamba2-130m)                                — Mamba2/SSD blocks
  hybrid  (zamba2-7b)                                  — Mamba2 + ONE shared
          attention block applied every ``attn_every`` layers (grouped scan)
  vlm     (phi-3-vision-4.2b)                          — dense decoder with a
          precomputed-patch-embedding prefix (+ optional W2TTFS patch merge)

plus an encoder-decoder (seamless-m4t-large-v2) built from the same blocks.

Execution modes map 1:1 onto the assigned shape grid:
  loss/train_step -> train_4k          (full causal LM step)
  prefill         -> prefill_32k       (logits + cache construction)
  decode_step     -> decode_32k / long_500k (one token against a full cache)

Layers run under ``lax.scan`` over stacked params (cfg.scan_layers) so the
HLO stays one-block-sized regardless of depth — this is what keeps 64-layer
32B configs compilable for a 512-way mesh on a CPU host. Remat policy is
per-config ("none" | "full" | "dots").

The paper's techniques are config flags (see DESIGN §Arch-applicability):
``spiking`` turns FFN gates and QK paths into LIF spike events (C1/C3);
``attention_kind='qk_spiking'`` swaps softmax attention for the on-the-fly
QKFormer token attention (C4) — O(N*Dh), cache-free decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..core.w2ttfs import window_counts
from .attention import (attn_append, attn_apply, attn_decode, attn_init,
                        attn_prefill)
from .ffn import mlp_apply, mlp_init, moe_apply, moe_init
from .layers import (dense_apply, dense_init, embedding_init,
                     embedding_lookup, embedding_logits, maybe_spike,
                     rmsnorm_apply, rmsnorm_init)
from .sharding import shard_act
from .ssm import (mamba_apply, mamba_decode_step, mamba_init,
                  mamba_init_state, ssm_dims)

Array = jax.Array


# ===================================================================== blocks
def _block_kind(cfg: ModelConfig) -> str:
    return {"dense": "attn_mlp", "vlm": "attn_mlp", "moe": "attn_moe",
            "ssm": "mamba", "hybrid": "mamba"}[cfg.family]


def block_init(rng: Array, cfg: ModelConfig) -> dict:
    kind = _block_kind(cfg)
    r1, r2 = jax.random.split(rng)
    if kind == "attn_mlp":
        return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
                "attn": attn_init(r1, cfg),
                "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
                "mlp": mlp_init(r2, cfg)}
    if kind == "attn_moe":
        return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
                "attn": attn_init(r1, cfg),
                "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
                "moe": moe_init(r2, cfg)}
    if kind == "mamba":
        return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
                "mamba": mamba_init(r1, cfg)}
    raise ValueError(kind)


def shared_attn_init(rng: Array, cfg: ModelConfig) -> dict:
    """Zamba2's weight-shared attention block (one param set, many sites)."""
    r1, r2 = jax.random.split(rng)
    return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": attn_init(r1, cfg),
            "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(r2, cfg, d_ff=cfg.d_ff)}


def _zero_aux() -> dict:
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)}


def block_apply(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                *, causal: bool = True) -> tuple[Array, dict]:
    """Full-sequence block forward (train). Returns (x, moe_aux).

    With ``cfg.seq_shard`` (Megatron-SP) the residual stream lives
    SEQUENCE-SHARDED over 'model'; norms run in the sharded region (they are
    per-token), and each attention/FFN module is entered through an
    all-gather and exited through a reduce-scatter — both explicit, both on
    bf16 activations (left to itself GSPMD gathers f32 weights instead).
    """
    kind = _block_kind(cfg)
    aux = _zero_aux()
    sp = cfg.seq_shard
    x = shard_act(x, "dp", "model" if sp else None, None)
    if kind in ("attn_mlp", "attn_moe"):
        h = attn_apply(p["attn"], cfg, rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                       positions, causal=causal)
        x = x + h
        y = rmsnorm_apply(p["ln2"], x, cfg.rms_eps)
        if kind == "attn_mlp":
            x = x + mlp_apply(p["mlp"], cfg, y)
        else:
            moe_y, aux = moe_apply(p["moe"], cfg, y)
            x = x + moe_y
    else:  # mamba
        x = x + mamba_apply(p["mamba"], cfg,
                            rmsnorm_apply(p["ln1"], x, cfg.rms_eps))
    return shard_act(x, "dp", "model" if sp else None, None), aux


def block_prefill(p: dict, cfg: ModelConfig, x: Array, positions: Array
                  ) -> tuple[Array, Any]:
    """Block forward that also emits its cache entry."""
    kind = _block_kind(cfg)
    x = shard_act(x, "dp", None, None)
    if kind in ("attn_mlp", "attn_moe"):
        h, kv = attn_prefill(p["attn"], cfg,
                             rmsnorm_apply(p["ln1"], x, cfg.rms_eps), positions)
        x = x + h
        y = rmsnorm_apply(p["ln2"], x, cfg.rms_eps)
        if kind == "attn_mlp":
            x = x + mlp_apply(p["mlp"], cfg, y)
        else:
            moe_y, _ = moe_apply(p["moe"], cfg, y)
            x = x + moe_y
        return x, kv
    out, st = mamba_apply(p["mamba"], cfg,
                          rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                          return_state=True)
    return x + out, st


def block_decode(p: dict, cfg: ModelConfig, x: Array, cache_l: Any,
                 cache_len: Array) -> tuple[Array, Any]:
    kind = _block_kind(cfg)
    if kind in ("attn_mlp", "attn_moe"):
        h, (k, v) = attn_decode(p["attn"], cfg,
                                rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                                cache_len, cache_l[0], cache_l[1], cache_len)
        x = x + h
        y = rmsnorm_apply(p["ln2"], x, cfg.rms_eps)
        if kind == "attn_mlp":
            x = x + mlp_apply(p["mlp"], cfg, y)
        else:
            moe_y, _ = moe_apply(p["moe"], cfg, y)
            x = x + moe_y
        return x, (k, v)
    out, st = mamba_decode_step(p["mamba"], cfg,
                                rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                                cache_l)
    return x + out, st


def block_append(p: dict, cfg: ModelConfig, x: Array, cache_l: Any,
                 cache_len: Array) -> tuple[Array, Any]:
    """Chunked-prefill block forward: C tokens appended to an existing
    cache entry (the multi-token generalization of ``block_decode``)."""
    kind = _block_kind(cfg)
    if kind in ("attn_mlp", "attn_moe"):
        h, (k, v) = attn_append(p["attn"], cfg,
                                rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                                cache_l[0], cache_l[1], cache_len)
        x = x + h
        y = rmsnorm_apply(p["ln2"], x, cfg.rms_eps)
        if kind == "attn_mlp":
            x = x + mlp_apply(p["mlp"], cfg, y)
        else:
            moe_y, _ = moe_apply(p["moe"], cfg, y)
            x = x + moe_y
        return x, (k, v)
    out, st = mamba_apply(p["mamba"], cfg,
                          rmsnorm_apply(p["ln1"], x, cfg.rms_eps),
                          init_state=cache_l, return_state=True)
    return x + out, st


def _pad_kv_layers(layers: Any, max_len: int) -> Any:
    """Pad KV leaves (seq axis = -3) to max_len; mamba states untouched."""

    def pad(path, leaf):
        ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        # int32 leaves are packed qk_spiking spike-state words: one row per
        # token by construction (O(1) in sequence length) — never padded
        if "ssm" in ps or "conv" in ps or leaf.ndim < 4 \
                or leaf.dtype == jnp.int32:
            return leaf
        s = leaf.shape[-3]
        if s >= max_len or s == 0:
            return leaf
        width = [(0, 0)] * leaf.ndim
        width[-3] = (0, max_len - s)
        return jnp.pad(leaf, width)

    return jax.tree_util.tree_map_with_path(pad, layers)


# ================================================================== LM model
class LM:
    """Decoder-only LM over the unified block zoo (all families but encdec)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, rng: Array) -> dict:
        cfg = self.cfg
        r_emb, r_blocks, r_head, r_shared, r_vis = jax.random.split(rng, 5)
        params: dict = {
            "embed": embedding_init(r_emb, cfg.vocab_size, cfg.d_model,
                                    cfg.param_dtype),
            "blocks": jax.vmap(lambda r: block_init(r, cfg))(
                jax.random.split(r_blocks, cfg.n_layers)),
            "final_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(r_head, cfg.d_model, cfg.vocab_size,
                                        dtype=cfg.param_dtype)
        if cfg.family == "hybrid":
            params["shared_attn"] = shared_attn_init(r_shared, cfg)
        if cfg.family == "vlm":
            params["vision_proj"] = dense_init(r_vis, cfg.d_vision,
                                               cfg.d_model,
                                               dtype=cfg.param_dtype)
        return params

    # ------------------------------------------------------------ embeddings
    def _embed(self, params: dict, batch: dict) -> tuple[Array, Array]:
        """-> (x [B,S,D], positions [B,S])."""
        cfg = self.cfg
        x = embedding_lookup(params["embed"], batch["tokens"], cfg.dtype)
        if cfg.family == "vlm" and "img_embeds" in batch:
            img = batch["img_embeds"].astype(cfg.dtype)
            if cfg.vision_pool_window > 1:
                img = self._patch_merge(img)
            img = dense_apply(params["vision_proj"], img)
            x = jnp.concatenate([img, x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return x, positions

    def _patch_merge(self, img: Array) -> Array:
        """W2TTFS patch merge (paper C2 applied to the vision frontend):
        spiking mode pools windows by SPIKE COUNT x unit scale — the WTFC
        datapath; ANN mode mean-pools. img: [B, N, Dv], N = g*g patches."""
        cfg = self.cfg
        b, n, dv = img.shape
        w = cfg.vision_pool_window
        g = int(round(n ** 0.5))
        grid = img.reshape(b, g, g, dv)
        if cfg.spiking:
            spikes = maybe_spike(grid, True, cfg.lif)
            cnt = window_counts(spikes, w)               # [B,g/w,g/w,Dv]
            pooled = cnt.astype(img.dtype) / float(w * w)
        else:
            pooled = grid.reshape(b, g // w, w, g // w, w, dv).mean(axis=(2, 4))
        return pooled.reshape(b, (g // w) ** 2, dv)

    # ----------------------------------------------------------- stack (train)
    def _stack_train(self, params: dict, x: Array, positions: Array) -> tuple[Array, dict]:
        cfg = self.cfg

        def body_plain(x, p_l):
            y, aux = block_apply(p_l, cfg, x, positions)
            return y, aux

        body = self._maybe_remat(body_plain)

        if cfg.family == "hybrid":
            x, aux = self._hybrid_train(params, x, positions, body)
        elif cfg.scan_layers:
            def scan_body(carry, p_l):
                y, aux = body(carry, p_l)
                return y, aux
            x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
            aux = jax.tree_util.tree_map(jnp.sum, auxs)
        else:
            aux = _zero_aux()
            for i in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                x, a = body(x, p_l)
                aux = jax.tree_util.tree_map(jnp.add, aux, a)
        return rmsnorm_apply(params["final_norm"], x, cfg.rms_eps), aux

    def _hybrid_train(self, params, x, positions, body):
        """Zamba2 grouped scan: shared attention block before each group of
        ``attn_every`` mamba layers. n_layers must divide into groups."""
        cfg = self.cfg
        k = cfg.attn_every
        ng = cfg.n_layers // k
        shared = params["shared_attn"]
        blocks_g = jax.tree_util.tree_map(
            lambda a: a.reshape(ng, k, *a.shape[1:]), params["blocks"])

        def attn_site(x):
            h = attn_apply(shared["attn"], cfg,
                           rmsnorm_apply(shared["ln1"], x, cfg.rms_eps),
                           positions, causal=True)
            x = x + h
            y = rmsnorm_apply(shared["ln2"], x, cfg.rms_eps)
            return x + mlp_apply(shared["mlp"], cfg, y)

        def group_body(carry, p_g):
            x = attn_site(carry)
            x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, p_g)
            return x, jax.tree_util.tree_map(jnp.sum, auxs)

        x, auxs = jax.lax.scan(group_body, x, blocks_g)
        return x, jax.tree_util.tree_map(jnp.sum, auxs)

    def _maybe_remat(self, fn):
        cfg = self.cfg
        if cfg.remat == "full":
            return jax.checkpoint(fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return fn

    # -------------------------------------------------------------- readout
    def _logits(self, params: dict, x: Array) -> Array:
        if self.cfg.tie_embeddings:
            return embedding_logits(params["embed"], x)
        return dense_apply(params["head"], x.astype(jnp.float32))

    # ------------------------------------------------------------------ loss
    def loss(self, params: dict, batch: dict) -> tuple[Array, dict]:
        """Causal next-token CE (+ MoE aux). batch['tokens']: [B, S]."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        x, aux = self._stack_train(params, x, positions)
        # predict token t+1 from position t (text positions only for vlm)
        n_pred = batch["tokens"].shape[1] - 1
        hs = x[:, -n_pred - 1:-1, :]
        targets = batch["tokens"][:, 1:]
        logits = self._logits(params, hs)
        logits = shard_act(logits, "dp", None, "model")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        metrics = {"nll": loss}
        if cfg.family == "moe":
            loss = (loss + cfg.router_aux_weight * aux["load_balance"]
                    + 1e-3 * aux["router_z"])
            metrics.update({k: v for k, v in aux.items()})
        metrics["loss"] = loss
        return loss, metrics

    # --------------------------------------------------------------- prefill
    def prefill(self, params: dict, batch: dict,
                return_all_logits: bool = False,
                max_len: int = 0) -> tuple[Array, Any]:
        """Full-context forward -> (last-position logits [B,V], cache).
        ``return_all_logits`` gives [B,S,V] (serving engines pick the last
        REAL token's position when prompts are right-padded).
        ``max_len`` > S pads the KV cache with headroom so decode_step can
        append new tokens directly."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)

        if cfg.family == "hybrid":
            x, cache = self._hybrid_prefill(params, x, positions)
        elif cfg.scan_layers:
            def scan_body(carry, p_l):
                y, c = block_prefill(p_l, cfg, carry, positions)
                return y, c
            x, cache = jax.lax.scan(scan_body, x, params["blocks"])
        else:
            entries = []
            for i in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                x, c = block_prefill(p_l, cfg, x, positions)
                entries.append(c)
            cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *entries)
        x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
        if return_all_logits:
            logits = self._logits(params, x)
        else:
            logits = self._logits(params, x[:, -1:, :])[:, 0, :]
        if max_len:
            cache = _pad_kv_layers(cache, max_len)
        cache = {"layers": cache,
                 "len": jnp.array(positions.shape[1], jnp.int32)}
        return logits, cache

    def _hybrid_prefill(self, params, x, positions):
        cfg = self.cfg
        k = cfg.attn_every
        ng = cfg.n_layers // k
        shared = params["shared_attn"]
        blocks_g = jax.tree_util.tree_map(
            lambda a: a.reshape(ng, k, *a.shape[1:]), params["blocks"])

        def group_body(carry, p_g):
            x = carry
            h, kv = attn_prefill(shared["attn"], cfg,
                                 rmsnorm_apply(shared["ln1"], x, cfg.rms_eps),
                                 positions)
            x = x + h
            x = x + mlp_apply(shared["mlp"], cfg,
                              rmsnorm_apply(shared["ln2"], x, cfg.rms_eps))
            x, states = jax.lax.scan(
                lambda c, p: block_prefill(p, cfg, c, positions), x, p_g)
            return x, {"attn": kv, "mamba": states}

        x, cache = jax.lax.scan(group_body, x, blocks_g)
        return x, cache

    # ----------------------------------------------------------- decode step
    def decode_step(self, params: dict, tokens: Array, cache: dict
                    ) -> tuple[Array, dict]:
        """One token for every sequence. tokens: [B, 1] int32.
        cache['len'] may be a scalar or a per-sequence [B] vector (slot
        pools in the serving engine)."""
        cfg = self.cfg
        cache_len = cache["len"]
        x = embedding_lookup(params["embed"], tokens, cfg.dtype)
        x = shard_act(x, "dp", None, None)

        if cfg.family == "hybrid":
            x, layers = self._hybrid_decode(params, x, cache)
        elif cfg.scan_layers:
            def scan_body(carry, inp):
                p_l, c_l = inp
                y, nc = block_decode(p_l, cfg, carry, c_l, cache_len)
                return y, nc
            x, layers = jax.lax.scan(scan_body, x,
                                     (params["blocks"], cache["layers"]))
        else:
            entries = []
            for i in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                c_l = jax.tree_util.tree_map(lambda a: a[i], cache["layers"])
                x, nc = block_decode(p_l, cfg, x, c_l, cache_len)
                entries.append(nc)
            layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *entries)
        x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
        logits = self._logits(params, x)[:, 0, :]
        return logits, {"layers": layers, "len": cache_len + 1}

    def _hybrid_decode(self, params, x, cache):
        cfg = self.cfg
        k = cfg.attn_every
        ng = cfg.n_layers // k
        shared = params["shared_attn"]
        cache_len = cache["len"]
        blocks_g = jax.tree_util.tree_map(
            lambda a: a.reshape(ng, k, *a.shape[1:]), params["blocks"])

        def group_body(carry, inp):
            x = carry
            p_g, c_g = inp
            h, (ck, cv) = attn_decode(
                shared["attn"], cfg,
                rmsnorm_apply(shared["ln1"], x, cfg.rms_eps),
                cache_len, c_g["attn"][0], c_g["attn"][1], cache_len)
            x = x + h
            x = x + mlp_apply(shared["mlp"], cfg,
                              rmsnorm_apply(shared["ln2"], x, cfg.rms_eps))
            x, states = jax.lax.scan(
                lambda c, pc: block_decode(pc[0], cfg, c, pc[1], cache_len),
                x, (p_g, c_g["mamba"]))
            return x, {"attn": (ck, cv), "mamba": states}

        x, layers = jax.lax.scan(group_body, x, (blocks_g, cache["layers"]))
        return x, layers

    # -------------------------------------------------------- chunked prefill
    def prefill_chunk(self, params: dict, tokens: Array, cache: dict
                      ) -> tuple[Array, dict]:
        """Continued prefill: C tokens appended to an existing cache.

        tokens: [B, C] int32; cache: an ``init_cache``-layout pytree whose
        ``cache['len']`` (scalar or [B]) is the number of positions already
        prefilled. Returns (all-position logits [B, C, V], updated cache
        with len advanced by C). Feeding a prompt through this in chunks is
        bit-identical to one blocking ``prefill`` pass — the serving
        engine's elastic-FIFO prefill unit (decode ticks interleave between
        chunks, so one long prompt cannot stall the decode pipeline).
        """
        cfg = self.cfg
        cache_len = cache["len"]
        x = embedding_lookup(params["embed"], tokens, cfg.dtype)
        x = shard_act(x, "dp", None, None)

        if cfg.family == "hybrid":
            x, layers = self._hybrid_append(params, x, cache)
        elif cfg.scan_layers:
            def scan_body(carry, inp):
                p_l, c_l = inp
                y, nc = block_append(p_l, cfg, carry, c_l, cache_len)
                return y, nc
            x, layers = jax.lax.scan(scan_body, x,
                                     (params["blocks"], cache["layers"]))
        else:
            entries = []
            for i in range(cfg.n_layers):
                p_l = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                c_l = jax.tree_util.tree_map(lambda a: a[i], cache["layers"])
                x, nc = block_append(p_l, cfg, x, c_l, cache_len)
                entries.append(nc)
            layers = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *entries)
        x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
        logits = self._logits(params, x)
        return logits, {"layers": layers, "len": cache_len + tokens.shape[1]}

    def _hybrid_append(self, params, x, cache):
        cfg = self.cfg
        k = cfg.attn_every
        ng = cfg.n_layers // k
        shared = params["shared_attn"]
        cache_len = cache["len"]
        blocks_g = jax.tree_util.tree_map(
            lambda a: a.reshape(ng, k, *a.shape[1:]), params["blocks"])

        def group_body(carry, inp):
            x = carry
            p_g, c_g = inp
            h, (ck, cv) = attn_append(
                shared["attn"], cfg,
                rmsnorm_apply(shared["ln1"], x, cfg.rms_eps),
                c_g["attn"][0], c_g["attn"][1], cache_len)
            x = x + h
            x = x + mlp_apply(shared["mlp"], cfg,
                              rmsnorm_apply(shared["ln2"], x, cfg.rms_eps))
            x, states = jax.lax.scan(
                lambda c2, pc: block_append(pc[0], cfg, c2, pc[1], cache_len),
                x, (p_g, c_g["mamba"]))
            return x, {"attn": (ck, cv), "mamba": states}

        x, layers = jax.lax.scan(group_body, x, (blocks_g, cache["layers"]))
        return x, layers

    # ------------------------------------------------------------ cache spec
    def init_cache(self, batch_size: int, max_len: int) -> dict:
        """Zero cache pytree (ShapeDtypeStruct-compatible via eval_shape)."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        hkv = cfg.n_kv_heads or cfg.n_heads
        kv_dtype = (jnp.float8_e4m3fn if cfg.kv_dtype == "f8_e4m3"
                    else cfg.dtype)

        def attn_entry(lead):
            if cfg.attention_kind == "qk_spiking":
                empty = jnp.zeros((lead, batch_size, 0, hkv, dh), kv_dtype)
                if cfg.exec_policy.packed:
                    # per-slot spike state, BIT-PACKED (32 spikes/int32
                    # word): one row of masked-attention spikes per layer —
                    # O(1) in sequence length, 8x smaller than int8
                    from .attention import qk_spike_state_width
                    words = jnp.zeros(
                        (lead, batch_size, 1, 1, qk_spike_state_width(cfg)),
                        jnp.int32)
                    return (words, empty)
                return (empty, empty)
            shp = (lead, batch_size, max_len, hkv, dh)
            return (jnp.zeros(shp, kv_dtype), jnp.zeros(shp, kv_dtype))

        def mamba_entry(lead):
            st = mamba_init_state(cfg, batch_size, dtype=cfg.dtype)
            return jax.tree_util.tree_map(
                lambda a: jnp.zeros((lead, *a.shape), a.dtype), st)

        if cfg.family in ("dense", "moe", "vlm"):
            layers = attn_entry(cfg.n_layers)
        elif cfg.family == "ssm":
            layers = mamba_entry(cfg.n_layers)
        elif cfg.family == "hybrid":
            ng = cfg.n_layers // cfg.attn_every
            att = attn_entry(ng)
            mam = mamba_entry(cfg.n_layers)
            mam = jax.tree_util.tree_map(
                lambda a: a.reshape(ng, cfg.attn_every, *a.shape[1:]), mam)
            layers = {"attn": att, "mamba": mam}
        else:
            raise ValueError(cfg.family)
        # len = max_len - 1: the cache is "full", the next token writes the
        # final slot — so a decode step attends to exactly ``max_len`` keys.
        return {"layers": layers,
                "len": jnp.array(max(max_len - 1, 0), jnp.int32)}

    # ------------------------------------------------------------- input spec
    def input_specs(self, shape: ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for the step function being lowered."""
        cfg = self.cfg
        b = shape.global_batch
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {"tokens": sds((b, self._text_len(shape.seq_len)), jnp.int32)}
            if cfg.family == "vlm":
                batch["img_embeds"] = sds(
                    (b, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": sds((b, self._text_len(shape.seq_len)), jnp.int32)}
            if cfg.family == "vlm":
                batch["img_embeds"] = sds(
                    (b, cfg.n_img_tokens, cfg.d_vision), jnp.bfloat16)
            return {"batch": batch}
        # decode: one new token against a seq_len cache
        cache = jax.eval_shape(lambda: self.init_cache(b, shape.seq_len))
        return {"tokens": sds((b, 1), jnp.int32), "cache": cache}

    def _text_len(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.family == "vlm":
            n_img = cfg.n_img_tokens
            if cfg.vision_pool_window > 1:
                n_img //= cfg.vision_pool_window ** 2
            return seq_len - n_img
        return seq_len
