"""Sharding rules for the model zoo (GSPMD / pjit).

Layout policy (single source of truth):
  * batch dims            -> ('pod', 'data')   (DP; 'pod' only on multi-pod)
  * column-parallel W     -> last dim 'model'  (wq/wk/wv/gate/up/in_proj)
  * row-parallel W        -> first dim 'model' (wo/down/out_proj)
  * MoE expert stacks     -> expert dim 'model' (EP == TP axis)
  * vocab embedding       -> vocab dim 'model'
  * norms / scalar vectors -> replicated
  * KV caches             -> batch on DP, kv-heads on 'model' when divisible;
                             long-context batch=1 shards SEQUENCE on 'data'
                             (context-parallel decode).

Activations are constrained at block boundaries through ``shard_act`` which
reads the process-global mesh installed by the launcher (``set_global_mesh``)
— model code stays mesh-agnostic and tests run unsharded with no mesh set.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_GLOBAL_MESH: Optional[Mesh] = None
_DP_INCLUDES_MODEL: bool = False


def set_global_mesh(mesh: Optional[Mesh]) -> None:
    global _GLOBAL_MESH
    _GLOBAL_MESH = mesh


def get_global_mesh() -> Optional[Mesh]:
    return _GLOBAL_MESH


def set_dp_includes_model(flag: bool) -> None:
    """Pure-DP/FSDP regime (cfg.dp_over_model): batch shards over 'model'
    too; model-sharded params act as ZeRO-3 shards gathered on use."""
    global _DP_INCLUDES_MODEL
    _DP_INCLUDES_MODEL = flag


def dp_axes(mesh: Optional[Mesh] = None):
    """The data-parallel axis bundle: ('pod','data') on multi-pod meshes."""
    m = mesh or _GLOBAL_MESH
    if m is None:
        return ("data",)
    axes = tuple(a for a in ("pod", "data") if a in m.axis_names)
    if _DP_INCLUDES_MODEL and "model" in m.axis_names:
        axes = axes + ("model",)
    return axes


def shard_act(x: jax.Array, *spec) -> jax.Array:
    """Constrain an activation if a global mesh is installed; no-op otherwise.

    ``spec`` entries: 'dp' expands to the DP bundle; None / axis names pass
    through. Axis sizes that do not divide are dropped (replicated) — this is
    how e.g. 40 heads on a 16-way 'model' axis degrades gracefully.
    """
    m = _GLOBAL_MESH
    if m is None:
        return x
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "dp":
            s = dp_axes(m)
        s = _fit_axis(m, dim, s)
        resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*resolved)))


def _axis_size(mesh: Mesh, s) -> int:
    if s is None:
        return 1
    if isinstance(s, (tuple, list)):
        out = 1
        for a in s:
            out *= mesh.shape[a]
        return out
    return mesh.shape[s]


def _fit_axis(mesh: Mesh, dim: int, s):
    """Drop a sharding that does not evenly divide ``dim``."""
    if s is None:
        return None
    if isinstance(s, (tuple, list)):
        s = tuple(a for a in s if a in mesh.axis_names)
        if not s:
            return None
    elif s not in mesh.axis_names:
        return None
    return s if dim % _axis_size(mesh, s) == 0 else None


# ------------------------------------------------------------ param specs
# (regex over the param tree path, base spec WITHOUT the stacked-layer dim)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"emb",                          ("model", None)),      # [V, D]
    (r"(wq|wk|wv|gate|up|in_proj)/w", (None, "model")),
    (r"(wq|wk|wv|gate|up|in_proj)/b", ("model",)),
    (r"(wo|down|out_proj)/w",         ("model", None)),
    (r"(wo|down|out_proj)/b",         (None,)),
    (r"router/w",                     (None, None)),
    (r"w_gate|w_up",                  ("model", None, None)),  # [E, D, F]
    (r"w_down",                       ("model", None, None)),  # [E, F, D]
    (r"conv_w",                       (None, "model")),
    (r"conv_b",                       ("model",)),
    (r"(A_log|dt_bias|D$|/D)",        (None,)),
    (r"(norm|scale|q_norm|k_norm)",   (None,)),
    (r"head/w",                       (None, "model")),      # lm head [D, V]
    (r"head/b",                       ("model",)),
    (r"(proj|vision_proj|src_proj)/w", (None, "model")),
    (r"(proj|vision_proj|src_proj)/b", ("model",)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_leaf(path: str, ndim: int, mesh: Optional[Mesh] = None,
                  shape: Optional[tuple[int, ...]] = None) -> P:
    """Resolve the PartitionSpec for one param leaf. Leading stacked-layer
    dims (scan over layers) are padded with None on the left."""
    for pat, base in _PARAM_RULES:
        if re.search(pat, path):
            spec = list(base)
            while len(spec) < ndim:
                spec.insert(0, None)
            spec = spec[:ndim] if ndim else []
            if mesh is not None and shape is not None:
                spec = [_fit_axis(mesh, d, s) for d, s in zip(shape, spec)]
            return P(*spec)
    return P()  # replicate by default (norm scales, biases, scalars)


def param_specs(params_shape: Any, mesh: Optional[Mesh] = None) -> Any:
    """Map a param pytree (arrays OR ShapeDtypeStructs) to PartitionSpecs."""

    def one(path, leaf):
        return spec_for_leaf(_path_str(path), leaf.ndim, mesh,
                             tuple(leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh))


def cache_specs(cache_shape: Any, mesh: Mesh, *, batch: int,
                context_parallel: bool = False,
                seq_axis: Optional[str] = None) -> Any:
    """PartitionSpecs for a decode cache pytree (LM or EncDecLM layout).

    * KV leaves  [.., B, S, Hkv, Dh] — B on DP, Hkv on 'model' (if divisible).
      With ``context_parallel`` (long-context batch=1) the SEQUENCE dim
      shards over 'data' instead: GSPMD then lowers the decode softmax into
      the flash-decoding partial-combine across 'data'.
    * mamba 'ssm' leaves [.., B, H, P, N] — B on DP, H on 'model'.
    * mamba 'conv' leaves [.., B, K, C] — B on DP, C on 'model'.
    * 'len' scalar — replicated.
    """
    dp = dp_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        nd = leaf.ndim
        if nd == 0 or "len" in ps:
            return P()
        spec = [None] * nd
        if "ssm" in ps:                    # [.., B, H, P, N]
            b_dim = nd - 4
            spec[b_dim] = _fit_axis(mesh, leaf.shape[b_dim], dp)
            spec[nd - 3] = _fit_axis(mesh, leaf.shape[nd - 3], "model")
        elif "conv" in ps:                 # [.., B, K, C]
            b_dim = nd - 3
            spec[b_dim] = _fit_axis(mesh, leaf.shape[b_dim], dp)
            spec[nd - 1] = _fit_axis(mesh, leaf.shape[nd - 1], "model")
        else:                              # KV: [.., B, S, Hkv, Dh]
            b_dim = nd - 4
            if context_parallel and batch == 1:
                spec[nd - 3] = _fit_axis(mesh, leaf.shape[nd - 3], "data")
            elif seq_axis:
                # context-parallel cache on a chosen axis (e.g. 'model' when
                # kv-heads don't divide TP): flash-decode combine over it
                spec[b_dim] = _fit_axis(
                    mesh, leaf.shape[b_dim],
                    tuple(a for a in dp if a != seq_axis))
                spec[nd - 3] = _fit_axis(mesh, leaf.shape[nd - 3], seq_axis)
            else:
                spec[b_dim] = _fit_axis(mesh, leaf.shape[b_dim], dp)
                spec[nd - 2] = _fit_axis(mesh, leaf.shape[nd - 2], "model")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Input batch: leading dim on DP, the rest replicated."""
    dp = dp_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        spec = [None] * leaf.ndim
        spec[0] = _fit_axis(mesh, leaf.shape[0], dp)
        return P(*spec)

    return jax.tree_util.tree_map(one, batch_shape)


# ---------------------------------------------------------- replica serving
def replica_meshes(n: int, devices: Optional[list] = None) -> list[Mesh]:
    """Meshes for data-parallel multi-replica serving: the local device set
    is dealt round-robin into ``n`` single-device 'data' meshes, one per
    engine replica (each replica owns its own slot pool — the serving-side
    DP shard). With fewer devices than replicas, replicas share devices
    (the CPU/dev-box degenerate case)."""
    import numpy as _np
    devices = list(devices if devices is not None else jax.devices())
    return [Mesh(_np.asarray([devices[i % len(devices)]]), ("data",))
            for i in range(n)]


def replicate_params(params: Any, mesh: Mesh) -> Any:
    """Place a param pytree fully-replicated on one replica mesh — each
    serving replica reads its own device-local copy (weights are replicated
    across the serving DP axis; the slot-pool caches are what shard)."""
    sharding = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda a: jax.device_put(a, sharding), params)


def zero1_specs(params_shape: Any, mesh: Optional[Mesh] = None) -> Any:
    """Optimizer-state sharding (ZeRO-1): additionally shard the FIRST
    already-unsharded dim over 'data' where divisible. GSPMD then emits
    reduce-scatter(grads) + all-gather(updates) around the optimizer."""

    def one(path, leaf):
        spec = list(spec_for_leaf(_path_str(path), leaf.ndim, mesh,
                                  tuple(leaf.shape)))
        if mesh is None or "data" not in mesh.axis_names:
            return P(*spec)
        dsize = mesh.shape["data"]
        for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
            if s is None and dim % dsize == 0 and dim >= 4 * dsize:
                spec[i] = "data"
                break
            if s == "model" and dim % (dsize * mesh.shape["model"]) == 0:
                spec[i] = ("model", "data")
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)
