"""Encoder-decoder backbone (seamless-m4t-large-v2 cell).

The modality frontend is a STUB per the brief: ``input_specs`` supplies
precomputed audio-frame embeddings [B, S_src, d_src]; the model owns the
``src_proj`` into d_model, the bidirectional encoder stack, and a causal
decoder with per-layer cross-attention onto the encoder output.

W2TTFS hook (paper C2): the encoder front applies a window-``w`` frame
downsampling stage; in spiking mode the frames are LIF-spiked and pooled by
spike COUNT x unit-scale (the WTFC datapath), in ANN mode mean-pooled —
mirroring how the paper replaces average pooling.

Decode: self-attn KV cache (decoder) + cross-attn KV computed once from the
encoder output at prefill and reused every step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..core.w2ttfs import window_counts
from .attention import attn_apply, attn_decode, attn_init, attn_prefill
from .ffn import mlp_apply, mlp_init
from .layers import (dense_apply, dense_init, embedding_init,
                     embedding_lookup, maybe_spike, rmsnorm_apply,
                     rmsnorm_init)
from .sharding import shard_act

Array = jax.Array


def enc_block_init(rng: Array, cfg: ModelConfig) -> dict:
    r1, r2 = jax.random.split(rng)
    return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": attn_init(r1, cfg),
            "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(r2, cfg)}


def dec_block_init(rng: Array, cfg: ModelConfig) -> dict:
    r1, r2, r3 = jax.random.split(rng, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": attn_init(r1, cfg),
            "ln_cross": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "cross": attn_init(r2, cfg),
            "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": mlp_init(r3, cfg)}


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, rng: Array) -> dict:
        cfg = self.cfg
        r_emb, r_enc, r_dec, r_src, r_head = jax.random.split(rng, 5)
        n_enc = cfg.n_enc_layers or cfg.n_layers
        return {
            "embed": embedding_init(r_emb, cfg.vocab_size, cfg.d_model,
                                    cfg.param_dtype),
            "src_proj": dense_init(r_src, cfg.d_src or cfg.d_model,
                                   cfg.d_model, dtype=cfg.param_dtype),
            "enc_blocks": jax.vmap(lambda r: enc_block_init(r, cfg))(
                jax.random.split(r_enc, n_enc)),
            "enc_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "dec_blocks": jax.vmap(lambda r: dec_block_init(r, cfg))(
                jax.random.split(r_dec, cfg.n_layers)),
            "final_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "head": dense_init(r_head, cfg.d_model, cfg.vocab_size,
                               dtype=cfg.param_dtype),
        }

    # --------------------------------------------------------------- encoder
    def _frontend(self, params: dict, src: Array) -> Array:
        """Frame downsampling (W2TTFS in spiking mode) + projection."""
        cfg = self.cfg
        x = src.astype(cfg.dtype)
        w = cfg.vision_pool_window          # reused as the frame-pool window
        if w > 1:
            b, s, d = x.shape
            if cfg.spiking:
                spikes = maybe_spike(x.reshape(b, s // w, w, d), True, cfg.lif)
                x = (spikes.sum(axis=2) / float(w)).astype(x.dtype)
            else:
                x = x.reshape(b, s // w, w, d).mean(axis=2)
        return dense_apply(params["src_proj"], x)

    def encode(self, params: dict, src_embeds: Array) -> Array:
        cfg = self.cfg
        x = self._frontend(params, src_embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(carry, p_l):
            x = shard_act(carry, "dp", None, None)
            h = attn_apply(p_l["attn"], cfg,
                           rmsnorm_apply(p_l["ln1"], x, cfg.rms_eps),
                           positions, causal=False)
            x = x + h
            x = x + mlp_apply(p_l["mlp"], cfg,
                              rmsnorm_apply(p_l["ln2"], x, cfg.rms_eps))
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rmsnorm_apply(params["enc_norm"], x, cfg.rms_eps)

    # --------------------------------------------------------------- decoder
    def _dec_block(self, p_l, x, positions, enc_out, enc_positions):
        cfg = self.cfg
        x = shard_act(x, "dp", None, None)
        h = attn_apply(p_l["attn"], cfg,
                       rmsnorm_apply(p_l["ln1"], x, cfg.rms_eps),
                       positions, causal=True)
        x = x + h
        # cross-attn: project encoder K/V on the fly
        hkv = cfg.n_kv_heads or cfg.n_heads
        dh = cfg.resolved_head_dim
        b, sk, _ = enc_out.shape
        k = dense_apply(p_l["cross"]["wk"], enc_out).reshape(b, sk, hkv, dh)
        v = dense_apply(p_l["cross"]["wv"], enc_out).reshape(b, sk, hkv, dh)
        c = attn_apply(p_l["cross"], cfg,
                       rmsnorm_apply(p_l["ln_cross"], x, cfg.rms_eps),
                       positions, causal=False, kv_override=(k, v))
        x = x + c
        return x + mlp_apply(p_l["mlp"], cfg,
                             rmsnorm_apply(p_l["ln2"], x, cfg.rms_eps))

    def decode_train(self, params: dict, tgt_tokens: Array, enc_out: Array) -> Array:
        cfg = self.cfg
        x = embedding_lookup(params["embed"], tgt_tokens, cfg.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        enc_positions = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1])[None], (b, enc_out.shape[1]))

        def body(carry, p_l):
            return self._dec_block(p_l, carry, positions, enc_out,
                                   enc_positions), None

        body = self._maybe_remat(body)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)

    def _maybe_remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return fn

    # ------------------------------------------------------------------ loss
    def loss(self, params: dict, batch: dict) -> tuple[Array, dict]:
        enc_out = self.encode(params, batch["src_embeds"])
        x = self.decode_train(params, batch["tgt_tokens"][:, :-1], enc_out)
        logits = dense_apply(params["head"], x.astype(jnp.float32))
        logits = shard_act(logits, "dp", None, "model")
        targets = batch["tgt_tokens"][:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        return loss, {"loss": loss, "nll": loss}

    # --------------------------------------------------------------- serving
    def prefill(self, params: dict, batch: dict,
                return_all_logits: bool = False,
                max_len: int = 0) -> tuple[Array, dict]:
        """Encode source + run decoder prefill on tgt prefix -> cache with
        (self KV, cross KV) per decoder layer. ``max_len`` pads the SELF
        cache with decode headroom (cross cache length is fixed)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"])
        hkv = cfg.n_kv_heads or cfg.n_heads
        dh = cfg.resolved_head_dim
        b, sk, _ = enc_out.shape

        tgt = batch["tgt_tokens"]
        x = embedding_lookup(params["embed"], tgt, cfg.dtype)
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        enc_positions = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))

        def body(carry, p_l):
            x = carry
            h, kv = attn_prefill(p_l["attn"], cfg,
                                 rmsnorm_apply(p_l["ln1"], x, cfg.rms_eps),
                                 positions)
            x = x + h
            ck = dense_apply(p_l["cross"]["wk"], enc_out).reshape(b, sk, hkv, dh)
            cv = dense_apply(p_l["cross"]["wv"], enc_out).reshape(b, sk, hkv, dh)
            c = attn_apply(p_l["cross"], cfg,
                           rmsnorm_apply(p_l["ln_cross"], x, cfg.rms_eps),
                           positions, causal=False, kv_override=(ck, cv))
            x = x + c
            x = x + mlp_apply(p_l["mlp"], cfg,
                              rmsnorm_apply(p_l["ln2"], x, cfg.rms_eps))
            return x, {"self": kv, "cross": (ck, cv)}

        x, layers = jax.lax.scan(body, x, params["dec_blocks"])
        x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
        if return_all_logits:
            logits = dense_apply(params["head"], x.astype(jnp.float32))
        else:
            logits = dense_apply(params["head"],
                                 x[:, -1:, :].astype(jnp.float32))[:, 0, :]
        if max_len and max_len > s:
            k, v = layers["self"]
            width = [(0, 0)] * k.ndim
            width[-3] = (0, max_len - s)
            layers = dict(layers, self=(jnp.pad(k, width), jnp.pad(v, width)))
        return logits, {"layers": layers, "len": jnp.array(s, jnp.int32)}

    def decode_step(self, params: dict, tokens: Array, cache: dict
                    ) -> tuple[Array, dict]:
        cfg = self.cfg
        cache_len = cache["len"]
        x = embedding_lookup(params["embed"], tokens, cfg.dtype)
        b = x.shape[0]

        def body(carry, inp):
            x = carry
            p_l, c_l = inp
            h, (k, v) = attn_decode(p_l["attn"], cfg,
                                    rmsnorm_apply(p_l["ln1"], x, cfg.rms_eps),
                                    cache_len, c_l["self"][0], c_l["self"][1],
                                    cache_len)
            x = x + h
            ck, cv = c_l["cross"]
            positions = jnp.broadcast_to(cache_len[None, None], (b, 1))
            c = attn_apply(p_l["cross"], cfg,
                           rmsnorm_apply(p_l["ln_cross"], x, cfg.rms_eps),
                           positions, causal=False, kv_override=(ck, cv))
            x = x + c
            x = x + mlp_apply(p_l["mlp"], cfg,
                              rmsnorm_apply(p_l["ln2"], x, cfg.rms_eps))
            return x, {"self": (k, v), "cross": (ck, cv)}

        x, layers = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["layers"]))
        x = rmsnorm_apply(params["final_norm"], x, cfg.rms_eps)
        logits = dense_apply(params["head"], x.astype(jnp.float32))[:, 0, :]
        return logits, {"layers": layers, "len": cache_len + 1}

    # ----------------------------------------------------------- cache/specs
    def init_cache(self, batch_size: int, max_len: int, src_len: int) -> dict:
        cfg = self.cfg
        hkv = cfg.n_kv_heads or cfg.n_heads
        dh = cfg.resolved_head_dim
        l = cfg.n_layers
        kv = lambda s: (jnp.zeros((l, batch_size, s, hkv, dh), cfg.dtype),
                        jnp.zeros((l, batch_size, s, hkv, dh), cfg.dtype))
        return {"layers": {"self": kv(max_len), "cross": kv(src_len)},
                "len": jnp.array(max(max_len - 1, 0), jnp.int32)}

    def input_specs(self, shape: ShapeSpec) -> dict:
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        sds = jax.ShapeDtypeStruct
        d_src = cfg.d_src or cfg.d_model
        if shape.kind == "train":
            return {"batch": {"src_embeds": sds((b, s, d_src), jnp.bfloat16),
                              "tgt_tokens": sds((b, s), jnp.int32)}}
        if shape.kind == "prefill":
            return {"batch": {"src_embeds": sds((b, s, d_src), jnp.bfloat16),
                              "tgt_tokens": sds((b, s), jnp.int32)}}
        src_len = s // cfg.vision_pool_window if cfg.vision_pool_window > 1 else s
        cache = jax.eval_shape(lambda: self.init_cache(b, s, src_len))
        return {"tokens": sds((b, 1), jnp.int32), "cache": cache}
