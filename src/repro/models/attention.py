"""Attention layer: GQA softmax attention (full / chunked-flash / decode) and
the paper's spiking Q-K attention (C4) as a drop-in replacement.

Softmax path
  * ``full``     — materializes [B,H,Sq,Sk] scores; right choice for
                   train_4k (4k^2 tiles fit VMEM budgets after blocking).
  * ``chunked``  — flash-style streaming over KV blocks with running
                   (max, denom) — used above ``cfg.flash_threshold`` so
                   prefill_32k never materializes a 32k^2 score matrix.
  * ``decode``   — one query position against the cache; with the cache
                   sequence-sharded (long_500k) GSPMD turns the softmax
                   reductions into the flash-decoding partial-softmax
                   combine across the 'data' axis automatically.

Spiking path (attention_kind="qk_spiking", paper C4 / QKFormer QKTA)
  Q,K are LIF spike maps; a per-token mask = spike(rowsum(Q) - theta) gates
  K; output = mask * K. O(N*Dh) — no score matrix, no softmax, and the mask
  for token i depends only on token i, so decode needs NO KV cache at all
  (this is what makes long_500k feasible for every arch in spiking mode).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .. import ops
from ..configs.base import ModelConfig
from ..core.qk_attention import qk_grouped_token_attention
from ..ops import SpikeTensor
from .layers import (apply_rope, causal_mask, dense_apply, dense_init,
                     maybe_spike, rmsnorm_apply, rmsnorm_init)
from .sharding import shard_act

Array = jax.Array


# ----------------------------------------------------------------------- init
def attn_init(rng: Array, cfg: ModelConfig, d_model: Optional[int] = None,
              n_heads: Optional[int] = None, n_kv: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    h = n_heads or cfg.n_heads
    hkv = n_kv or (cfg.n_kv_heads or h)
    dh = cfg.resolved_head_dim
    rq, rk, rv, ro = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(rq, d, h * dh, bias=cfg.qkv_bias, dtype=cfg.param_dtype),
        "wk": dense_init(rk, d, hkv * dh, bias=cfg.qkv_bias, dtype=cfg.param_dtype),
        "wv": dense_init(rv, d, hkv * dh, bias=cfg.qkv_bias, dtype=cfg.param_dtype),
        "wo": dense_init(ro, h * dh, d, dtype=cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, cfg.param_dtype)
        p["k_norm"] = rmsnorm_init(dh, cfg.param_dtype)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                 h: int, hkv: int):
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = dense_apply(p["wq"], x).reshape(b, s, h, dh)
    k = dense_apply(p["wk"], x).reshape(b, s, hkv, dh)
    v = dense_apply(p["wv"], x).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.rms_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: Array, h: int) -> Array:
    """[B,S,Hkv,Dh] -> [B,S,H,Dh] by repeating each KV head h/hkv times."""
    hkv = k.shape[-2]
    if hkv == h:
        return k
    return jnp.repeat(k, h // hkv, axis=-2)


# ---------------------------------------------------------------- full attn
def _attn_full(q: Array, k: Array, v: Array, scale: float,
               causal: bool, q_offset: int = 0) -> Array:
    # f32 via preferred_element_type (not .astype): the backward transposed
    # dots then produce bf16 dq/dk directly — their TP partial-sum
    # all-reduces run at half the wire width (EXPERIMENTS §Perf A7)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        scores = scores + causal_mask(q.shape[1], k.shape[1], q_offset)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


# ----------------------------------------------------------- chunked (flash)
def _attn_chunked(q: Array, k: Array, v: Array, scale: float, causal: bool,
                  q_block: int, kv_block: int) -> Array:
    """Flash-style: stream KV blocks, keep running (max, denom, out). The
    scan over KV blocks bounds live memory to O(q_block * kv_block)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, sk, q_block, kv_block)
    nq, nk = sq // q_block, sk // kv_block

    qb = q.reshape(b, nq, q_block, h, dh)
    kb = k.reshape(b, nk, kv_block, h, dh)
    vb = v.reshape(b, nk, kv_block, h, dh)

    def process_q_block(qi, q_i):
        # q_i: [b, q_block, h, dh]
        def kv_step(carry, inputs):
            m, l, o = carry
            ki, (k_j, v_j) = inputs
            s_ij = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                q_pos = qi * q_block + jnp.arange(q_block)[:, None]
                k_pos = ki * kv_block + jnp.arange(kv_block)[None, :]
                s_ij = s_ij + jnp.where(k_pos <= q_pos, 0.0, -1e30)[None, None]
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p_ij = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_ij.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_ij.astype(q.dtype), v_j).astype(jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        o0 = jnp.zeros((b, h, q_block, dh), jnp.float32)
        ks = jnp.arange(nk)
        # checkpoint the block body: backward recomputes p_ij from (q, k)
        # instead of saving [q_block, kv_block] scores per step — the
        # flash-attention memory property under autodiff
        (m, l, o), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, o0),
            (ks, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhqd->bqhd", out).astype(q.dtype)

    outs = jax.lax.map(lambda args: process_q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)


# -------------------------------------------------------------------- public
def attn_apply(p: dict, cfg: ModelConfig, x: Array, positions: Array,
               *, causal: bool = True, n_heads: Optional[int] = None,
               n_kv: Optional[int] = None,
               kv_override: Optional[tuple[Array, Array]] = None) -> Array:
    """Training/prefill attention over a full sequence.

    ``kv_override`` supplies external K/V (cross-attention: encoder states
    already projected). Returns [B, S, D_out].
    """
    h = n_heads or cfg.n_heads
    hkv = n_kv or (cfg.n_kv_heads or h)
    dh = cfg.resolved_head_dim
    b, s, _ = x.shape
    scale = dh ** -0.5

    if cfg.attention_kind == "qk_spiking":
        return _qk_spiking_apply(p, cfg, x, h, hkv)

    if kv_override is None:
        q, k, v = _project_qkv(p, cfg, x, positions, h, hkv)
    else:
        q = dense_apply(p["wq"], x).reshape(b, s, h, dh)
        if cfg.qk_norm:
            q = rmsnorm_apply(p["q_norm"], q, cfg.rms_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k, v = kv_override
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)

    if s * k.shape[1] > cfg.flash_threshold ** 2 and s > 1:
        out = _attn_chunked(q, k, v, scale, causal, cfg.attn_q_block,
                            cfg.attn_kv_block)
    else:
        out = _attn_full(q, k, v, scale, causal)
    return dense_apply(p["wo"], out.reshape(b, s, h * dh))


def attn_prefill(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                 *, n_heads: Optional[int] = None,
                 n_kv: Optional[int] = None) -> tuple[Array, tuple[Array, Array]]:
    """Prefill: full-sequence attention that ALSO returns (k, v) for the cache."""
    h = n_heads or cfg.n_heads
    hkv = n_kv or (cfg.n_kv_heads or h)
    dh = cfg.resolved_head_dim
    b, s, _ = x.shape
    if cfg.attention_kind == "qk_spiking":
        empty = jnp.zeros((b, 0, hkv, dh), x.dtype)
        if cfg.exec_policy.packed:
            # cache the last token's masked spike map BIT-PACKED — the
            # engine's per-slot spike state (8x fewer bytes than int8; the
            # telemetry popcounts it for measured sparsity)
            out, state = _qk_spiking_apply(p, cfg, x, h, hkv,
                                           return_spike_state=True)
            return out, (state, empty)
        out = _qk_spiking_apply(p, cfg, x, h, hkv)
        # QKTA keeps no inter-token state: empty cache entries
        return out, (empty, empty)
    q, k, v = _project_qkv(p, cfg, x, positions, h, hkv)
    ke, ve = _expand_kv(k, h), _expand_kv(v, h)
    scale = dh ** -0.5
    if s * s > cfg.flash_threshold ** 2:
        out = _attn_chunked(q, ke, ve, scale, True, cfg.attn_q_block,
                            cfg.attn_kv_block)
    else:
        out = _attn_full(q, ke, ve, scale, True)
    return dense_apply(p["wo"], out.reshape(b, s, h * dh)), (k, v)


def attn_append(p: dict, cfg: ModelConfig, x: Array,
                cache_k: Array, cache_v: Array, cache_len: Array,
                *, n_heads: Optional[int] = None,
                n_kv: Optional[int] = None) -> tuple[Array, tuple[Array, Array]]:
    """Continued (chunked) prefill: C new tokens against a partially-filled
    cache. x: [B, C, D]; cache_[kv]: [B, S_max, Hkv, Dh]; cache_len: scalar
    or [B] — the number of already-valid cache rows per sequence.

    The chunk's K/V rows are written at positions cache_len..cache_len+C-1
    and query i attends the cached prefix plus chunk positions <= i — the
    serving engine's elastic-FIFO prefill unit (one chunk per call, decode
    ticks interleave between calls). Bit-identical to running the whole
    prompt through ``attn_prefill`` in one pass: per-position projections
    are local, masked-out keys get exactly-zero softmax weight, and scores
    accumulate in f32 either way. C == 1 is ``attn_decode``'s math.

    NOTE: scores read the cache as written, so bit-identity to blocking
    prefill requires the cache dtype to be the COMPUTE dtype — with a
    quantized (f8) serving cache the engine keeps per-request chunk caches
    at compute precision and quantizes once on the slot write, exactly
    where the blocking path does. Bit-identity also assumes the blocking
    pass took the full-softmax branch: above ``cfg.flash_threshold``
    ``attn_prefill`` streams KV blocks with running-max rescaling, a
    different f32 reduction order this append path does not reproduce.
    """
    h = n_heads or cfg.n_heads
    hkv = n_kv or (cfg.n_kv_heads or h)
    dh = cfg.resolved_head_dim
    b, c, _ = x.shape
    scale = dh ** -0.5

    if cfg.attention_kind == "qk_spiking":
        # token-local: the chunk is self-contained; packed mode refreshes
        # the per-slot spike state with the chunk's last token
        if cfg.exec_policy.packed:
            out, state = _qk_spiking_apply(p, cfg, x, h, hkv,
                                           return_spike_state=True)
            return out, (state, cache_v)
        out = _qk_spiking_apply(p, cfg, x, h, hkv)
        return out, (cache_k, cache_v)

    lens = jnp.broadcast_to(jnp.asarray(cache_len), (b,))        # [B]
    positions = lens[:, None] + jnp.arange(c)[None, :]           # [B, C]
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, h, hkv)

    if jnp.ndim(cache_len) == 0:
        k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                         (0, cache_len, 0, 0))
        v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                         (0, cache_len, 0, 0))
    else:
        bi = jnp.arange(b)[:, None]
        rows = positions
        k = cache_k.at[bi, rows].set(k_new.astype(cache_k.dtype))
        v = cache_v.at[bi, rows].set(v_new.astype(cache_v.dtype))

    ke = _expand_kv(k.astype(q.dtype), h)
    ve = _expand_kv(v.astype(q.dtype), h)
    # f32 scores via preferred_element_type — same accumulation as the
    # blocking prefill's _attn_full, so chunked == blocking bit-for-bit
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, ke,
                        preferred_element_type=jnp.float32) * scale
    # query i (absolute position lens+i) sees key j iff j <= lens + i
    ki = jnp.arange(ke.shape[1])[None, None, :]                  # [1,1,S]
    valid = ki <= positions[:, :, None]                          # [B,C,S]
    scores = jnp.where(valid[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, ve)
    return dense_apply(p["wo"], out.reshape(b, c, h * dh)), (k, v)


def attn_decode(p: dict, cfg: ModelConfig, x: Array, pos: Array,
                cache_k: Array, cache_v: Array, cache_len: Array,
                *, n_heads: Optional[int] = None,
                n_kv: Optional[int] = None) -> tuple[Array, tuple[Array, Array]]:
    """One-token decode. x: [B, 1, D]; cache_[kv]: [B, S_max, Hkv, Dh];
    cache_len: [] scalar OR [B] vector of per-sequence valid lengths (the
    serving engine's slot pool uses the vector form; the new token is
    written at index cache_len per sequence).

    When the cache is sequence-sharded over 'data' (long_500k), the masked
    softmax below reduces over a sharded axis — GSPMD lowers it to the
    flash-decoding partial combine (max/sum all-reduce over 'data').
    """
    h = n_heads or cfg.n_heads
    hkv = n_kv or (cfg.n_kv_heads or h)
    dh = cfg.resolved_head_dim
    b = x.shape[0]
    scale = dh ** -0.5

    if cfg.attention_kind == "qk_spiking":
        if cfg.exec_policy.packed:
            out, state = _qk_spiking_apply(p, cfg, x, h, hkv,
                                           return_spike_state=True)
            return out, (state, cache_v)
        out = _qk_spiking_apply(p, cfg, x, h, hkv)
        return out, (cache_k, cache_v)

    lens = jnp.broadcast_to(jnp.asarray(cache_len), (b,))       # [B]
    positions = lens[:, None] if jnp.ndim(pos) <= 1 else pos
    if jnp.ndim(pos) == 0:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, h, hkv)

    if cfg.decode_cp_axis:
        # context-parallel decode (cache SEQUENCE-sharded over an axis):
        # the cache is FROZEN — a dynamic-index write into a seq-sharded
        # buffer makes GSPMD gather the whole cache (measured: 56 GB/step
        # on decode_32k, EXPERIMENTS §Perf C). Instead the new token's K/V
        # joins the softmax as a separate flash-decode term; reductions
        # over the sharded seq dim lower to tiny [B,H] stat all-reduces.
        # q must REPLICATE across the cp axis (it is KB-sized): if it stays
        # head-sharded over 'model' the score einsum cannot shard over seq
        # and GSPMD gathers the whole cache instead. GQA is handled with a
        # GROUPED einsum (q reshaped [B,1,Hkv,G,Dh]) — jnp.repeat of a
        # seq-sharded cache lowers to a broadcast GSPMD can only realize by
        # gathering (measured: 56 GB/step; EXPERIMENTS §Perf C4).
        g = h // hkv
        q5 = shard_act(q, "dp", None, None, None).reshape(b, 1, hkv, g, dh)
        kc = cache_k.astype(q.dtype)                     # [b,S,hkv,dh]
        vc = cache_v.astype(q.dtype)
        s_ctx = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kc,
                           preferred_element_type=jnp.float32) * scale
        valid = (jnp.arange(kc.shape[1])[None, :] < lens[:, None])
        s_ctx = jnp.where(valid[:, None, None, None, :], s_ctx, -1e30)
        s_new = jnp.einsum("bqhgd,bqhd->bhgq", q5, k_new.astype(q.dtype),
                           preferred_element_type=jnp.float32)[..., None] * scale
        m = jnp.maximum(s_ctx.max(axis=-1, keepdims=True), s_new)
        p_ctx = jnp.exp(s_ctx - m)                       # [b,hkv,g,1,S]
        p_new = jnp.exp(s_new - m)[..., 0]               # [b,hkv,g,1]
        denom = p_ctx.sum(axis=-1) + p_new               # [b,hkv,g,1]
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p_ctx.astype(q.dtype), vc)
        out = out + jnp.einsum("bhgq,bqhd->bhgqd", p_new.astype(q.dtype),
                               v_new.astype(q.dtype))
        out = out / denom[..., None].astype(q.dtype)     # [b,hkv,g,1,dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h * dh)
        out = dense_apply(p["wo"], out)
        return out, (cache_k, cache_v)

    # write the new K/V row at index cache_len (per sequence)
    if jnp.ndim(cache_len) == 0:
        k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                         (0, cache_len, 0, 0))
        v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                         (0, cache_len, 0, 0))
    else:
        bi = jnp.arange(b)
        k = cache_k.at[bi, lens].set(k_new[:, 0].astype(cache_k.dtype))
        v = cache_v.at[bi, lens].set(v_new[:, 0].astype(cache_v.dtype))

    ke = _expand_kv(k.astype(q.dtype), h)
    ve = _expand_kv(v.astype(q.dtype), h)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * scale
    valid = (jnp.arange(ke.shape[1])[None, :] <= lens[:, None])
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(q.dtype), ve)
    return dense_apply(p["wo"], out.reshape(b, 1, h * dh)), (k, v)


# ----------------------------------------------------- spiking QKTA (paper C4)
def qk_spike_state_width(cfg: ModelConfig) -> int:
    """int32 words per cached packed spike-state row: the masked attention
    map [H*Dh] padded to the 128 lane grid, 32 spikes per word."""
    d = cfg.n_heads * cfg.resolved_head_dim
    return (-(-d // 128) * 128) // 32


def _packed_token_state(out_last: Array) -> Array:
    """[B, D] binary spike map -> [B, 1, 1, ceil(D/128)*4] int32 words —
    the per-token spike state the serving engine caches per slot (packed:
    8x fewer bytes than int8, and popcount over it IS the measured spike
    count the engine's telemetry reports)."""
    from ..core.events import pack_words

    b, d = out_last.shape
    dp = -(-d // 128) * 128
    padded = jnp.pad(out_last.astype(jnp.int32), ((0, 0), (0, dp - d)))
    return pack_words(padded)[:, None, None, :]


def _token_state(st: SpikeTensor, b: int, s: int) -> Array:
    """Last token's masked spike map as packed [B, 1, 1, W] int32 — the
    per-slot state the serving engine caches, extracted without unpacking
    when the map is already packed."""
    if st.is_packed:
        dw = st.data.shape[-1]
        return st.data[:b * s].reshape(b, s, dw)[:, -1][:, None, None, :]
    return _packed_token_state(st.data.reshape(b, s, -1)[:, -1])


def _qk_spiking_apply(p: dict, cfg: ModelConfig, x: Array,
                      h: int, hkv: int, *, return_spike_state: bool = False):
    """QKFormer token attention on LIF spikes (paper Fig 5, on-the-fly form).

    Per head: Q,K spike maps [B,S,h,Dh]; token mask from Q row-sum gates K.
    No RoPE (spike trains carry no phase), no cache (mask is token-local).

    ``cfg.exec_policy`` selects the execution (one body, no format forks):

      * fused policies (deployed serving path) run NEURAL's fused PE
        dataflow for EVERY head count — wq/wk projections + LIF threshold
        are single fused Pallas passes (``ops.dense_lif``; no f32
        pre-activation round-trip), and the QK token mask is applied
        inside the K pass's write-back as a HEAD-BLOCKED mask (the full
        Fig 5 fusion: one row-sum threshold per head; h==1 degenerates to
        the whole-row mask). Grouped KV (hkv < h) expands the K
        projection's WEIGHT columns so the per-query-head mask gates
        in-kernel — no replicated per-token KV tensor. The output
        projection consumes the masked spikes through the event-skipped
        ``ops.matmul``. Forward-exact vs the reference path; a
        differentiable policy (``policy.for_training()`` — what
        ``launch/train.py --spiking --policy fused_dense`` requests)
        additionally routes these ops through their surrogate-gradient
        custom_vjp so the SAME fused forward trains with backprop.
      * a packed policy ships the spike maps between passes bit-packed
        end to end for every head count: the Q operand's per-head row
        sums are in-kernel masked popcounts and the K pass's output
        leaves packed — the masked map never exists dense.

    ``return_spike_state`` additionally returns the LAST token's masked
    spike map packed ([B, 1, 1, W] int32) — the state the serving engine
    caches per slot.
    """
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    pol = cfg.exec_policy
    state = None
    if pol.fused:
        # fully fused head-blocked Fig 5 chain: the K pass masks per head
        # on write-back, and under a packed policy the masked map never
        # exists dense
        q_st = ops.dense_lif(p["wq"], x, cfg.lif, policy=pol)
        out_st = ops.dense_lif(p["wk"], x, cfg.lif, q=q_st,
                               qk_threshold=cfg.lif.v_th,
                               heads=(h, dh), kv_heads=hkv, policy=pol)
        proj = ops.matmul(out_st, p["wo"]["w"], policy=pol).astype(x.dtype)
        if return_spike_state:
            state = _token_state(out_st, b, s)
        if "b" in p["wo"]:
            proj = proj + p["wo"]["b"].astype(proj.dtype)
        proj = proj.reshape(b, s, -1)
        return (proj, state) if return_spike_state else proj
    q_cur = dense_apply(p["wq"], x).reshape(b, s, h, dh)
    k_cur = dense_apply(p["wk"], x).reshape(b, s, hkv, dh)
    q = maybe_spike(q_cur, True, cfg.lif)
    k = maybe_spike(k_cur, True, cfg.lif)
    # [B,S,H,Dh] — the QK token mask (4); grouped KV broadcasts the
    # per-query-head mask over each group instead of replicating K
    out = qk_grouped_token_attention(q, k, mode="threshold",
                                     threshold=cfg.lif.v_th,
                                     surrogate=cfg.lif.surrogate,
                                     alpha=cfg.lif.alpha)
    proj = dense_apply(p["wo"], out.reshape(b, s, h * dh))
    if return_spike_state:
        state = _packed_token_state(out.reshape(b, s, h * dh)[:, -1])
        return proj, state
    return proj
