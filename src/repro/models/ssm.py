"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Chunked SSD algorithm: within-chunk computation is attention-like (dense
matmuls — MXU-friendly), across chunks a tiny sequential recurrence carries
the [H, P, N] state. Chunk length is ``cfg.ssm_chunk``.

Decode is O(1): a per-layer (conv_state, ssm_state) pair replaces the KV
cache entirely — which is why the ssm/hybrid archs are the ones that run
the long_500k cell.

Sharding: batch on ('pod','data'); the d_inner axis (and thus heads) on
'model'; the recurrent state [B, H, P, N] shards the same way. The
inter-chunk scan is sequential in time but involves no collectives.

Spiking hook (paper C3): ``spiking`` replaces the SiLU on the conv branch
with a LIF spike, making xBC a binary event stream (the SSM input events).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_apply, dense_init, maybe_spike, rmsnorm_gated_apply, rmsnorm_init

Array = jax.Array


def ssm_dims(cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    nheads = d_inner // cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    return d, d_inner, nheads, g, n, conv_dim


def mamba_init(rng: Array, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d, d_inner, h, g, n, conv_dim = ssm_dims(cfg, d_model)
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    d_in_proj = 2 * d_inner + 2 * g * n + h
    dt = jnp.exp(jax.random.uniform(r3, (h,)) * (jnp.log(0.1) - jnp.log(0.001))
                 + jnp.log(0.001))
    dt = jnp.clip(dt, 1e-4, None)
    return {
        "in_proj": dense_init(r1, d, d_in_proj, dtype=cfg.param_dtype),
        "conv_w": (jax.random.normal(r2, (cfg.ssm_conv, conv_dim)) * 0.02
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(d_inner, cfg.param_dtype),
        "out_proj": dense_init(r4, d_inner, d, dtype=cfg.param_dtype),
    }


def _split_proj(zxbcdt: Array, cfg: ModelConfig, d_inner: int, g: int, n: int):
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array, spiking: bool, cfg,
                 tail: Optional[Array] = None) -> Array:
    """Depthwise causal conv over time. xbc: [B,S,C]; w: [K,C].

    ``tail`` [B, K-1, C] supplies the previous chunk's last K-1 pre-conv
    inputs (chunked prefill); None means sequence start (zero history)."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    # depthwise conv as K shifted adds — K is tiny (4); avoids conv lowering
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :].astype(xbc.dtype)
              for i in range(k))
    out = out + b.astype(out.dtype)
    return maybe_spike(out, True, cfg.lif) if spiking else jax.nn.silu(out)


def _ssd_chunked(xs: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                 chunk: int, init_state: Optional[Array] = None
                 ) -> tuple[Array, Array]:
    """Chunked SSD: ONE scan over chunks carrying the [B,H,P,N] state.

    xs: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B,S,G,N]. Returns (y [B,S,H,P], final_state [B,H,P,N]).

    The intra-chunk decay matrix L [B,q,q,H] lives only inside one scan step
    (and the body is checkpointed), so peak memory is O(S/chunk) smaller than
    the fully-vectorized formulation — the same working-set argument as the
    paper's elastic-FIFO streaming: stream blocks, keep one in flight.
    """
    b, s, h, p = xs.shape
    g, n = Bm.shape[-2:]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g                                       # heads per B/C group
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    # chunk-major inputs for the scan: [nc, b, chunk, ...]
    def cm(x):
        return jnp.moveaxis(x.reshape(b, nc, chunk, *x.shape[2:]), 1, 0)

    xs_c, dt_c, B_c, C_c = cm(xs), cm(dt), cm(Bm), cm(Cm)

    def body(state, inp):
        x_i, dt_i, B_i, C_i = inp                    # [b,q,h,p] [b,q,h] [b,q,g,n]
        dA = dt_i * A[None, None, :]                 # [b,q,h] (negative)
        dA_cs = jnp.cumsum(dA, axis=1)
        # intra-chunk: L[i,j] = exp(dA_cs[i]-dA_cs[j]) for i>=j (masked pre-exp)
        li = dA_cs[:, :, None, :] - dA_cs[:, None, :, :]      # [b,i,j,h]
        L = jnp.exp(jnp.where(mask[None, :, :, None], li, -jnp.inf))
        scores = jnp.einsum("bign,bjgn->bijg", C_i.astype(jnp.float32),
                            B_i.astype(jnp.float32))          # [b,i,j,g]
        dx = dt_i[..., None] * x_i.astype(jnp.float32)        # [b,q,h,p]
        # group heads: h = g*hg — contract without materializing repeat()
        Lg = L.reshape(b, chunk, chunk, g, hg)
        dxg = dx.reshape(b, chunk, g, hg, p)
        y_intra = jnp.einsum("bijgr,bijg,bjgrp->bigrp", Lg, scores, dxg)
        # inter-chunk: contribution of the incoming state
        decay_in = jnp.exp(dA_cs)                             # [b,q,h]
        stg = state.reshape(b, g, hg, p, n)
        y_inter = jnp.einsum("bqgn,bghpn->bqghp",
                             C_i.astype(jnp.float32), stg)
        y_inter = y_inter * decay_in.reshape(b, chunk, g, hg)[..., None]
        # state update
        seg_end = dA_cs[:, -1:, :]                            # [b,1,h]
        decay_to_end = jnp.exp(seg_end - dA_cs)               # [b,q,h]
        wdx = (dx * decay_to_end[..., None]).reshape(b, chunk, g, hg, p)
        new_state = jnp.einsum("bqgn,bqghp->bghpn",
                               B_i.astype(jnp.float32), wdx)
        new_state = new_state.reshape(b, h, p, n)
        state = state * jnp.exp(seg_end[:, 0, :])[..., None, None] + new_state
        y = (y_intra + y_inter.reshape(b, chunk, g, hg, p)).reshape(
            b, chunk, h, p)
        return state, y

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, y_c = jax.lax.scan(jax.checkpoint(body), s0,
                              (xs_c, dt_c, B_c, C_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(b, s, h, p)
    return y, final


def mamba_apply(p: dict, cfg: ModelConfig, x: Array,
                d_model: Optional[int] = None,
                init_state: Optional[dict] = None,
                return_state: bool = False):
    """Full-sequence forward. x: [B,S,D] -> y: [B,S,D] (+ state dict)."""
    d, d_inner, h, g, n, conv_dim = ssm_dims(cfg, d_model)
    b, s, _ = x.shape
    zxbcdt = dense_apply(p["in_proj"], x)
    z, xbc_raw, dt_raw = _split_proj(zxbcdt, cfg, d_inner, g, n)
    conv_tail = None if init_state is None else init_state["conv"]
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"], cfg.spiking, cfg,
                       tail=conv_tail)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, s, h, cfg.ssm_headdim)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    chunk = min(cfg.ssm_chunk, s)
    s0 = (s // chunk) * chunk
    state0 = None if init_state is None else init_state["ssm"]
    if s0:
        y0, st = _ssd_chunked(xs[:, :s0], dt[:, :s0], A, Bm[:, :s0],
                              Cm[:, :s0], chunk, state0)
    else:
        y0, st = None, state0
    if s0 < s:                      # remainder chunk (exact, no padding)
        y1, st = _ssd_chunked(xs[:, s0:], dt[:, s0:], A, Bm[:, s0:],
                              Cm[:, s0:], s - s0, st)
        y = y1 if y0 is None else jnp.concatenate([y0, y1], axis=1)
    else:
        y = y0
    final = st
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm_gated_apply(p["norm"], y, z, cfg.rms_eps)
    out = dense_apply(p["out_proj"], y)
    if not return_state:
        return out
    # conv state = last K-1 PRE-conv inputs; history (the incoming conv tail
    # or zeros at sequence start) covers chunks shorter than K-1
    k1 = cfg.ssm_conv - 1
    hist = (jnp.zeros((b, k1, xbc_raw.shape[-1]), x.dtype)
            if conv_tail is None else conv_tail.astype(x.dtype))
    tail = jnp.concatenate([hist, xbc_raw], axis=1)[:, -k1:, :]
    return out, {"ssm": final.astype(jnp.float32), "conv": tail}


def mamba_init_state(cfg: ModelConfig, batch: int,
                     d_model: Optional[int] = None, dtype=jnp.float32) -> dict:
    d, d_inner, h, g, n, conv_dim = ssm_dims(cfg, d_model)
    return {
        "ssm": jnp.zeros((batch, h, cfg.ssm_headdim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba_decode_step(p: dict, cfg: ModelConfig, x: Array, state: dict,
                      d_model: Optional[int] = None) -> tuple[Array, dict]:
    """One-token step. x: [B,1,D]; state: {'ssm':[B,H,P,N], 'conv':[B,K-1,C]}."""
    d, d_inner, h, g, n, conv_dim = ssm_dims(cfg, d_model)
    b = x.shape[0]
    zxbcdt = dense_apply(p["in_proj"], x[:, 0, :])           # [B, dproj]
    z, xbc_new, dt_raw = _split_proj(zxbcdt, cfg, d_inner, g, n)

    # conv state update: window = [conv_state, xbc_new]
    window = jnp.concatenate([state["conv"].astype(x.dtype),
                              xbc_new[:, None, :]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(x.dtype))
    conv_out = conv_out + p["conv_b"].astype(conv_out.dtype)
    xbc = (maybe_spike(conv_out, True, cfg.lif) if cfg.spiking
           else jax.nn.silu(conv_out))
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)
    xs = xs.reshape(b, h, cfg.ssm_headdim).astype(jnp.float32)
    Bm = Bm.reshape(b, g, n).astype(jnp.float32)
    Cm = Cm.reshape(b, g, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    hg = h // g

    decay = jnp.exp(dt * A)[..., None, None]                 # [B,H,1,1]
    Bh = jnp.repeat(Bm, hg, axis=-2)                         # [B,H,N]
    Ch = jnp.repeat(Cm, hg, axis=-2)
    upd = (dt[..., None] * xs)[..., :, None] * Bh[:, :, None, :]  # [B,H,P,N]
    new_ssm = state["ssm"] * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Ch)             # [B,H,P]
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rmsnorm_gated_apply(p["norm"], y, z, cfg.rms_eps)
    out = dense_apply(p["out_proj"], y)[:, None, :]
    return out, {"ssm": new_ssm, "conv": new_conv}
