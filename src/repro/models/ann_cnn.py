"""ANN teacher models for the KD framework (paper §V.A: teacher = ResNet-34).

Standard ReLU CNNs sharing the nn.py layer library. Also provides the ANN
VGG-11 used as the non-spiking reference in the Fig 8 / Fig 9 comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import nn

Array = jax.Array

_DEPTHS = {"resnet18": (2, 2, 2, 2), "resnet34": (3, 4, 6, 3)}
_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512]


@dataclasses.dataclass(frozen=True)
class ANNCNNConfig:
    arch: str = "resnet34"          # resnet18 | resnet34 | vgg11
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    width_mult: float = 1.0
    dtype: Any = jnp.float32


def _c(ch: int, cfg: ANNCNNConfig) -> int:
    return max(8, int(ch * cfg.width_mult))


def build_layers(cfg: ANNCNNConfig) -> list[tuple]:
    layers: list[tuple] = []
    cin = cfg.in_channels
    size = cfg.image_size
    if cfg.arch == "vgg11":
        for item in _VGG11:
            if item == "M":
                layers.append(("maxpool",))
                size //= 2
            else:
                cout = _c(item, cfg)
                layers.append(("conv", cin, cout, 1))
                cin = cout
    else:
        blocks = _DEPTHS[cfg.arch]
        stem = _c(64, cfg)
        layers.append(("conv", cin, stem, 1))
        cin = stem
        for stage, nblk in enumerate(blocks):
            cout = _c(64 * (2 ** stage), cfg)
            for i in range(nblk):
                stride = 2 if (stage > 0 and i == 0) else 1
                layers.append(("resblock", cin, cout, stride))
                cin = cout
                size //= stride
    layers.append(("head", cin, size))
    return layers


def init(rng: Array, cfg: ANNCNNConfig) -> dict:
    params: list = []
    state: list = []
    layers = build_layers(cfg)
    for r, layer in zip(jax.random.split(rng, len(layers)), layers):
        kind = layer[0]
        if kind == "conv":
            _, cin, cout, _ = layer
            bn_p, bn_s = nn.bn_init(cout, cfg.dtype)
            params.append({"conv": nn.conv_init(r, 3, 3, cin, cout, dtype=cfg.dtype), "bn": bn_p})
            state.append({"bn": bn_s})
        elif kind == "maxpool":
            params.append({})
            state.append({})
        elif kind == "resblock":
            _, cin, cout, stride = layer
            r1, r2, r3 = jax.random.split(r, 3)
            bn1p, bn1s = nn.bn_init(cout, cfg.dtype)
            bn2p, bn2s = nn.bn_init(cout, cfg.dtype)
            p = {"conv1": nn.conv_init(r1, 3, 3, cin, cout, dtype=cfg.dtype), "bn1": bn1p,
                 "conv2": nn.conv_init(r2, 3, 3, cout, cout, dtype=cfg.dtype), "bn2": bn2p}
            s = {"bn1": bn1s, "bn2": bn2s}
            if stride != 1 or cin != cout:
                bnsp, bnss = nn.bn_init(cout, cfg.dtype)
                p["conv_sc"] = nn.conv_init(r3, 1, 1, cin, cout, dtype=cfg.dtype)
                p["bn_sc"] = bnsp
                s["bn_sc"] = bnss
            params.append(p)
            state.append(s)
        elif kind == "head":
            _, cin, _ = layer
            params.append({"fc": nn.linear_init(r, cin, cfg.num_classes, dtype=cfg.dtype)})
            state.append({})
    return {"params": params, "state": state}


def _conv_bn_relu(conv_p, bn_p, bn_s, x, train, stride=1, relu=True):
    y = nn.conv_apply(conv_p, x, stride)
    y, new_s = nn.bn_apply(bn_p, bn_s, y, train)
    if relu:
        y = jax.nn.relu(y)
    return y, new_s


def apply(variables: dict, images: Array, cfg: ANNCNNConfig,
          train: bool = False) -> tuple[Array, list]:
    params, state = variables["params"], variables["state"]
    layers = build_layers(cfg)
    x = images.astype(cfg.dtype)
    new_state: list = []
    for p, s, layer in zip(params, state, layers):
        kind = layer[0]
        if kind == "conv":
            x, bn_s = _conv_bn_relu(p["conv"], p["bn"], s["bn"], x, train, layer[3])
            new_state.append({"bn": bn_s})
        elif kind == "maxpool":
            x = nn.max_pool(x)
            new_state.append({})
        elif kind == "resblock":
            stride = layer[3]
            y, bn1_s = _conv_bn_relu(p["conv1"], p["bn1"], s["bn1"], x, train, stride)
            y2 = nn.conv_apply(p["conv2"], y, 1)
            y2, bn2_s = nn.bn_apply(p["bn2"], s["bn2"], y2, train)
            ns = {"bn1": bn1_s, "bn2": bn2_s}
            if "conv_sc" in p:
                sc = nn.conv_apply(p["conv_sc"], x, stride)
                sc, bnsc_s = nn.bn_apply(p["bn_sc"], s["bn_sc"], sc, train)
                ns["bn_sc"] = bnsc_s
            else:
                sc = x
            x = jax.nn.relu(y2 + sc)
            new_state.append(ns)
        elif kind == "head":
            _, cin, size = layer
            pooled = nn.avg_pool(x, size).reshape(x.shape[0], -1)
            logits = nn.linear_apply(p["fc"], pooled)
            new_state.append({})
    return logits, new_state
