"""W2TTFS — Window-to-Time-to-First-Spike (paper C2, Algorithm 1, Fig 6).

Average pooling on binary spike maps breaks full-spike execution: its output
is continuous (k/window^2). W2TTFS re-expresses each pooling window as a
ONE-HOT SPIKE over ``window^2 + 1`` virtual timesteps — the window's spike
count ``vld_cnt`` selects the firing time — and the classifier weights are
scaled by ``t / window^2`` at time t. The classifier therefore consumes only
binary spikes.

Three implementations, proven equivalent in tests:
  * ``w2ttfs_reference``      — Algorithm 1 verbatim (explicit time expansion),
  * ``w2ttfs_classifier``     — NEURAL's optimized WTFC: count -> unit scale
                                (1/window^2) with *time reuse* (repeat the unit
                                accumulation vld_cnt times; no divider),
  * plain ``avg_pool + FC``   — the ANN op being replaced; identical numerics
                                on binary inputs, which is WHY accuracy is
                                preserved (paper Fig 8 "W2TTFS" bars).

Layout: NHWC (TPU-friendly). ``spike_map``: [B, H, W, C] binary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def window_counts(spike_map: Array, window: int) -> Array:
    """vld_cnt per pooling window (the TTFS Filter in Fig 6).

    [B, H, W, C] -> [B, H//window, W//window, C] integer spike counts.
    """
    b, h, w, c = spike_map.shape
    ho, wo = h // window, w // window
    x = spike_map.reshape(b, ho, window, wo, window, c)
    return x.sum(axis=(2, 4))


def w2ttfs_expand(spike_map: Array, window: int) -> Array:
    """Algorithm 1 lines 4-16: one-hot spike train over window^2+1 timesteps.

    Returns [T=window^2+1, B, Ho, Wo, C] binary array where slice t has a
    spike exactly where the window's vld_cnt == t. (Algorithm 1 sizes the
    array ``window^2``; we use window^2+1 so a fully-active window — vld_cnt
    == window^2 — is representable. The paper's Verilog counts to the same
    bound; the pseudo-code elides the +1.)
    """
    cnt = window_counts(spike_map, window)  # [B, Ho, Wo, C]
    t_axis = jnp.arange(window * window + 1)
    onehot = (cnt[None, ...] == t_axis[:, None, None, None, None])
    return onehot.astype(spike_map.dtype)


def w2ttfs_reference(spike_map: Array, fc_w: Array, fc_b: Array,
                     window: int) -> Array:
    """Algorithm 1 verbatim: classifier over the expanded spike train.

    Lines 17-20: at virtual timestep t the FC weights are scaled by
    ``t / window^2``; the logits are the sum over timesteps. ``fc_w``:
    [Ho*Wo*C, num_classes].
    """
    expanded = w2ttfs_expand(spike_map, window)       # [T, B, Ho, Wo, C]
    t, b = expanded.shape[0], expanded.shape[1]
    flat = expanded.reshape(t, b, -1)
    scales = jnp.arange(t, dtype=fc_w.dtype) / float(window * window)

    def step(acc, xs):
        spikes_t, scale_t = xs
        return acc + (spikes_t @ fc_w) * scale_t, None

    init = jnp.zeros((b, fc_w.shape[1]), fc_w.dtype)
    logits, _ = jax.lax.scan(step, init, (flat, scales))
    return logits + fc_b


def w2ttfs_classifier(spike_map: Array, fc_w: Array, fc_b: Array,
                      window: int) -> Array:
    """NEURAL's optimized WTFC (Fig 6): vld_cnt * unit-scale FC.

    The scale no longer depends on the spike position: it is uniformly
    1/window^2, and a count of k is realized by REUSING the unit accumulation
    k times (paper §IV.D) — i.e. logits = (counts @ W) * (1/window^2). No
    multiplier or divider is needed in hardware; here the algebraic identity
    gives one small matmul.
    """
    cnt = window_counts(spike_map, window).astype(fc_w.dtype)  # [B,Ho,Wo,C]
    b = cnt.shape[0]
    unit = 1.0 / float(window * window)
    return (cnt.reshape(b, -1) @ fc_w) * unit + fc_b


def w2ttfs_time_reuse(spike_map: Array, fc_w: Array, fc_b: Array,
                      window: int) -> Array:
    """Bit-exact emulation of the time-reuse datapath: at micro-step u the FC
    accumulates ``unit * [vld_cnt > u]`` — i.e. the unit contribution is
    replayed vld_cnt times per window. Used by tests to show the hardware
    trick equals the algebraic form.
    """
    cnt = window_counts(spike_map, window)  # [B, Ho, Wo, C]
    b = cnt.shape[0]
    flat_cnt = cnt.reshape(b, -1)
    unit = 1.0 / float(window * window)

    def step(acc, u):
        # TTFS replay decode on integer counts, inference-only
        active = (flat_cnt > u).astype(fc_w.dtype)  # neurallint: disable=NL-BARE-HEAVISIDE
        return acc + (active @ fc_w) * unit, None

    init = jnp.zeros((b, fc_w.shape[1]), fc_w.dtype)
    logits, _ = jax.lax.scan(step, init, jnp.arange(window * window))
    return logits + fc_b


def avgpool_classifier(x: Array, fc_w: Array, fc_b: Array, window: int) -> Array:
    """The ANN head W2TTFS replaces: avg-pool then FC. On binary inputs this
    is numerically identical to the W2TTFS head (equivalence tested)."""
    b, h, w, c = x.shape
    ho, wo = h // window, w // window
    pooled = x.reshape(b, ho, window, wo, window, c).mean(axis=(2, 4))
    return pooled.reshape(b, -1).astype(fc_w.dtype) @ fc_w + fc_b
