"""Knowledge-distillation training framework (paper C1, Fig 2(b)).

Pipeline stages, exactly as the paper names them (Fig 8 legend):
  KDT     — full-precision student trained with logit-based KD from an ANN
            teacher (ref [6]: logit KD, temperature-scaled KL + CE),
  F&Q     — operator fusion + fixed-point quantization (post-training),
  KD-QAT  — quantization-aware fine-tuning with the same KD loss,
  W2TTFS  — swap average pooling for the W2TTFS head at inference.

The framework is model-agnostic: it only needs ``apply(params, batch) ->
logits`` for the student and teacher, so it distills the paper's CNNs and the
spiking-LM extension alike.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KDConfig:
    temperature: float = 4.0
    alpha: float = 0.7          # weight on the KD (KL) term; (1-alpha) on CE
    feature_beta: float = 0.0   # optional hidden-state MSE term


def softmax_cross_entropy(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def kl_divergence(student_logits: Array, teacher_logits: Array,
                  temperature: float) -> Array:
    """KL(teacher || student) with temperature scaling, scaled by T^2 so the
    gradient magnitude is independent of T (Hinton et al.)."""
    t = temperature
    p_t = jax.nn.softmax(teacher_logits / t, axis=-1)
    logp_t = jax.nn.log_softmax(teacher_logits / t, axis=-1)
    logp_s = jax.nn.log_softmax(student_logits / t, axis=-1)
    kl = jnp.sum(p_t * (logp_t - logp_s), axis=-1)
    return jnp.mean(kl) * (t * t)


def kd_loss(student_logits: Array, teacher_logits: Array, labels: Array,
            cfg: KDConfig = KDConfig(),
            student_feats: Optional[Array] = None,
            teacher_feats: Optional[Array] = None) -> tuple[Array, dict]:
    ce = softmax_cross_entropy(student_logits, labels)
    kl = kl_divergence(student_logits, jax.lax.stop_gradient(teacher_logits),
                       cfg.temperature)
    loss = (1.0 - cfg.alpha) * ce + cfg.alpha * kl
    metrics = {"ce": ce, "kl": kl}
    if cfg.feature_beta > 0.0 and student_feats is not None:
        fmse = jnp.mean((student_feats - jax.lax.stop_gradient(teacher_feats)) ** 2)
        loss = loss + cfg.feature_beta * fmse
        metrics["feature_mse"] = fmse
    metrics["loss"] = loss
    return loss, metrics


def sequence_kd_loss(student_logits: Array, teacher_logits: Array,
                     tokens: Array, cfg: KDConfig = KDConfig(),
                     mask: Optional[Array] = None) -> tuple[Array, dict]:
    """Token-level KD for LM distillation (spiking-LM extension).

    ``student_logits/teacher_logits``: [B, S, V]; ``tokens``: [B, S] targets.
    """
    b, s, v = student_logits.shape
    sl = student_logits.reshape(b * s, v)
    tl = teacher_logits.reshape(b * s, v)
    lab = tokens.reshape(b * s)
    if mask is not None:
        m = mask.reshape(b * s).astype(sl.dtype)
        logp = jax.nn.log_softmax(sl, axis=-1)
        ce = -(jnp.take_along_axis(logp, lab[:, None], axis=-1)[:, 0] * m).sum() / jnp.maximum(m.sum(), 1.0)
    else:
        ce = softmax_cross_entropy(sl, lab)
    kl = kl_divergence(sl, jax.lax.stop_gradient(tl), cfg.temperature)
    loss = (1.0 - cfg.alpha) * ce + cfg.alpha * kl
    return loss, {"ce": ce, "kl": kl, "loss": loss}


def make_distill_loss_fn(student_apply: Callable, teacher_apply: Callable,
                         teacher_params, cfg: KDConfig = KDConfig()) -> Callable:
    """Build ``loss_fn(student_params, batch) -> (loss, metrics)``.

    ``batch`` = {"inputs": ..., "labels": ...}. Teacher params are closed over
    and stop-gradiented; teacher runs in eval mode through its own apply fn.
    """

    def loss_fn(student_params, batch):
        s_logits = student_apply(student_params, batch["inputs"])
        t_logits = teacher_apply(teacher_params, batch["inputs"])
        return kd_loss(s_logits, t_logits, batch["labels"], cfg)

    return loss_fn
