"""Hybrid data-event execution metadata (paper C3 — PipeSDA / elastic FIFO,
adapted to TPU block granularity).

On the FPGA, PipeSDA turns each input spike's coordinates into per-neuron
event lists (SDU FIFOs) and each PE's FIFO tail register holds ``vld_cnt`` —
the number of valid events — so the LIF unit only runs for real events.

A TPU cannot gate single lanes, but it CAN gate whole VMEM blocks: control is
amortized per tile, so the event granularity that pays on this hardware is the
block. This module computes the *event metadata* — per-block spike counts
(``vld_cnt`` maps) — once per activation tensor (the PipeSDA analogue), and
the event-driven kernels (``repro.kernels.spike_matmul``) consume it with
``@pl.when(vld_cnt > 0)`` to skip silent blocks entirely: no MXU work, no HBM
write. The data-driven level is the Pallas grid itself (the elastic-FIFO
stream of blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def block_count_map_2d(spikes: Array, block_m: int, block_k: int) -> Array:
    """vld_cnt per (block_m x block_k) tile of a [M, K] spike matrix.

    Returns int32 [M//block_m, K//block_k]. M, K must be tile-aligned (pad
    first with ``pad_to_blocks``). This is the PipeSDA output: routing
    metadata for the event-driven matmul.

    Counts NONZERO entries — identical to the spike count for binary maps,
    and the right gating semantics when the operand is a dense (non-binary)
    activation tensor fed through the same event-skipped matmul.
    """
    m, k = spikes.shape
    assert m % block_m == 0 and k % block_k == 0, (m, k, block_m, block_k)
    x = (spikes != 0).reshape(m // block_m, block_m, k // block_k, block_k)
    return x.astype(jnp.int32).sum(axis=(1, 3))


def vld_or_compute(x: Array, vld_cnt: Array | None,
                   block_m: int, block_k: int) -> Array:
    """Metadata plumbing for the on-the-fly dataflow (paper C3 + Fig 5).

    ``x`` must already be padded to the block grid. When the previous layer's
    fused kernel emitted this tensor's ``vld_cnt`` map (fused_pe's third
    output), pass it through and the reduction pass over HBM is skipped —
    that is the PipeSDA metadata produced on the fly. Otherwise compute it
    here (one pass over ``x``).
    """
    m, k = x.shape
    expect = (m // block_m, k // block_k)
    if vld_cnt is None:
        return block_count_map_2d(x, block_m, block_k)
    assert vld_cnt.shape == expect, (vld_cnt.shape, expect)
    return vld_cnt.astype(jnp.int32)


def pad_to_blocks(x: Array, block_m: int, block_k: int) -> Array:
    m, k = x.shape[-2], x.shape[-1]
    pm, pk = (-m) % block_m, (-k) % block_k
    if pm or pk:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pk)]
        x = jnp.pad(x, pad)
    return x


def block_occupancy(spikes: Array, block_m: int = 8, block_k: int = 128) -> Array:
    """Fraction of NON-silent blocks — the sparsity actually exploitable on
    TPU (reported next to raw spike rate in the benchmarks; raw rate is what
    an FPGA exploits, block occupancy is what we exploit)."""
    flat = spikes.reshape(-1, spikes.shape[-1])
    flat = pad_to_blocks(flat, block_m, block_k)
    cnt = block_count_map_2d(flat, block_m, block_k)
    return jnp.mean((cnt > 0).astype(jnp.float32))


def event_stats(spikes: Array, block_m: int = 8, block_k: int = 128) -> dict:
    """Spike-rate + block-occupancy summary used by Table II/III benchmarks."""
    s = spikes.astype(jnp.float32)
    return {
        "spike_rate": jnp.mean(s),
        "total_spikes": jnp.sum(s),
        "block_occupancy": block_occupancy(spikes, block_m, block_k),
    }


def synaptic_ops(spikes: Array, fanout: int) -> Array:
    """Synaptic operations triggered by a spike tensor: every spike causes
    ``fanout`` accumulations downstream. This is the SOPS numerator of the
    paper's GSOPS/W metric (Table III)."""
    return jnp.sum(spikes.astype(jnp.float32)) * fanout
