"""Hybrid data-event execution metadata (paper C3 — PipeSDA / elastic FIFO,
adapted to TPU block granularity).

On the FPGA, PipeSDA turns each input spike's coordinates into per-neuron
event lists (SDU FIFOs) and each PE's FIFO tail register holds ``vld_cnt`` —
the number of valid events — so the LIF unit only runs for real events.

A TPU cannot gate single lanes, but it CAN gate whole VMEM blocks: control is
amortized per tile, so the event granularity that pays on this hardware is the
block. This module computes the *event metadata* — per-block spike counts
(``vld_cnt`` maps) — once per activation tensor (the PipeSDA analogue), and
the event-driven kernels (``repro.kernels.spike_matmul``) consume it with
``@pl.when(vld_cnt > 0)`` to skip silent blocks entirely: no MXU work, no HBM
write. The data-driven level is the Pallas grid itself (the elastic-FIFO
stream of blocks).

Event COMPRESSION lives here too: ``PackedSpikes`` is the bit-packed HBM
interchange format for spike tensors (32 spikes per int32 lane along the
last axis + the block-aligned ``vld_cnt`` map derived by popcount at pack
time). Spikes are 1-bit events; shipping them between layers as int8 — let
alone f32 — pays 8-32x the information-theoretic HBM cost, and memory
traffic is the term that decides whether spiking execution saves energy
(arXiv 2409.08290). The Pallas pack/unpack primitives are in
``repro.kernels.packed``; this module holds the container and the pure-jnp
references so ``core`` stays kernel-free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

LANE_BITS = 32                  # spikes per packed int32 word
SPIKE_FORMATS = ("dense", "packed")


class Blocks(NamedTuple):
    """The TPU tile grid every event-metadata map and kernel agrees on."""
    m: int = 128
    n: int = 128
    k: int = 128


# THE canonical block choice: kernels tile on it, PackedSpikes pads to it,
# and the occupancy/statistics helpers below measure on it — re-exported as
# ``repro.ops.DEFAULT_BLOCKS`` (the public home). Keeping a single constant
# is what makes a ``vld_cnt`` map produced by one kernel consumable by any
# other without a re-count.
DEFAULT_BLOCKS = Blocks()


def block_count_map_2d(spikes: Array, block_m: int, block_k: int) -> Array:
    """vld_cnt per (block_m x block_k) tile of a [M, K] spike matrix.

    Returns int32 [M//block_m, K//block_k]. M, K must be tile-aligned (pad
    first with ``pad_to_blocks``). This is the PipeSDA output: routing
    metadata for the event-driven matmul.

    Counts NONZERO entries — identical to the spike count for binary maps,
    and the right gating semantics when the operand is a dense (non-binary)
    activation tensor fed through the same event-skipped matmul.
    """
    m, k = spikes.shape
    assert m % block_m == 0 and k % block_k == 0, (m, k, block_m, block_k)
    x = (spikes != 0).reshape(m // block_m, block_m, k // block_k, block_k)
    return x.astype(jnp.int32).sum(axis=(1, 3))


def vld_or_compute(x: Array, vld_cnt: Array | None,
                   block_m: int, block_k: int) -> Array:
    """Metadata plumbing for the on-the-fly dataflow (paper C3 + Fig 5).

    ``x`` must already be padded to the block grid. When the previous layer's
    fused kernel emitted this tensor's ``vld_cnt`` map (fused_pe's third
    output), pass it through and the reduction pass over HBM is skipped —
    that is the PipeSDA metadata produced on the fly. Otherwise compute it
    here (one pass over ``x``).
    """
    m, k = x.shape
    expect = (m // block_m, k // block_k)
    if vld_cnt is None:
        return block_count_map_2d(x, block_m, block_k)
    assert vld_cnt.shape == expect, (vld_cnt.shape, expect)
    return vld_cnt.astype(jnp.int32)


def pad_to_blocks(x: Array, block_m: int, block_k: int) -> Array:
    m, k = x.shape[-2], x.shape[-1]
    pm, pk = (-m) % block_m, (-k) % block_k
    if pm or pk:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pk)]
        x = jnp.pad(x, pad)
    return x


def block_occupancy(spikes: Array, block_m: int = DEFAULT_BLOCKS.m,
                    block_k: int = DEFAULT_BLOCKS.k) -> Array:
    """Fraction of NON-silent blocks — the sparsity actually exploitable on
    TPU (reported next to raw spike rate in the benchmarks; raw rate is what
    an FPGA exploits, block occupancy is what we exploit).

    Defaults come from ``DEFAULT_BLOCKS`` — the SAME tile grid the kernels
    skip on — so the reported occupancy is the fraction of tiles the event
    path actually elides (earlier revisions hardcoded an 8x128 grid here
    that no kernel used, overstating exploitable sparsity)."""
    flat = spikes.reshape(-1, spikes.shape[-1])
    flat = pad_to_blocks(flat, block_m, block_k)
    cnt = block_count_map_2d(flat, block_m, block_k)
    return jnp.mean((cnt > 0).astype(jnp.float32))


def event_stats(spikes: Array, block_m: int = DEFAULT_BLOCKS.m,
                block_k: int = DEFAULT_BLOCKS.k) -> dict:
    """Spike-rate + block-occupancy summary used by Table II/III benchmarks."""
    s = spikes.astype(jnp.float32)
    return {
        "spike_rate": jnp.mean(s),
        "total_spikes": jnp.sum(s),
        "block_occupancy": block_occupancy(spikes, block_m, block_k),
    }


def synaptic_ops(spikes: Array, fanout: int) -> Array:
    """Synaptic operations triggered by a spike tensor: every spike causes
    ``fanout`` accumulations downstream. This is the SOPS numerator of the
    paper's GSOPS/W metric (Table III)."""
    return jnp.sum(spikes.astype(jnp.float32)) * fanout


# ===================================================== bit-packed spike format
#
# Layout contract (shared by the jnp references below, the Pallas kernels in
# ``repro.kernels.packed``, and the packed operand paths of spike_matmul /
# fused_pe): word j of a row covers columns [j*32, (j+1)*32) of the padded
# spike matrix, bit b (little-endian) = column j*32 + b. Both core dims are
# padded to the (block_m, block_k) grid — PackedSpikes is always
# kernel-ready — and block_k must be a multiple of 32 so VMEM tiles land on
# word boundaries.

def _word_shifts() -> Array:
    return jnp.arange(LANE_BITS, dtype=jnp.int32)


def pack_words(bits: Array) -> Array:
    """[..., K] 0/nonzero spikes -> [..., K/32] int32 words (K % 32 == 0).

    Pure bit math, safe inside Pallas kernel bodies: XLA shifts/adds are
    modular, so bit 31 wraps to INT32_MIN and the per-word sum of distinct
    powers of two is exactly the bitwise OR.
    """
    *lead, k = bits.shape
    assert k % LANE_BITS == 0, k
    b3 = (bits != 0).astype(jnp.int32).reshape(*lead, k // LANE_BITS,
                                               LANE_BITS)
    return jnp.sum(jnp.left_shift(b3, _word_shifts()), axis=-1,
                   dtype=jnp.int32)


def unpack_words(words: Array, dtype=jnp.int8) -> Array:
    """[..., W] int32 words -> [..., W*32] 0/1 spikes (inverse of
    ``pack_words``; arithmetic >> then &1 extracts every bit incl. bit 31)."""
    *lead, w = words.shape
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., None], _word_shifts()), 1)
    return bits.reshape(*lead, w * LANE_BITS).astype(dtype)


def popcount_block_map(words: Array, block_m: int, block_k: int) -> Array:
    """vld_cnt per (block_m x block_k) tile straight from packed words —
    the metadata pass reads 1/32nd of the bytes a dense re-read would."""
    *lead, m, w = words.shape
    wpb = block_k // LANE_BITS
    assert m % block_m == 0 and w % wpb == 0, (words.shape, block_m, block_k)
    pc = jax.lax.population_count(words)
    pc = pc.reshape(*lead, m // block_m, block_m, w // wpb, wpb)
    return jnp.sum(pc, axis=(-3, -1), dtype=jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedSpikes:
    """Event-compressed spike tensor: the HBM interchange type.

    words   : int32 [..., Mp, Kp/32] — bit-packed spikes, both core dims
              padded to the (block_m, block_k) grid
    vld_cnt : int32 [..., Mp/block_m, Kp/block_k] — per-block spike counts
              (PipeSDA FIFO-tail metadata), derived by popcount AT PACK TIME
              so no second pass over the tensor ever builds it
    shape   : the logical (pre-padding) shape, last two dims are (m, k)

    One object carries both the compressed payload and the routing metadata,
    so handing a layer's packed output to the next layer's kernel needs no
    recomputation of either. ~8x fewer HBM bytes than int8 spikes (32x vs
    f32), minus the tiny count map.
    """
    words: Array
    vld_cnt: Array
    shape: tuple
    block_m: int = 128
    block_k: int = 128

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        return (self.words, self.vld_cnt), (tuple(self.shape), self.block_m,
                                            self.block_k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, bm, bk = aux
        words, vld = children
        return cls(words, vld, shape, bm, bk)

    # -------------------------------------------------------------- views
    @property
    def m(self) -> int:
        return self.shape[-2]

    @property
    def k(self) -> int:
        return self.shape[-1]

    @property
    def padded_shape(self) -> tuple:
        return (*self.shape[:-2], self.words.shape[-2],
                self.words.shape[-1] * LANE_BITS)

    @property
    def packed_bytes(self) -> int:
        """HBM bytes this tensor occupies (words + metadata)."""
        return (4 * math.prod(self.words.shape)
                + 4 * math.prod(self.vld_cnt.shape))

    @property
    def dense_bytes(self) -> int:
        """HBM bytes of the int8 tensor it replaces (padded, as shipped)."""
        return math.prod(self.padded_shape)

    @property
    def compression(self) -> float:
        return self.dense_bytes / self.packed_bytes

    def __getitem__(self, idx) -> "PackedSpikes":
        """Index ONE leading (batch/time) dim; the packed core is
        preserved. Integer indices only — a slice would need the logical
        shape rewritten, which this deliberately does not support."""
        assert isinstance(idx, int), idx
        assert len(self.shape) > 2, "cannot index the packed core dims"
        return PackedSpikes(self.words[idx], self.vld_cnt[idx],
                            self.shape[1:], self.block_m, self.block_k)


def packed_from_words(words: Array, shape: tuple, *, block_m: int = 128,
                      block_k: int = 128,
                      vld_cnt: Optional[Array] = None) -> PackedSpikes:
    """Wrap an existing word tensor (e.g. im2col patches of packed maps or a
    bitwise-OR pooled map) into a kernel-ready PackedSpikes: pads rows to the
    block_m grid and derives vld_cnt by popcount over the WORDS — never the
    dense tensor — unless the producer already emitted it."""
    assert words.dtype == jnp.int32
    assert block_k % LANE_BITS == 0
    *lead, m, w = words.shape
    kp = w * LANE_BITS
    assert kp % block_k == 0, (kp, block_k)
    pm = (-m) % block_m
    if pm:
        pad = [(0, 0)] * (words.ndim - 2) + [(0, pm), (0, 0)]
        words = jnp.pad(words, pad)
    if vld_cnt is None:
        vld_cnt = popcount_block_map(words, block_m, block_k)
    return PackedSpikes(words, vld_cnt, tuple(shape), block_m, block_k)


def pack_spikes_ref(x: Array, *, block_m: int = 128,
                    block_k: int = 128) -> PackedSpikes:
    """Pure-jnp reference pack: pad -> pack_words -> popcount vld. The
    Pallas version (``repro.kernels.packed``) does all three in one grid
    pass; this is its oracle and the portable fallback."""
    assert block_k % LANE_BITS == 0
    xp = pad_to_blocks(x, block_m, block_k)
    words = pack_words(xp)
    vld = popcount_block_map(words, block_m, block_k)
    return PackedSpikes(words, vld, tuple(x.shape), block_m, block_k)


def unpack_spikes_ref(ps: PackedSpikes, dtype=jnp.int8) -> Array:
    """Pure-jnp reference unpack back to the LOGICAL (unpadded) dense map."""
    dense = unpack_words(ps.words, dtype)
    sl = tuple(slice(0, d) for d in ps.shape[-2:])
    return dense[(..., *sl)]
