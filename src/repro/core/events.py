"""Hybrid data-event execution metadata (paper C3 — PipeSDA / elastic FIFO,
adapted to TPU block granularity).

On the FPGA, PipeSDA turns each input spike's coordinates into per-neuron
event lists (SDU FIFOs) and each PE's FIFO tail register holds ``vld_cnt`` —
the number of valid events — so the LIF unit only runs for real events.

A TPU cannot gate single lanes, but it CAN gate whole VMEM blocks: control is
amortized per tile, so the event granularity that pays on this hardware is the
block. This module computes the *event metadata* — per-block spike counts
(``vld_cnt`` maps) — once per activation tensor (the PipeSDA analogue), and
the event-driven kernels (``repro.kernels.spike_matmul``) consume it with
``@pl.when(vld_cnt > 0)`` to skip silent blocks entirely: no MXU work, no HBM
write. The data-driven level is the Pallas grid itself (the elastic-FIFO
stream of blocks).

Event COMPRESSION lives here too: ``PackedSpikes`` is the bit-packed HBM
interchange format for spike tensors (32 spikes per int32 lane along the
last axis + the block-aligned ``vld_cnt`` map derived by popcount at pack
time). Spikes are 1-bit events; shipping them between layers as int8 — let
alone f32 — pays 8-32x the information-theoretic HBM cost, and memory
traffic is the term that decides whether spiking execution saves energy
(arXiv 2409.08290). The Pallas pack/unpack primitives are in
``repro.kernels.packed``; this module holds the container and the pure-jnp
references so ``core`` stays kernel-free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

LANE_BITS = 32                  # spikes per packed int32 word
SPIKE_FORMATS = ("dense", "packed")


class Blocks(NamedTuple):
    """The TPU tile grid every event-metadata map and kernel agrees on."""
    m: int = 128
    n: int = 128
    k: int = 128


# THE canonical block choice: kernels tile on it, PackedSpikes pads to it,
# and the occupancy/statistics helpers below measure on it — re-exported as
# ``repro.ops.DEFAULT_BLOCKS`` (the public home). Keeping a single constant
# is what makes a ``vld_cnt`` map produced by one kernel consumable by any
# other without a re-count.
DEFAULT_BLOCKS = Blocks()


def block_count_map_2d(spikes: Array, block_m: int, block_k: int) -> Array:
    """vld_cnt per (block_m x block_k) tile of a [M, K] spike matrix.

    Returns int32 [M//block_m, K//block_k]. M, K must be tile-aligned (pad
    first with ``pad_to_blocks``). This is the PipeSDA output: routing
    metadata for the event-driven matmul.

    Counts NONZERO entries — identical to the spike count for binary maps,
    and the right gating semantics when the operand is a dense (non-binary)
    activation tensor fed through the same event-skipped matmul.
    """
    m, k = spikes.shape
    assert m % block_m == 0 and k % block_k == 0, (m, k, block_m, block_k)
    x = (spikes != 0).reshape(m // block_m, block_m, k // block_k, block_k)
    return x.astype(jnp.int32).sum(axis=(1, 3))


def vld_or_compute(x: Array, vld_cnt: Array | None,
                   block_m: int, block_k: int) -> Array:
    """Metadata plumbing for the on-the-fly dataflow (paper C3 + Fig 5).

    ``x`` must already be padded to the block grid. When the previous layer's
    fused kernel emitted this tensor's ``vld_cnt`` map (fused_pe's third
    output), pass it through and the reduction pass over HBM is skipped —
    that is the PipeSDA metadata produced on the fly. Otherwise compute it
    here (one pass over ``x``).
    """
    m, k = x.shape
    expect = (m // block_m, k // block_k)
    if vld_cnt is None:
        return block_count_map_2d(x, block_m, block_k)
    if tuple(vld_cnt.shape) != expect:
        raise ValueError(
            f"vld_cnt grid {tuple(vld_cnt.shape)} does not match the "
            f"[{m}, {k}] operand tiled on (block_m={block_m}, "
            f"block_k={block_k}) — expected {expect}. A chained vld map "
            f"must come from a producer using the SAME block sizes.")
    return vld_cnt.astype(jnp.int32)


def compact_kmap(vld_cnt: Array) -> tuple[Array, Array]:
    """CSR-of-blocks routing for vld-gated tile streaming.

    ``vld_cnt``: int32 [Gm, Gk] per-block event counts. Returns

      nact [Gm] int32    — number of NON-silent k-blocks in each m-row
      kmap [Gm, Gk] int32 — for each m-row, the active k-block indices
                            compacted (ascending) to the front; tail
                            entries REPEAT the last active index.

    The gated kernels iterate step ``s`` over ``kmap[i, s]`` and gate
    compute on ``s < nact[i]``. Because Pallas only issues a DMA when a
    BlockSpec index map's result CHANGES between consecutive grid steps,
    the repeated tail index means silent blocks' weight tiles and spike
    words are never fetched from HBM — the byte-level counterpart of the
    ``@pl.when(vld_cnt > 0)`` FLOP skip. A fully-silent row maps to block 0
    (one inert fetch, compute still skipped).
    """
    gm, gk = vld_cnt.shape
    active = vld_cnt > 0
    nact = jnp.sum(active, axis=1, dtype=jnp.int32)
    # stable argsort of (inactive-last) compacts active indices, ascending
    kmap = jnp.argsort(jnp.logical_not(active), axis=1).astype(jnp.int32)
    last = jnp.take_along_axis(kmap, jnp.maximum(nact - 1, 0)[:, None],
                               axis=1)
    s_idx = jnp.arange(gk, dtype=jnp.int32)[None, :]
    kmap = jnp.where(s_idx < nact[:, None], kmap, last)
    return nact, kmap.astype(jnp.int32)


def pad_to_blocks(x: Array, block_m: int, block_k: int) -> Array:
    m, k = x.shape[-2], x.shape[-1]
    pm, pk = (-m) % block_m, (-k) % block_k
    if pm or pk:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pk)]
        x = jnp.pad(x, pad)
    return x


def block_occupancy(spikes: Array, block_m: int = DEFAULT_BLOCKS.m,
                    block_k: int = DEFAULT_BLOCKS.k) -> Array:
    """Fraction of NON-silent blocks — the sparsity actually exploitable on
    TPU (reported next to raw spike rate in the benchmarks; raw rate is what
    an FPGA exploits, block occupancy is what we exploit).

    Defaults come from ``DEFAULT_BLOCKS`` — the SAME tile grid the kernels
    skip on — so the reported occupancy is the fraction of tiles the event
    path actually elides (earlier revisions hardcoded an 8x128 grid here
    that no kernel used, overstating exploitable sparsity)."""
    flat = spikes.reshape(-1, spikes.shape[-1])
    flat = pad_to_blocks(flat, block_m, block_k)
    cnt = block_count_map_2d(flat, block_m, block_k)
    # occupancy metric, never differentiated  # neurallint: disable=NL-BARE-HEAVISIDE
    return jnp.mean((cnt > 0).astype(jnp.float32))


def event_stats(spikes: Array, block_m: int = DEFAULT_BLOCKS.m,
                block_k: int = DEFAULT_BLOCKS.k) -> dict:
    """Spike-rate + block-occupancy summary used by Table II/III benchmarks."""
    s = spikes.astype(jnp.float32)
    return {
        "spike_rate": jnp.mean(s),
        "total_spikes": jnp.sum(s),
        "block_occupancy": block_occupancy(spikes, block_m, block_k),
    }


def synaptic_ops(spikes: Array, fanout: int) -> Array:
    """Synaptic operations triggered by a spike tensor: every spike causes
    ``fanout`` accumulations downstream. This is the SOPS numerator of the
    paper's GSOPS/W metric (Table III)."""
    return jnp.sum(spikes.astype(jnp.float32)) * fanout


# ===================================================== bit-packed spike format
#
# Layout contract (shared by the jnp references below, the Pallas kernels in
# ``repro.kernels.packed``, and the packed operand paths of spike_matmul /
# fused_pe): word j of a row covers columns [j*32, (j+1)*32) of the padded
# spike matrix, bit b (little-endian) = column j*32 + b. Both core dims are
# padded to the (block_m, block_k) grid — PackedSpikes is always
# kernel-ready — and block_k must be a multiple of 32 so VMEM tiles land on
# word boundaries.

def _word_shifts() -> Array:
    return jnp.arange(LANE_BITS, dtype=jnp.int32)


def pack_words(bits: Array) -> Array:
    """[..., K] 0/nonzero spikes -> [..., K/32] int32 words (K % 32 == 0).

    Pure bit math, safe inside Pallas kernel bodies: XLA shifts/adds are
    modular, so bit 31 wraps to INT32_MIN and the per-word sum of distinct
    powers of two is exactly the bitwise OR.
    """
    *lead, k = bits.shape
    assert k % LANE_BITS == 0, k
    b3 = (bits != 0).astype(jnp.int32).reshape(*lead, k // LANE_BITS,
                                               LANE_BITS)
    return jnp.sum(jnp.left_shift(b3, _word_shifts()), axis=-1,
                   dtype=jnp.int32)


def unpack_words(words: Array, dtype=jnp.int8) -> Array:
    """[..., W] int32 words -> [..., W*32] 0/1 spikes (inverse of
    ``pack_words``; arithmetic >> then &1 extracts every bit incl. bit 31)."""
    *lead, w = words.shape
    bits = jnp.bitwise_and(
        jnp.right_shift(words[..., None], _word_shifts()), 1)
    return bits.reshape(*lead, w * LANE_BITS).astype(dtype)


def head_lane_masks(n_heads: int, head_dim: int, total_cols: int) -> Array:
    """Per-head word masks for head-blocked popcount row sums.

    Returns int32 ``[n_heads, total_cols // 32]``: bit ``b`` of word ``w``
    in row ``h`` is set iff packed column ``w*32 + b`` belongs to head
    ``h`` (column ``// head_dim == h``). ANDing a packed spike row with
    row ``h`` and popcounting gives that head's spike row sum — the
    packed-format form of the Fig-5 per-head Row Summation. Columns at or
    beyond ``n_heads * head_dim`` (lane padding) belong to no head.

    Shapes are static, so inside a kernel body this folds to a constant.
    """
    assert total_cols % LANE_BITS == 0, total_cols
    assert n_heads * head_dim <= total_cols, (n_heads, head_dim, total_cols)
    cols = jnp.arange(total_cols, dtype=jnp.int32)
    sel = (cols[None, :] // head_dim
           == jnp.arange(n_heads, dtype=jnp.int32)[:, None])
    return pack_words(sel.astype(jnp.int32))


def popcount_block_map(words: Array, block_m: int, block_k: int) -> Array:
    """vld_cnt per (block_m x block_k) tile straight from packed words —
    the metadata pass reads 1/32nd of the bytes a dense re-read would."""
    *lead, m, w = words.shape
    wpb = block_k // LANE_BITS
    assert m % block_m == 0 and w % wpb == 0, (words.shape, block_m, block_k)
    pc = jax.lax.population_count(words)
    pc = pc.reshape(*lead, m // block_m, block_m, w // wpb, wpb)
    return jnp.sum(pc, axis=(-3, -1), dtype=jnp.int32)


def word_occupancy_map(words: Array, block_m: int, block_k: int) -> Array:
    """Second-level event metadata: per-block WORD-COLUMN occupancy bitmap.

    For each (block_m x block_k) tile, bit ``c`` of the returned int32 is set
    iff word-column ``c`` of the tile — dense columns
    [c*32, (c+1)*32) — holds ANY nonzero word across the tile's rows.
    Returns int32 [..., Mp/block_m, Kp/block_k]. This is the irregular-
    sparsity level beyond ``vld_cnt`` (ExSpike): the MXU feed iterates the
    tile's 32-column stripes and skips the silent ones. Requires
    block_k <= 1024 so the per-tile word count fits the 32 bits (bit 31
    wraps to the sign bit, same modular arithmetic as ``pack_words``).
    """
    *lead, m, w = words.shape
    wpb = block_k // LANE_BITS
    assert wpb <= LANE_BITS, (block_k, "word bitmap needs block_k <= 1024")
    assert m % block_m == 0 and w % wpb == 0, (words.shape, block_m, block_k)
    nz = (words != 0).reshape(*lead, m // block_m, block_m, w // wpb, wpb)
    col = jnp.any(nz, axis=-3).astype(jnp.int32)         # [..., Gm, Gk, wpb]
    shifts = jnp.arange(wpb, dtype=jnp.int32)
    return jnp.sum(jnp.left_shift(col, shifts), axis=-1, dtype=jnp.int32)


def word_occupancy_map_dense(x: Array, block_m: int, block_k: int) -> Array:
    """``word_occupancy_map`` computed straight from a dense [..., Mp, Kp]
    operand (no packing required): columns are grouped into 32-wide stripes
    and a stripe counts as occupied when any entry is nonzero."""
    *lead, m, k = x.shape
    wpb = block_k // LANE_BITS
    assert wpb <= LANE_BITS, (block_k, "word bitmap needs block_k <= 1024")
    assert m % block_m == 0 and k % block_k == 0, (x.shape, block_m, block_k)
    nz = (x != 0).reshape(*lead, m // block_m, block_m,
                          k // block_k, wpb, LANE_BITS)
    col = jnp.any(nz, axis=(-4, -1)).astype(jnp.int32)   # [..., Gm, Gk, wpb]
    shifts = jnp.arange(wpb, dtype=jnp.int32)
    return jnp.sum(jnp.left_shift(col, shifts), axis=-1, dtype=jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedSpikes:
    """Event-compressed spike tensor: the HBM interchange type.

    words   : int32 [..., Mp, Kp/32] — bit-packed spikes, both core dims
              padded to the (block_m, block_k) grid
    vld_cnt : int32 [..., Mp/block_m, Kp/block_k] — per-block spike counts
              (PipeSDA FIFO-tail metadata), derived by popcount AT PACK TIME
              so no second pass over the tensor ever builds it
    shape   : the logical (pre-padding) shape, last two dims are (m, k)

    One object carries both the compressed payload and the routing metadata,
    so handing a layer's packed output to the next layer's kernel needs no
    recomputation of either. ~8x fewer HBM bytes than int8 spikes (32x vs
    f32), minus the tiny count map.

    ``occ`` is the OPTIONAL second compression level (ExSpike's irregular
    sparsity): the per-block word-column occupancy bitmap from
    ``word_occupancy_map``, emitted in the same pack pass as ``vld_cnt``.
    Kernels running ``skip="two_level"`` use it to elide silent 32-column
    stripes inside otherwise-active blocks. ``None`` means "not computed";
    consumers fall back to computing it on demand.
    """
    words: Array
    vld_cnt: Array
    shape: tuple
    block_m: int = 128
    block_k: int = 128
    occ: Optional[Array] = None

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        return (self.words, self.vld_cnt, self.occ), (
            tuple(self.shape), self.block_m, self.block_k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, bm, bk = aux
        words, vld, occ = children
        return cls(words, vld, shape, bm, bk, occ)

    def with_occ(self) -> "PackedSpikes":
        """Return self with the word-occupancy bitmap populated (no-op when
        the pack pass already emitted it)."""
        if self.occ is not None:
            return self
        occ = word_occupancy_map(self.words, self.block_m, self.block_k)
        return PackedSpikes(self.words, self.vld_cnt, self.shape,
                            self.block_m, self.block_k, occ)

    # -------------------------------------------------------------- views
    @property
    def m(self) -> int:
        return self.shape[-2]

    @property
    def k(self) -> int:
        return self.shape[-1]

    @property
    def padded_shape(self) -> tuple:
        return (*self.shape[:-2], self.words.shape[-2],
                self.words.shape[-1] * LANE_BITS)

    @property
    def packed_bytes(self) -> int:
        """HBM bytes this tensor occupies (words + metadata)."""
        n = (4 * math.prod(self.words.shape)
             + 4 * math.prod(self.vld_cnt.shape))
        if self.occ is not None:
            n += 4 * math.prod(self.occ.shape)
        return n

    def two_level_bytes(self) -> int:
        """HBM bytes under two-level compression: only OCCUPIED word-columns
        of each block ship (a consumer honouring ``occ`` never reads the
        silent stripes), plus both metadata maps. Concrete-value helper for
        the byte model — forces the arrays to host."""
        import numpy as np
        ps = self.with_occ()
        wpb = ps.block_k // LANE_BITS
        occ = np.asarray(ps.occ).astype(np.uint32)
        occupied_cols = sum(int(((occ >> c) & 1).sum())
                            for c in range(wpb))
        word_bytes = 4 * occupied_cols * ps.block_m
        meta = (4 * math.prod(ps.vld_cnt.shape)
                + 4 * math.prod(ps.occ.shape))
        return word_bytes + meta

    @property
    def dense_bytes(self) -> int:
        """HBM bytes of the int8 tensor it replaces (padded, as shipped)."""
        return math.prod(self.padded_shape)

    @property
    def compression(self) -> float:
        return self.dense_bytes / self.packed_bytes

    def __getitem__(self, idx) -> "PackedSpikes":
        """Index ONE leading (batch/time) dim; the packed core is
        preserved. Integer indices only — a slice would need the logical
        shape rewritten, which this deliberately does not support."""
        assert isinstance(idx, int), idx
        assert len(self.shape) > 2, "cannot index the packed core dims"
        occ = None if self.occ is None else self.occ[idx]
        return PackedSpikes(self.words[idx], self.vld_cnt[idx],
                            self.shape[1:], self.block_m, self.block_k, occ)


def packed_from_words(words: Array, shape: tuple, *, block_m: int = 128,
                      block_k: int = 128,
                      vld_cnt: Optional[Array] = None,
                      occ: Optional[Array] = None,
                      with_occ: bool = False) -> PackedSpikes:
    """Wrap an existing word tensor (e.g. im2col patches of packed maps or a
    bitwise-OR pooled map) into a kernel-ready PackedSpikes: pads rows to the
    block_m grid and derives vld_cnt by popcount over the WORDS — never the
    dense tensor — unless the producer already emitted it. Pass
    ``with_occ=True`` to also emit the word-occupancy bitmap."""
    assert words.dtype == jnp.int32
    assert block_k % LANE_BITS == 0
    *lead, m, w = words.shape
    kp = w * LANE_BITS
    assert kp % block_k == 0, (kp, block_k)
    pm = (-m) % block_m
    if pm:
        pad = [(0, 0)] * (words.ndim - 2) + [(0, pm), (0, 0)]
        words = jnp.pad(words, pad)
    if vld_cnt is None:
        vld_cnt = popcount_block_map(words, block_m, block_k)
    if occ is None and with_occ:
        occ = word_occupancy_map(words, block_m, block_k)
    return PackedSpikes(words, vld_cnt, tuple(shape), block_m, block_k, occ)


def pack_spikes_ref(x: Array, *, block_m: int = 128,
                    block_k: int = 128,
                    with_occ: bool = False) -> PackedSpikes:
    """Pure-jnp reference pack: pad -> pack_words -> popcount vld (+ the
    word-occupancy bitmap when ``with_occ``). The Pallas version
    (``repro.kernels.packed``) does all of it in one grid pass; this is its
    oracle and the portable fallback."""
    assert block_k % LANE_BITS == 0
    xp = pad_to_blocks(x, block_m, block_k)
    words = pack_words(xp)
    vld = popcount_block_map(words, block_m, block_k)
    occ = word_occupancy_map(words, block_m, block_k) if with_occ else None
    return PackedSpikes(words, vld, tuple(x.shape), block_m, block_k, occ)


def unpack_spikes_ref(ps: PackedSpikes, dtype=jnp.int8) -> Array:
    """Pure-jnp reference unpack back to the LOGICAL (unpadded) dense map."""
    dense = unpack_words(ps.words, dtype)
    sl = tuple(slice(0, d) for d in ps.shape[-2:])
    return dense[(..., *sl)]


# ===================================================== packed-word invariants
#
# A well-formed packed spike tensor satisfies invariants that a corrupted
# one (bit-flipped word, torn write, stale metadata) almost always breaks:
# pad-lane bits — columns beyond the logical k and rows beyond the logical
# m — are zero by construction of the pack pass, and the vld_cnt / occ
# metadata maps agree with a popcount re-derivation from the words. The
# serving engine's per-tick integrity guard checks the cheap pad-lane
# invariant on cached spike-state pools; ``check_packed_invariants`` is the
# full (host-side) audit used by tests and the fault-injection harness.

def pad_lane_mask(k: int, n_words: int) -> "np.ndarray":
    """int32 mask per packed word with 1-bits at every PAD-lane position
    (logical columns >= ``k``). A packed row over ``n_words`` int32 words is
    pad-clean iff ``(words & mask) == 0`` everywhere."""
    import numpy as np

    mask = np.zeros(n_words, np.uint32)
    for j in range(n_words):
        nbits = min(max(k - j * LANE_BITS, 0), LANE_BITS)
        valid = np.uint32(0xFFFFFFFF) if nbits == LANE_BITS else \
            np.uint32((1 << nbits) - 1)
        mask[j] = ~valid & np.uint32(0xFFFFFFFF)
    return mask.view(np.int32)


def check_packed_invariants(ps: PackedSpikes) -> dict:
    """Audit one PackedSpikes against its structural invariants. Returns a
    host-side dict: ``ok`` plus per-invariant violation counts —

      pad_cols     words with nonzero bits in column pad lanes (>= k)
      pad_rows     nonzero words in row-pad rows (>= m)
      vld_mismatch blocks whose stored vld_cnt != popcount of their words
      occ_mismatch blocks whose stored occ bitmap != the re-derived one
                   (0 when ``occ`` is None — absent metadata is legal)

    Forces the arrays to host; this is the audit path (tests, quarantine
    forensics), not the per-tick guard."""
    import numpy as np

    words = np.asarray(ps.words)
    flat = words.reshape(-1, words.shape[-2], words.shape[-1])
    mask = pad_lane_mask(ps.k, words.shape[-1])
    pad_cols = int(((flat & mask) != 0).sum())
    m = ps.m
    pad_rows = int((flat[:, m:, :] != 0).sum()) if m < flat.shape[1] else 0
    vld_ref = np.asarray(popcount_block_map(
        jnp.asarray(words), ps.block_m, ps.block_k))
    vld_mismatch = int((vld_ref != np.asarray(ps.vld_cnt)).sum())
    occ_mismatch = 0
    if ps.occ is not None:
        occ_ref = np.asarray(word_occupancy_map(
            jnp.asarray(words), ps.block_m, ps.block_k))
        occ_mismatch = int((occ_ref != np.asarray(ps.occ)).sum())
    return {
        "ok": not (pad_cols or pad_rows or vld_mismatch or occ_mismatch),
        "pad_cols": pad_cols,
        "pad_rows": pad_rows,
        "vld_mismatch": vld_mismatch,
        "occ_mismatch": occ_mismatch,
    }
