"""NEURAL's contributions as composable JAX modules.

C1  kd.py / quant.py  — KD + fixed-point/FP8 QAT for single-timestep SNNs
C2  w2ttfs.py         — window-to-time-to-first-spike pooling replacement
C3  lif.py / events.py / surrogate.py — hybrid data-event spiking execution
C4  qk_attention.py   — on-the-fly spiking QKFormer attention
"""
from .surrogate import spike, available_surrogates
from .lif import LIFConfig, lif_forward, lif_multistep, lif_single_step, spike_rate, total_spikes
from .w2ttfs import (window_counts, w2ttfs_expand, w2ttfs_reference,
                     w2ttfs_classifier, w2ttfs_time_reuse, avgpool_classifier)
from .qk_attention import (qk_token_mask, qk_channel_mask, qk_token_attention,
                           qk_channel_attention, spiking_self_attention)
from .kd import KDConfig, kd_loss, sequence_kd_loss, kl_divergence, softmax_cross_entropy, make_distill_loss_fn
from .quant import QuantConfig, fake_quant, quantize_fixed, quantize_fp8, fuse_bn_into_conv, fuse_bn_into_linear, quantize_tree
from .events import (block_count_map_2d, pad_to_blocks, block_occupancy,
                     event_stats, synaptic_ops)
