"""Surrogate-gradient spike functions (paper §III.B: surrogate-gradient training).

Forward is the exact Heaviside step H(v - v_th); backward substitutes a smooth
pseudo-derivative so single-timestep SNNs train with plain backprop — the
enabler for the paper's KD framework (C1).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

_SURROGATES: dict[str, Callable[[Array, float], Array]] = {}


def _register(name: str):
    def deco(fn):
        _SURROGATES[name] = fn
        return fn
    return deco


@_register("atan")
def _atan_grad(v: Array, alpha: float) -> Array:
    # SpikingJelly default: d/dv [ 1/pi * atan(pi/2 * alpha * v) + 1/2 ]
    return alpha / (2.0 * (1.0 + (math.pi / 2.0 * alpha * v) ** 2))


@_register("sigmoid")
def _sigmoid_grad(v: Array, alpha: float) -> Array:
    s = jax.nn.sigmoid(alpha * v)
    return alpha * s * (1.0 - s)


@_register("triangle")
def _triangle_grad(v: Array, alpha: float) -> Array:
    # Esser et al. piecewise-linear window; support |v| < 1/alpha
    return jnp.maximum(0.0, alpha - alpha * alpha * jnp.abs(v)) / alpha * alpha


@_register("rect")
def _rect_grad(v: Array, alpha: float) -> Array:
    return jnp.where(jnp.abs(v) < 0.5 / alpha, alpha, 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def spike(v_minus_vth: Array, surrogate: str = "atan", alpha: float = 2.0) -> Array:
    """Heaviside spike with surrogate gradient. Output is {0,1} in v's dtype."""
    # the primitive the rule points everyone at  # neurallint: disable=NL-BARE-HEAVISIDE
    return (v_minus_vth >= 0).astype(v_minus_vth.dtype)


def _spike_fwd(v, surrogate, alpha):
    return spike(v, surrogate, alpha), v


def _spike_bwd(surrogate, alpha, v, g):
    grad_fn = _SURROGATES[surrogate]
    return (g * grad_fn(v, alpha).astype(g.dtype),)


spike.defvjp(_spike_fwd, _spike_bwd)


def available_surrogates() -> tuple[str, ...]:
    return tuple(_SURROGATES)


def surrogate_grad(v: Array, surrogate: str, alpha: float) -> Array:
    """The registered pseudo-derivative evaluated at membrane offset ``v``
    (= v_mem - v_th). Pure jnp — safe inside Pallas kernel bodies, which is
    how the backward kernels fuse the factor into the ``g @ wᵀ`` sweep."""
    return _SURROGATES[surrogate](v, alpha)
