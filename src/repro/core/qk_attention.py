"""Spiking QKFormer Q-K attention (paper C4, Fig 5 "on-the-fly" dataflow).

QKFormer's Q-K *token* attention (QKTA, ref [8]) on binary spikes:

    Q, K in {0,1}^[B, N, D]
    t_i  = sum_d Q[i, d]                  (Row Summation along the Q path)
    A_i  = spike(t_i - theta)             (token activation mask, {0,1}^N)
    X'   = A (broadcast) * K              (QK token mask applied to K)

and the *channel* variant (QKCA): c_d = sum_i Q[i, d], mask over channels.

NEURAL's hardware realization replaces the threshold on the row sum with a
bitwise OR across channels (mask = any spike in the row) and fuses the whole
thing into the PE->spike-buffer write-back path: no score matrix, no dedicated
attention unit, O(N*D) work and a single pass over K. Both mask modes are
implemented; ``mode="or"`` is what the atten_reg in Fig 5 computes.

These are pure functions; the QKFormer *block* (Linear+BN+LIF plumbing,
residuals, paper Fig 2(a)) lives with the models, and the fused Pallas kernel
in ``repro.kernels.qk_attention`` implements the same contract for the
write-back path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .surrogate import spike

Array = jax.Array


def qk_token_mask(q_spikes: Array, mode: str = "threshold",
                  threshold: float = 1.0, surrogate: str = "atan",
                  alpha: float = 2.0) -> Array:
    """Per-token activation mask from Q spikes.

    q_spikes: [..., N, D] binary. Returns [..., N, 1] binary mask.
      mode="threshold": spike(sum_d Q - threshold)   (QKFormer, trainable path)
      mode="or":        1[sum_d Q > 0]               (NEURAL atten_reg, Fig 5 (2))
    """
    rowsum = q_spikes.sum(axis=-1, keepdims=True)
    if mode == "or":
        # hardware atten_reg: deliberately no gradient into Q
        return (rowsum > 0).astype(q_spikes.dtype)  # neurallint: disable=NL-BARE-HEAVISIDE
    return spike(rowsum - threshold, surrogate, alpha)


def qk_channel_mask(q_spikes: Array, mode: str = "threshold",
                    threshold: float = 1.0, surrogate: str = "atan",
                    alpha: float = 2.0) -> Array:
    """Per-channel activation mask. q_spikes: [..., N, D] -> [..., 1, D]."""
    colsum = q_spikes.sum(axis=-2, keepdims=True)
    if mode == "or":
        # hardware atten_reg: deliberately no gradient into Q
        return (colsum > 0).astype(q_spikes.dtype)  # neurallint: disable=NL-BARE-HEAVISIDE
    return spike(colsum - threshold, surrogate, alpha)


def qk_token_attention(q_spikes: Array, k_spikes: Array, mode: str = "threshold",
                       threshold: float = 1.0, surrogate: str = "atan",
                       alpha: float = 2.0) -> Array:
    """QKTA: mask K rows by the Q token mask. Shapes [..., N, D] -> [..., N, D].

    Note the mask for row i depends only on row i of Q — this is what makes
    the paper's "on-the-fly" fusion (and O(1)-state autoregressive decode)
    possible: each token's output is computable the moment its Q/K rows are.
    """
    a = qk_token_mask(q_spikes, mode, threshold, surrogate, alpha)
    return a * k_spikes


def qk_grouped_token_attention(q_spikes: Array, k_spikes: Array,
                               mode: str = "threshold",
                               threshold: float = 1.0, surrogate: str = "atan",
                               alpha: float = 2.0) -> Array:
    """Grouped-KV QKTA: per-QUERY-head token masks gate grouped KV heads.

    q_spikes: [..., N, H, Dh], k_spikes: [..., N, Hkv, Dh] with H a
    multiple of Hkv. Query head ``qh`` reads kv head ``qh // (H//Hkv)``
    (``jnp.repeat`` order). Returns [..., N, H, Dh] — the masked,
    group-EXPANDED K — without ever materializing a replicated
    [..., N, H, Dh] copy of K in HBM before masking: the expansion happens
    inside the broadcast multiply, fused by XLA.
    """
    h, hkv = q_spikes.shape[-2], k_spikes.shape[-2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    a = qk_token_mask(q_spikes, mode, threshold, surrogate, alpha)
    lead, n, dh = q_spikes.shape[:-3], q_spikes.shape[-3], q_spikes.shape[-1]
    a = a.reshape(*lead, n, hkv, g, 1)
    out = a * k_spikes[..., :, :, None, :]
    return out.reshape(*lead, n, h, dh)


def qk_channel_attention(q_spikes: Array, k_spikes: Array, mode: str = "threshold",
                         threshold: float = 1.0, surrogate: str = "atan",
                         alpha: float = 2.0) -> Array:
    c = qk_channel_mask(q_spikes, mode, threshold, surrogate, alpha)
    return c * k_spikes


def spiking_self_attention(q: Array, k: Array, v: Array, scale: float = 0.125,
                           causal: bool = False) -> Array:
    """Spikformer-style SSA (used by QKFormer's final stage, ref [8]):
    out = (Q K^T) V * scale with binary Q/K/V and NO softmax.

    Because there is no softmax, for the non-causal case we associate as
    Q (K^T V): O(N*D^2) instead of O(N^2*D) — the linear-attention identity
    the binary formulation buys. The causal case uses a cumulative K^T V
    prefix state (chunked), the basis of O(1)-state spiking LM decode.
    """
    if not causal:
        kv = jnp.einsum("...nd,...ne->...de", k, v)
        return jnp.einsum("...nd,...de->...ne", q, kv) * scale
    # causal: prefix-sum of per-token outer products, chunked to bound memory
    n = q.shape[-2]
    chunk = min(128, n)
    pad = (-n) % chunk
    if pad:
        qp = jnp.pad(q, [(0, 0)] * (q.ndim - 2) + [(0, pad), (0, 0)])
        kp = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)])
        vp = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
    else:
        qp, kp, vp = q, k, v
    nc = qp.shape[-2] // chunk
    qc = qp.reshape(*qp.shape[:-2], nc, chunk, qp.shape[-1])
    kc = kp.reshape(*kp.shape[:-2], nc, chunk, kp.shape[-1])
    vc = vp.reshape(*vp.shape[:-2], nc, chunk, vp.shape[-1])
    # within-chunk causal part
    scores = jnp.einsum("...cnd,...cmd->...cnm", qc, kc)
    causal_mask = jnp.tril(jnp.ones((chunk, chunk), q.dtype))
    intra = jnp.einsum("...cnm,...cme->...cne", scores * causal_mask, vc)
    # inter-chunk: cumulative K^T V of all previous chunks
    kv_chunks = jnp.einsum("...cnd,...cne->...cde", kc, vc)
    kv_prefix = jnp.cumsum(kv_chunks, axis=-3) - kv_chunks  # exclusive
    inter = jnp.einsum("...cnd,...cde->...cne", qc, kv_prefix)
    out = (intra + inter).reshape(*qp.shape[:-2], qp.shape[-2], vp.shape[-1])
    if pad:
        out = out[..., :n, :]
    return out * scale
