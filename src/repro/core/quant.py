"""Fixed-point / FP8 quantization + QAT (paper C1: "operator fusion and
fixed-point quantization ... KD-based quantization-aware training").

Fake-quant with straight-through estimator (STE): forward uses the quantized
value, backward passes gradients unchanged. Supports
  * symmetric fixed-point intN (per-tensor or per-channel scales) — the
    paper's FPGA deployment format,
  * fp8 (e4m3 / e5m2) — the precision row reported in paper Table III,
and BN→conv operator fusion (paper Fig 2(b) "F&Q" stage).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    enabled: bool = False
    mode: str = "int"          # "int" | "fp8_e4m3" | "fp8_e5m2"
    bits: int = 8              # for "int" mode
    per_channel: bool = True   # per-output-channel scale on weights
    quantize_activations: bool = False
    act_bits: int = 8


def _ste(x: Array, xq: Array) -> Array:
    """Straight-through estimator: forward xq, backward identity."""
    return x + jax.lax.stop_gradient(xq - x)


def quantize_fixed(x: Array, bits: int = 8, axis: Optional[int] = None) -> Array:
    """Symmetric fixed-point fake-quant. ``axis`` = per-channel scale axis."""
    qmax = 2.0 ** (bits - 1) - 1.0
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=tuple(i for i in range(x.ndim) if i != axis),
                       keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return _ste(x, q * scale)


def quantize_fp8(x: Array, variant: str = "e4m3") -> Array:
    dt = jnp.float8_e4m3fn if variant == "e4m3" else jnp.float8_e5m2
    xq = x.astype(dt).astype(x.dtype)
    return _ste(x, xq)


def fake_quant(x: Array, cfg: QuantConfig, *, is_weight: bool = True) -> Array:
    """Apply the configured fake-quant. No-op when disabled."""
    if not cfg.enabled:
        return x
    if not is_weight and not cfg.quantize_activations:
        return x
    if cfg.mode == "int":
        bits = cfg.bits if is_weight else cfg.act_bits
        axis = 0 if (is_weight and cfg.per_channel and x.ndim >= 2) else None
        return quantize_fixed(x, bits, axis)
    if cfg.mode.startswith("fp8"):
        return quantize_fp8(x, cfg.mode.split("_")[1])
    raise ValueError(f"unknown quant mode {cfg.mode!r}")


def fuse_bn_into_conv(w: Array, b: Optional[Array], bn_gamma: Array,
                      bn_beta: Array, bn_mean: Array, bn_var: Array,
                      eps: float = 1e-5) -> tuple[Array, Array]:
    """Operator fusion (paper Fig 2(b)): fold BN statistics into conv weights.

    ``w`` has output channels on the LAST axis (HWIO, matching
    lax.conv_general_dilated with dimension_numbers NHWC/HWIO).
    """
    inv_std = bn_gamma / jnp.sqrt(bn_var + eps)
    w_fused = w * inv_std  # broadcasts over trailing (output-channel) axis
    b0 = b if b is not None else jnp.zeros_like(bn_mean)
    b_fused = (b0 - bn_mean) * inv_std + bn_beta
    return w_fused, b_fused


def fuse_bn_into_linear(w: Array, b: Optional[Array], bn_gamma: Array,
                        bn_beta: Array, bn_mean: Array, bn_var: Array,
                        eps: float = 1e-5) -> tuple[Array, Array]:
    """Fold a BN that FOLLOWS a linear layer: y = gamma*(xW+b-mean)/std + beta."""
    inv_std = bn_gamma / jnp.sqrt(bn_var + eps)
    w_fused = w * inv_std[None, :]
    b0 = b if b is not None else jnp.zeros_like(bn_mean)
    b_fused = (b0 - bn_mean) * inv_std + bn_beta
    return w_fused, b_fused


def quantize_tree(params, cfg: QuantConfig):
    """Fake-quant every floating leaf of a parameter pytree (QAT forward)."""
    if not cfg.enabled:
        return params

    def q(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating):
            return fake_quant(x, cfg, is_weight=True)
        return x

    return jax.tree_util.tree_map(q, params)
