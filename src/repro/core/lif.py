"""LIF neuron dynamics (paper Fig 1 / Fig 3 ④: MP update + threshold + reset).

Discrete LIF used throughout the paper's models:

    v[t]   = tau * v[t-1] * (1 - s[t-1])  +  I[t]      (hard reset)
    s[t]   = H(v[t] - v_th)

With the paper's single-timestep paradigm (T=1, v[0]=0) this degenerates to
``s = H(I - v_th)`` — no temporal state, no multi-timestep scheduling. The
multi-timestep path (lax.scan) is kept as the baseline the paper compares
against (SiBrain/STI-SNN style T>1 execution).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .surrogate import spike

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LIFConfig:
    tau: float = 0.5            # decay (paper §V.A: tau = 0.5)
    v_th: float = 1.0           # firing threshold
    surrogate: str = "atan"
    alpha: float = 2.0
    soft_reset: bool = False    # paper uses hard reset; soft kept for ablation


def lif_single_step(current: Array, cfg: LIFConfig = LIFConfig(),
                    v_prev: Optional[Array] = None) -> tuple[Array, Array]:
    """One LIF update. Returns (spikes, new membrane potential)."""
    if v_prev is None:
        v = current
    else:
        v = cfg.tau * v_prev + current
    s = spike(v - cfg.v_th, cfg.surrogate, cfg.alpha)
    if cfg.soft_reset:
        v_next = v - cfg.v_th * s
    else:
        v_next = v * (1.0 - s)
    return s, v_next


def lif_forward(current: Array, cfg: LIFConfig = LIFConfig()) -> Array:
    """Single-timestep spiking activation (paper's deployed mode): s = H(I - v_th)."""
    return spike(current - cfg.v_th, cfg.surrogate, cfg.alpha)


def lif_multistep(currents: Array, cfg: LIFConfig = LIFConfig()) -> Array:
    """Multi-timestep LIF over leading time axis ``currents[T, ...]`` via scan.

    Baseline execution mode (what SiBrain-style multi-timestep accelerators
    run); used for the T>1 vs T=1 comparisons in the benchmarks.
    """
    v0 = jnp.zeros_like(currents[0])

    def step(v, i_t):
        s, v_next = lif_single_step(i_t, cfg, v_prev=v)
        return v_next, s

    _, spikes = jax.lax.scan(step, v0, currents)
    return spikes


def spike_rate(spikes: Array) -> Array:
    """Fraction of active neurons — drives the event-skip analysis (C3)."""
    return jnp.mean(spikes.astype(jnp.float32))


def total_spikes(spikes: Array) -> Array:
    """Total Spikes (TS) metric from paper Table II."""
    return jnp.sum(spikes.astype(jnp.float32)).astype(jnp.int32)
