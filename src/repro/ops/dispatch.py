"""Format-dispatching entry points: the public surface of ``repro.ops``.

Every op takes spike operands as ``SpikeTensor`` (raw arrays and
``PackedSpikes`` are coerced via ``SpikeTensor.wrap``) plus an
``ExecutionPolicy`` — preset name, ``ExecutionPolicy`` instance, or None —
and dispatches to the implementation the kernel families registered in
``repro.ops.registry``:

  * ``policy.kernels`` selects the implementation ("reference" jnp oracles
    vs the "fused" Pallas kernels);
  * ``policy.format`` selects the HBM format of emitted spike maps (and
    operands are converted as needed), so a chain of ``ops.*`` calls is
    format-preserving end to end;
  * ``policy=None`` infers the natural policy from the input: fused
    kernels, format preserved from the operand.

Spike-emitting ops return ``SpikeTensor`` with the ``vld_cnt`` metadata the
next op's event skip consumes — the on-the-fly dataflow needs no explicit
metadata plumbing at call sites.

The policy's third axis — ``differentiable`` (``policy.for_training()`` /
a ``"+grad"`` preset suffix) — resolves the same ``(op, mode)`` registry
to the surrogate-gradient implementations in ``repro.ops.grad``: forward
still runs this policy's kernels, backward substitutes the registered
surrogate pseudo-derivative for every Heaviside. Differentiable spike
outputs are dense f32 (autodiff connectivity) and skip the metadata maps.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.events import DEFAULT_BLOCKS
from ..core.lif import LIFConfig
from .policy import ExecutionPolicy, PolicyLike, as_policy
from .registry import lookup
from .spike_tensor import SpikeTensor, Spikes

Array = jax.Array


def _policy_for(policy: PolicyLike, *sts: Optional[SpikeTensor]
                ) -> ExecutionPolicy:
    """None -> fused kernels, format inherited from the first spike
    operand (format preservation is the default behavior)."""
    if policy is not None:
        return as_policy(policy)
    fmt = "dense"
    for st in sts:
        if st is not None and st.is_packed:
            fmt = "packed"
            break
    return ExecutionPolicy("fused", fmt)


def _non_tuned(pol: ExecutionPolicy) -> ExecutionPolicy:
    """Ops without a tuner cost model run "auto" as "fused" (the kernels
    are unconditionally the right call for data movement / elementwise
    work; only the matmul-sweep ops have a strategy space worth pricing)."""
    return dataclasses.replace(pol, kernels="fused") if pol.auto else pol


def _auto_matmul(op: str, pol: ExecutionPolicy, st: SpikeTensor, n: int,
                 block_m: int, block_n: int, block_k: int,
                 allow_wide_n: bool = True
                 ) -> tuple[ExecutionPolicy, str, int, int, int]:
    """Resolve an "auto" policy for a matmul-sweep op: ask the roofline
    autotuner for the (kernel, skip strategy, block shape) plan on this
    operand's shape + measured sparsity. Returns the concretized policy
    plus (skip, block_m, block_n, block_k). An op whose fused kernel was
    demoted at runtime (``repro.ops.fallback``) resolves straight to
    reference — "auto" stops pricing a mode that cannot run."""
    if not pol.auto:
        return pol, "dense", block_m, block_n, block_k
    from .autotune import get_tuner

    tuner = get_tuner()
    if tuner.is_demoted(op):
        return (dataclasses.replace(pol, kernels="reference"),
                "dense", block_m, block_n, block_k)
    if pol.differentiable:
        # "auto+grad": price the BACKWARD execution points instead — the
        # plan picks this layer's backward skip mode (the dw sweep's event
        # gating) and whether the residual-cached fused vjp beats plain
        # autodiff on this shape.  Differentiable operands are dense f32
        # tracers under jit, so the sparsity comes from the measured
        # per-step training feed (``observe_train_sparsity`` ->
        # ``AutoTuner.observe``), not the operand metadata.
        plan = tuner.plan_grad_for(st, n)
        return (dataclasses.replace(pol, kernels=plan.kernels),
                plan.skip, block_m, block_n, block_k)
    plan = tuner.plan_for(st, n, block_m=block_m, block_n=block_n,
                          block_k=block_k, allow_wide_n=allow_wide_n)
    pol = dataclasses.replace(pol, kernels=plan.kernels)
    return pol, plan.skip, plan.block_m, plan.block_n, plan.block_k


class FusedOut(NamedTuple):
    """``ops.fused_pe`` / ``ops.fused_pe_layer`` result: the emitted spike
    map (format per policy, metadata attached), optional membrane state,
    and the raw vld map (also carried by ``spikes.vld_cnt``)."""
    spikes: SpikeTensor
    v_next: Optional[Array]
    vld_next: Optional[Array]


# ------------------------------------------------------------------- matmul
def matmul(x: Spikes, w: Array, *, policy: PolicyLike = None,
           skip: str = "dense",
           block_m: int = DEFAULT_BLOCKS.m, block_n: int = DEFAULT_BLOCKS.n,
           block_k: int = DEFAULT_BLOCKS.k) -> Array:
    """Event-driven spike matmul: [M, K] spikes @ [K, N] -> f32 current.
    Fused mode skips silent blocks on the operand's ``vld_cnt`` (computing
    it only if the SpikeTensor does not already carry one). ``skip``
    selects the byte-skip strategy ("dense" | "gated" | "two_level");
    an ``"auto"`` policy overrides it with the autotuner's plan."""
    st = SpikeTensor.wrap(x)
    pol = _policy_for(policy, st)
    if pol.auto:
        pol, skip, block_m, block_n, block_k = _auto_matmul(
            "matmul", pol, st, w.shape[1], block_m, block_n, block_k)
    return lookup("matmul", pol.mode)(st, w, block_m=block_m,
                                         block_n=block_n, block_k=block_k,
                                         skip=skip)


# ---------------------------------------------------------------------- lif
def lif(current: Array, v_prev: Array, s_prev: Array, *,
        lif_cfg: LIFConfig = LIFConfig(),
        policy: PolicyLike = None) -> tuple[Array, Array]:
    """One LIF membrane step over an arbitrary-shaped current tensor.
    Returns (spikes int8, v_next f32)."""
    pol = _non_tuned(_policy_for(policy))
    return lookup("lif", pol.mode)(current, v_prev, s_prev, lif_cfg)


# ----------------------------------------------------------------- fused_pe
def fused_pe(x: Spikes, w: Array, *,
             bias: Optional[Array] = None,
             residual: Optional[Spikes] = None,
             q: Optional[Spikes] = None,
             v_prev: Optional[Array] = None,
             s_prev: Optional[Array] = None,
             qk_threshold: float = 1.0,
             lif_cfg: LIFConfig = LIFConfig(),
             policy: PolicyLike = None,
             skip: str = "dense",
             heads: Optional[tuple[int, int]] = None,
             block_m: int = DEFAULT_BLOCKS.m,
             block_n: int = DEFAULT_BLOCKS.n,
             block_k: int = DEFAULT_BLOCKS.k) -> FusedOut:
    """One fused PE layer over a 2-D spike operand: event-skipped matmul +
    bias/residual + LIF threshold + optional QK write-back mask, emitting
    the next layer's metadata on the fly. ``residual`` may be a spike map
    (either format) or a raw f32 membrane current. ``skip`` selects the
    byte-skip strategy; an ``"auto"`` policy overrides it (and the block
    shape) with the autotuner's plan for this operand. ``heads=(h, dh)``
    makes the QK mask head-blocked: one row-sum threshold per head over
    ``q``'s head slice, gating only that head's ``dh`` output columns
    (requires ``w.shape[1] == h*dh``)."""
    st = SpikeTensor.wrap(x)
    res = SpikeTensor.wrap(residual) if residual is not None else None
    qs = SpikeTensor.wrap(q) if q is not None else None
    pol = _policy_for(policy, st)
    if pol.auto:
        wide_ok = not ((res is not None and res.is_packed)
                       or (qs is not None and qs.is_packed))
        pol, skip, block_m, block_n, block_k = _auto_matmul(
            "fused_pe", pol, st, w.shape[1], block_m, block_n, block_k,
            allow_wide_n=wide_ok)
    return lookup("fused_pe", pol.mode)(
        st, w, bias=bias, residual=res, q=qs, v_prev=v_prev, s_prev=s_prev,
        qk_threshold=qk_threshold, lif_cfg=lif_cfg, fmt=pol.format,
        block_m=block_m, block_n=block_n, block_k=block_k, skip=skip,
        heads=heads)


def fused_pe_layer(x: Spikes, w: Array, *,
                   bias: Optional[Array] = None,
                   residual: Optional[Spikes] = None,
                   q: Optional[Spikes] = None,
                   qk_threshold: float = 1.0,
                   lif_cfg: LIFConfig = LIFConfig(),
                   policy: PolicyLike = None,
                   skip: str = "dense",
                   heads: Optional[tuple[int, int]] = None,
                   block_m: int = DEFAULT_BLOCKS.m,
                   block_n: int = DEFAULT_BLOCKS.n,
                   block_k: int = DEFAULT_BLOCKS.k) -> FusedOut:
    """Multi-timestep fused layer over [T, M, K] spike trains (T=1 is the
    paper's stateless deployed mode; T>1 carries LIF state across steps).
    ``heads=(h, dh)`` makes the QK mask head-blocked (see ``fused_pe``)."""
    st = SpikeTensor.wrap(x)
    res = SpikeTensor.wrap(residual) if residual is not None else None
    qs = SpikeTensor.wrap(q) if q is not None else None
    pol = _policy_for(policy, st)
    if pol.auto:
        wide_ok = not ((res is not None and res.is_packed)
                       or (qs is not None and qs.is_packed))
        pol, skip, block_m, block_n, block_k = _auto_matmul(
            "fused_pe_layer", pol, st, w.shape[1], block_m, block_n,
            block_k, allow_wide_n=wide_ok)
    return lookup("fused_pe_layer", pol.mode)(
        st, w, bias=bias, residual=res, q=qs, qk_threshold=qk_threshold,
        lif_cfg=lif_cfg, fmt=pol.format, block_m=block_m, block_n=block_n,
        block_k=block_k, skip=skip, heads=heads)


# --------------------------------------------------------- spatial reshapes
def im2col(x: Spikes, spatial: tuple, kh: int, kw: int, stride: int, *,
           t: int = 1, policy: PolicyLike = None
           ) -> tuple[SpikeTensor, tuple[int, int]]:
    """Conv patch extraction on a token-layout spike map.

    ``x``: SpikeTensor with core [t, B*H*W, C]; ``spatial`` = (B, H, W, C).
    Returns (patches [t, B*Ho*Wo, kh*kw*Cp] SpikeTensor in the input's
    format, (Ho, Wo)). Patch extraction is channel-preserving, so the
    packed variant im2cols the WORD tensor directly — the patches of a
    packed map ARE the packing of the dense patches."""
    st = SpikeTensor.wrap(x)
    pol = _non_tuned(_policy_for(policy, st))
    return lookup("im2col", pol.mode)(st, spatial, kh, kw, stride, t=t,
                                         fmt=pol.format)


def pool(x: Spikes, spatial: tuple, *, t: int = 1, window: int = 2,
         policy: PolicyLike = None) -> tuple[SpikeTensor, tuple[int, int]]:
    """Spatial max-pool of a binary spike map in token layout.

    Max of binary == OR, so the packed variant pools by bitwise OR of the
    words — the pooled map never exists dense. Returns (pooled SpikeTensor
    [t, B*H2*W2, C], (H2, W2))."""
    st = SpikeTensor.wrap(x)
    pol = _non_tuned(_policy_for(policy, st))
    return lookup("pool", pol.mode)(st, spatial, t=t, window=window,
                                       fmt=pol.format)


def conv_matmul_weights(w: Array, patches: Spikes) -> Array:
    """[kh, kw, Cin, Cout] conv weight -> the [K, Cout] matmul weight
    matching ``ops.im2col``'s feature ordering for EITHER format (packed
    patches carry channel pad lanes; the matching weight rows are zero)."""
    from ..models import nn

    st = SpikeTensor.wrap(patches)
    kh, kw = w.shape[:2]
    c_padded = st.k // (kh * kw)
    return nn.conv_weights_as_matmul_packed(w, c_padded)


# ------------------------------------------------------------------ qk mask
def qk_mask(q: Spikes, k: Spikes, *, threshold: float = 1.0,
            mode: str = "threshold", surrogate: str = "atan",
            alpha: float = 2.0, policy: PolicyLike = None) -> SpikeTensor:
    """QKFormer token attention (paper C4): mask K's spike rows by Q's
    per-token row-sum threshold. Inputs [..., N, D]; output preserves the
    policy's format.

    ``mode`` / ``surrogate`` / ``alpha`` shape the GRADIENT under a
    differentiable policy: ``"threshold"`` backpropagates the registered
    surrogate pseudo-derivative through the row-sum Heaviside into Q,
    ``"or"`` (the hardware atten_reg) is forward-identical on integer
    spike counts at threshold 1 but passes no gradient into Q. Inference
    policies ignore them (the kernels compute the row-sum threshold)."""
    qs = SpikeTensor.wrap(q)
    ks = SpikeTensor.wrap(k)
    pol = _non_tuned(_policy_for(policy, ks))
    if pol.differentiable:
        masked = lookup("qk_mask", pol.mode)(
            qs.to_dense(jnp.float32) if qs.is_packed else qs.data,
            ks.to_dense(jnp.float32) if ks.is_packed else ks.data,
            threshold, mode=mode, surrogate=surrogate, alpha=alpha)
        return SpikeTensor.dense(masked)
    masked = lookup("qk_mask", pol.kernels)(qs.to_dense(),
                                            ks.to_dense(), threshold)
    out = SpikeTensor.dense(masked)
    return pack(out, policy=pol) if pol.packed else out


# ------------------------------------------------------------- pack / unpack
def pack(x: Spikes, *, policy: PolicyLike = None,
         block_m: int = DEFAULT_BLOCKS.m,
         block_k: int = DEFAULT_BLOCKS.k) -> SpikeTensor:
    """Convert to the event-compressed format (no-op if already packed)."""
    st = SpikeTensor.wrap(x)
    if st.is_packed:
        return st
    pol = _non_tuned(as_policy(policy, ExecutionPolicy("fused", "packed")))
    return lookup("pack", pol.kernels)(st, block_m=block_m, block_k=block_k)


def unpack(x: Spikes, *, dtype=jnp.int8, policy: PolicyLike = None) -> Array:
    """Materialize the dense spike map at the logical shape (no-op reshape
    for dense input)."""
    st = SpikeTensor.wrap(x)
    if not st.is_packed:
        return st.data.astype(dtype)
    pol = _non_tuned(as_policy(policy, ExecutionPolicy("fused", "packed")))
    return lookup("unpack", pol.kernels)(st, dtype)


# -------------------------------------------------------- softmax attention
def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              q_block: int = 512, kv_block: int = 512,
              policy: PolicyLike = None) -> Array:
    """Streaming causal softmax attention ([B, S, H, Dh] operands) — the
    non-spiking side of the hybrid flow, registered by the
    ``flash_attention`` kernel family."""
    pol = _non_tuned(_policy_for(policy))
    return lookup("attention", pol.kernels)(q, k, v, causal=causal,
                                            q_block=q_block,
                                            kv_block=kv_block)


# -------------------------------------------------- dense -> LIF projection
def dense_lif(p: dict, x: Array, lif_cfg: LIFConfig, *,
              q: Optional[Spikes] = None, qk_threshold: float = 1.0,
              heads: Optional[tuple[int, int]] = None,
              kv_heads: Optional[int] = None,
              policy: PolicyLike = None) -> SpikeTensor:
    """dense(x) + LIF threshold as one fused PE pass (the LM projection
    analogue of the PE dataflow): ``x`` is the dense residual stream, the
    f32 pre-activation never round-trips HBM, and the emitted spikes leave
    in the policy's format as a 2-D SpikeTensor over [tokens, Dout].
    ``q`` (either format) applies the QK write-back mask.

    ``heads=(h, dh)`` makes the mask head-blocked — one row-sum threshold
    per head over ``q``'s head slice, gating only that head's ``dh``
    output columns. ``kv_heads < h`` declares a grouped-KV projection
    (``p["w"]`` maps to ``kv_heads`` head blocks): the per-QUERY-head mask
    broadcasts over each group and the emitted map is the group-expanded
    [tokens, h*dh] — fused mode expands the WEIGHT columns (token-count
    independent), reference mode broadcasts at the mask multiply; neither
    materializes a replicated pre-mask KV tensor."""
    flat = x.reshape(-1, x.shape[-1])
    qs = SpikeTensor.wrap(q) if q is not None else None
    pol = _non_tuned(_policy_for(policy))
    return lookup("dense_lif", pol.mode)(p, flat, lif_cfg, q=qs,
                                            qk_threshold=qk_threshold,
                                            fmt=pol.format, heads=heads,
                                            kv_heads=kv_heads)


# ------------------------------------------------------------- W2TTFS head
def w2ttfs_head(spikes: Array, fc_w: Array, fc_b: Array, *, window: int,
                policy: PolicyLike = None) -> Array:
    """W2TTFS classifier head (paper C2): window spike-count pooling +
    unit-scale FC over a dense [B, H, W, C] spike map."""
    pol = _non_tuned(_policy_for(policy))
    return lookup("w2ttfs_head", pol.mode)(spikes, fc_w, fc_b,
                                              window=window)
