"""Kernel-family registrations for the ops dispatch layer.

Each of the seven kernel families binds its "fused" (Pallas) and
"reference" (pure-jnp oracle) implementations here. Implementations take
already-wrapped ``SpikeTensor`` operands from ``repro.ops.dispatch``,
convert to whatever the kernel-level wrappers accept, and wrap spike
outputs back into ``SpikeTensor`` — format selection (``fmt``) and operand
coercion live HERE so neither the kernels nor the call sites fork on the
spike format.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.events import (DEFAULT_BLOCKS, LANE_BITS, block_count_map_2d,
                           pack_spikes_ref, packed_from_words, pad_to_blocks,
                           unpack_spikes_ref)
from ..core.lif import LIFConfig, lif_forward
from .dispatch import FusedOut
from .registry import register
from .spike_tensor import SpikeTensor

Array = jax.Array


def _operand(st: Optional[SpikeTensor]):
    """Kernel-level operand: PackedSpikes for packed, the raw payload (no
    cast — dense residual currents stay f32) for dense."""
    if st is None:
        return None
    return st.to_packed_spikes() if st.is_packed else st.data


def _q_operand(q: Optional[SpikeTensor]):
    """Q spikes for the write-back mask: packed stays packed (row sums are
    popcounts); dense flattens to the [tokens, Dq] core."""
    if q is None:
        return None
    if q.is_packed:
        return q.to_packed_spikes()
    return q.data.reshape(-1, q.data.shape[-1])


def _wrap_spikes(spikes, vld, fmt: str, block_m: int, block_n: int
                 ) -> SpikeTensor:
    """Kernel output -> SpikeTensor (the emitted map's metadata grid tiles
    on (block_m, block_n), so the output tensor's block_k IS block_n)."""
    if fmt == "packed":
        return SpikeTensor.from_packed(spikes)
    return SpikeTensor.dense(spikes, vld, block_m=block_m, block_k=block_n)


def _ref_wrap(spk: Array, vld, fmt: str, block_m: int, block_n: int
              ) -> SpikeTensor:
    if fmt == "packed":
        return SpikeTensor.from_packed(
            pack_spikes_ref(spk, block_m=block_m, block_k=block_n))
    return SpikeTensor.dense(spk, vld, block_m=block_m, block_k=block_n)


# =============================================================== spike_matmul
@register("matmul", "fused")
def _matmul_fused(st: SpikeTensor, w: Array, *, block_m, block_n, block_k,
                  skip="dense"):
    from ..kernels.spike_matmul import spike_matmul

    if st.is_packed:
        return spike_matmul(st.to_packed_spikes(), w, block_m=block_m,
                            block_n=block_n, block_k=block_k, skip=skip)
    return spike_matmul(st.data, w, vld_cnt=st.vld_cnt, block_m=block_m,
                        block_n=block_n, block_k=block_k, skip=skip)


@register("matmul", "reference")
def _matmul_ref(st: SpikeTensor, w: Array, *, block_m, block_n, block_k,
                skip="dense"):
    from ..kernels.spike_matmul import spike_matmul_ref

    x = st.to_dense() if st.is_packed else st.data
    return spike_matmul_ref(x, w)


# ================================================================= lif_update
@register("lif", "fused")
def _lif_fused(current, v_prev, s_prev, cfg: LIFConfig):
    from ..kernels.lif_update import lif_update, lif_update_ref

    if jax.default_backend() != "tpu":
        # purely elementwise: off-TPU the Pallas interpreter emulation has
        # no skip or format behaviour to preserve — same math, ~10x the
        # wall clock. The kernel itself stays covered by the kernel-level
        # parity tests, which invoke it directly.
        return lif_update_ref(current, v_prev, s_prev, tau=cfg.tau,
                              v_th=cfg.v_th, soft_reset=cfg.soft_reset)
    return lif_update(current, v_prev, s_prev, tau=cfg.tau, v_th=cfg.v_th,
                      soft_reset=cfg.soft_reset)


@register("lif", "reference")
def _lif_ref(current, v_prev, s_prev, cfg: LIFConfig):
    from ..kernels.lif_update import lif_update_ref

    return lif_update_ref(current, v_prev, s_prev, tau=cfg.tau,
                          v_th=cfg.v_th, soft_reset=cfg.soft_reset)


# =================================================================== fused_pe
@register("fused_pe", "fused")
def _fused_pe_fused(st: SpikeTensor, w: Array, *, bias, residual, q, v_prev,
                    s_prev, qk_threshold, lif_cfg: LIFConfig, fmt,
                    block_m, block_n, block_k, skip="dense", heads=None):
    from ..kernels.fused_pe import fused_pe

    out = fused_pe(
        _operand(st), w, bias=bias, residual=_operand(residual),
        v_prev=v_prev, s_prev=s_prev, q=_q_operand(q),
        vld_cnt=None if st.is_packed else st.vld_cnt,
        tau=lif_cfg.tau, v_th=lif_cfg.v_th, soft_reset=lif_cfg.soft_reset,
        qk_threshold=qk_threshold, block_m=block_m, block_n=block_n,
        block_k=block_k, out_format=fmt, skip=skip, heads=heads)
    return FusedOut(_wrap_spikes(out.spikes, out.vld_next, fmt, block_m,
                                 block_n), out.v_next, out.vld_next)


@register("fused_pe", "reference")
def _fused_pe_reference(st: SpikeTensor, w: Array, *, bias, residual, q,
                        v_prev, s_prev, qk_threshold, lif_cfg: LIFConfig,
                        fmt, block_m, block_n, block_k, skip="dense",
                        heads=None):
    from ..kernels.fused_pe import fused_pe_ref

    res = residual.to_dense(jnp.float32) if residual is not None else None
    qd = q.to_dense().reshape(-1, q.shape[-1]) if q is not None else None
    spk, v_next, vld = fused_pe_ref(
        st.to_dense() if st.is_packed else st.data, w, bias=bias,
        residual=res, v_prev=v_prev, s_prev=s_prev, q=qd, tau=lif_cfg.tau,
        v_th=lif_cfg.v_th, soft_reset=lif_cfg.soft_reset,
        qk_threshold=qk_threshold, block_m=block_m, block_n=block_n,
        heads=heads)
    return FusedOut(_ref_wrap(spk, vld, fmt, block_m, block_n), v_next, vld)


@register("fused_pe_layer", "fused")
def _fused_pe_layer_fused(st: SpikeTensor, w: Array, *, bias, residual, q,
                          qk_threshold, lif_cfg: LIFConfig, fmt,
                          block_m, block_n, block_k, skip="dense",
                          heads=None):
    from ..kernels.fused_pe import fused_pe_layer

    spikes, vld = fused_pe_layer(
        _operand(st), w, bias=bias, residual=_operand(residual),
        q=None if q is None else _operand(q),
        vld_cnt=None if st.is_packed else st.vld_cnt,
        tau=lif_cfg.tau, v_th=lif_cfg.v_th, soft_reset=lif_cfg.soft_reset,
        qk_threshold=qk_threshold, block_m=block_m, block_n=block_n,
        block_k=block_k, out_format=fmt, skip=skip, heads=heads)
    return FusedOut(_wrap_spikes(spikes, vld, fmt, block_m, block_n),
                    None, vld)


@register("fused_pe_layer", "reference")
def _fused_pe_layer_reference(st: SpikeTensor, w: Array, *, bias, residual,
                              q, qk_threshold, lif_cfg: LIFConfig, fmt,
                              block_m, block_n, block_k, skip="dense",
                              heads=None):
    from ..kernels.fused_pe import fused_pe_ref
    from ..kernels.qk_attention import qk_attention_ref

    x = st.to_dense() if st.is_packed else st.data
    t, m, _ = x.shape
    n = w.shape[1]
    res = residual.to_dense(jnp.float32) if residual is not None else None
    qd = q.to_dense() if q is not None else None
    spikes_ts, vld_ts = [], []
    v = jnp.zeros((m, n), jnp.float32)
    s = jnp.zeros((m, n), jnp.int8)
    for ti in range(t):
        q_t = None if qd is None else qd[ti]
        if t == 1:
            spk, _, vld = fused_pe_ref(
                x[ti], w, bias=bias,
                residual=None if res is None else res[ti], q=q_t,
                tau=lif_cfg.tau, v_th=lif_cfg.v_th,
                soft_reset=lif_cfg.soft_reset, qk_threshold=qk_threshold,
                block_m=block_m, block_n=block_n, heads=heads)
        else:
            # stateful form: LIF state carries the PRE-mask spikes, the QK
            # mask gates outside — mirroring the kernel layer's T>1 path
            spk, v, vld = fused_pe_ref(
                x[ti], w, bias=bias,
                residual=None if res is None else res[ti], v_prev=v,
                s_prev=s, tau=lif_cfg.tau, v_th=lif_cfg.v_th,
                soft_reset=lif_cfg.soft_reset, block_m=block_m,
                block_n=block_n)
            s = spk
            if q_t is not None and heads is not None:
                h, dh = heads
                rs = q_t[:, :h * dh].astype(jnp.float32).reshape(
                    -1, h, dh).sum(axis=-1)
                # inference registration; +grad modes use the surrogate
                mask = (rs >= qk_threshold).astype(spk.dtype)  # neurallint: disable=NL-BARE-HEAVISIDE
                spk = (spk.reshape(-1, h, dh)
                       * mask[:, :, None]).reshape(spk.shape)
                vld = block_count_map_2d(
                    pad_to_blocks(spk, block_m, block_n), block_m, block_n)
            elif q_t is not None:
                spk = qk_attention_ref(q_t, spk, threshold=qk_threshold)
                vld = block_count_map_2d(
                    pad_to_blocks(spk, block_m, block_n), block_m, block_n)
        spikes_ts.append(spk)
        vld_ts.append(vld)
    spk3 = jnp.stack(spikes_ts)
    vld3 = jnp.stack(vld_ts)
    if fmt == "packed":
        out = SpikeTensor.from_packed(
            pack_spikes_ref(spk3, block_m=block_m, block_k=block_n))
    else:
        out = SpikeTensor.dense(spk3, vld3, block_m=block_m, block_k=block_n)
    return FusedOut(out, None, vld3)


# ======================================================== packed (pack/unpack)
@register("pack", "fused")
def _pack_fused(st: SpikeTensor, *, block_m, block_k):
    from ..kernels.packed import pack_spikes

    return SpikeTensor.from_packed(
        pack_spikes(st.data, block_m=block_m, block_k=block_k))


@register("pack", "reference")
def _pack_ref(st: SpikeTensor, *, block_m, block_k):
    return SpikeTensor.from_packed(
        pack_spikes_ref(st.data, block_m=block_m, block_k=block_k))


@register("unpack", "fused")
def _unpack_fused(st: SpikeTensor, dtype):
    from ..kernels.packed import unpack_spikes

    return unpack_spikes(st.to_packed_spikes(), dtype=dtype)


@register("unpack", "reference")
def _unpack_ref(st: SpikeTensor, dtype):
    return unpack_spikes_ref(st.to_packed_spikes(), dtype)


# =============================================================== qk_attention
@register("qk_mask", "fused")
def _qk_mask_fused(q: Array, k: Array, threshold: float):
    from ..kernels.qk_attention import qk_attention_fused

    return qk_attention_fused(q, k, threshold=threshold)


@register("qk_mask", "reference")
def _qk_mask_ref(q: Array, k: Array, threshold: float):
    from ..kernels.qk_attention import qk_attention_ref

    return qk_attention_ref(q, k, threshold=threshold)


# ============================================================ flash_attention
@register("attention", "fused")
def _attention_fused(q, k, v, *, causal, q_block, kv_block):
    from ..kernels.flash_attention import flash_attention

    return flash_attention(q, k, v, q_block=q_block, kv_block=kv_block,
                           causal=causal)


@register("attention", "reference")
def _attention_ref(q, k, v, *, causal, q_block, kv_block):
    from ..kernels.flash_attention import flash_attention_ref

    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    out = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        k.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        v.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        causal=causal, scale=d ** -0.5)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


# ================================================== w2ttfs_pool + spatial ops
# im2col / max-pool are pure data movement (no reference-vs-fused numeric
# distinction) but ARE format-dispatched: the packed variants operate on
# the word tensor and rebuild vld_cnt by popcount (1/32nd of the bytes a
# dense re-read would touch). The "reference" registrations differ only in
# HOW a format conversion (if one is needed) runs: via the pure-jnp
# pack/unpack oracles instead of the Pallas kernels, honoring the
# reference mode's no-Pallas contract.

def _spatial_words(st: SpikeTensor, spatial: tuple, t: int) -> Array:
    b, h, w_, _ = spatial
    cw = st.data.shape[-1]
    return st.data[:, :b * h * w_].reshape(t * b, h, w_, cw)


def _to_fmt(st: SpikeTensor, fmt: str, use_kernels: bool) -> SpikeTensor:
    pack = _pack_fused if use_kernels else _pack_ref
    unpack = _unpack_fused if use_kernels else _unpack_ref
    if fmt == "packed" and not st.is_packed:
        return pack(st, block_m=st.block_m, block_k=st.block_k)
    if fmt == "dense" and st.is_packed:
        return SpikeTensor.dense(unpack(st, jnp.int8),
                                 block_m=st.block_m, block_k=st.block_k)
    return st


def _im2col_impl(st: SpikeTensor, spatial: tuple, kh, kw, stride, *, t, fmt,
                 use_kernels: bool = True):
    from ..models import nn

    st = _to_fmt(st, fmt, use_kernels)
    b, h, w_, c = spatial
    if st.is_packed:
        pat = nn.im2col_packed(_spatial_words(st, spatial, t), kh, kw,
                               stride)
        _, ho, wo, kww = pat.shape
        pat3 = pat.reshape(t, b * ho * wo, kww)
        ps = packed_from_words(pat3, (t, b * ho * wo, kww * LANE_BITS),
                               block_m=st.block_m, block_k=st.block_k)
        return SpikeTensor.from_packed(ps), (ho, wo)
    dense = st.data.reshape(t * b, h, w_, c).astype(jnp.int8)
    pat = nn.im2col(dense, kh, kw, stride)
    _, ho, wo, kdim = pat.shape
    return (SpikeTensor.dense(pat.reshape(t, b * ho * wo, kdim),
                              block_m=st.block_m, block_k=st.block_k),
            (ho, wo))


def _pool_impl(st: SpikeTensor, spatial: tuple, *, t, window, fmt,
               use_kernels: bool = True):
    from ..models import nn

    st = _to_fmt(st, fmt, use_kernels)
    b, h, w_, c = spatial
    if st.is_packed:
        pooled = nn.max_pool_packed(_spatial_words(st, spatial, t), window)
        h2, w2 = pooled.shape[1], pooled.shape[2]
        ps = packed_from_words(
            pooled.reshape(t, b * h2 * w2, pooled.shape[3]),
            (t, b * h2 * w2, c), block_m=st.block_m, block_k=st.block_k)
        return SpikeTensor.from_packed(ps), (h2, w2)
    x = st.data.reshape(t * b, h, w_, c).astype(jnp.float32)
    pooled = nn.max_pool(x, window)
    h2, w2 = pooled.shape[1], pooled.shape[2]
    return (SpikeTensor.dense(
        pooled.reshape(t, b * h2 * w2, c).astype(jnp.int8),
        block_m=st.block_m, block_k=st.block_k), (h2, w2))


register("im2col", "fused")(_im2col_impl)
register("im2col", "reference")(functools.partial(_im2col_impl,
                                                  use_kernels=False))
register("pool", "fused")(_pool_impl)
register("pool", "reference")(functools.partial(_pool_impl,
                                                use_kernels=False))


# =========================================================== dense -> LIF map
def expand_group_weights(p: dict, heads: tuple[int, int], kv_heads: int
                         ) -> dict:
    """Grouped-KV projection -> per-query-head projection, in WEIGHT space.

    ``p["w"]`` maps to ``kv_heads`` head blocks of ``dh`` columns; the
    returned weight replicates each kv head's columns ``h // kv_heads``
    times (``jnp.repeat`` head order: query head qh reads kv head qh//g) so
    the fused kernel emits the group-EXPANDED [tokens, h*dh] map directly.
    A stateless LIF of replicated columns equals replicated LIF spikes, so
    this is bit-identical to masking grouped KV and broadcasting — but the
    replication cost is one [d, h*dh] WEIGHT (token-count independent)
    instead of ``_expand_kv``'s per-token [tokens, h*dh] HBM tensor.
    """
    h, dh = heads
    g = h // kv_heads
    w = p["w"]
    d = w.shape[0]
    assert w.shape[1] == kv_heads * dh, (w.shape, kv_heads, dh)
    out = {"w": jnp.repeat(w.reshape(d, kv_heads, dh), g,
                           axis=1).reshape(d, h * dh)}
    if "b" in p:
        out["b"] = jnp.repeat(p["b"].reshape(kv_heads, dh), g,
                              axis=0).reshape(h * dh)
    return out


@register("dense_lif", "fused")
def _dense_lif_fused(p: dict, flat: Array, lif_cfg: LIFConfig, *, q,
                     qk_threshold, fmt, heads=None, kv_heads=None,
                     with_current=False):
    from ..kernels.fused_pe import fused_pe

    if heads is not None and kv_heads is not None and kv_heads != heads[0]:
        p = expand_group_weights(p, heads, kv_heads)
    m, k = flat.shape
    bm, bn, bk = (DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.n, DEFAULT_BLOCKS.k)
    mq = -(-m // bm) * bm   # fused_pe pads x up to the block grid
    kq = -(-k // bk) * bk
    # dense residual stream: a ones map — dense blocks are never silent,
    # so no metadata pass is spent on the operand
    ones_vld = jnp.ones((mq // bm, kq // bk), jnp.int32)
    out = fused_pe(flat, p["w"], bias=p.get("b"), vld_cnt=ones_vld,
                   q=_q_operand(q), qk_threshold=qk_threshold,
                   tau=lif_cfg.tau, v_th=lif_cfg.v_th,
                   soft_reset=lif_cfg.soft_reset, out_format=fmt,
                   block_m=bm, block_n=bn, block_k=bk,
                   # heads only drives the head-blocked MASK — grouped KV
                   # without q is fully handled by the weight expansion
                   heads=None if q is None else heads,
                   emit_current=with_current)
    st = _wrap_spikes(out.spikes, out.vld_next, fmt, bm, bn)
    # the grad path asks for the kernel-cached membrane current (pre-LIF,
    # post-bias) so its backward never re-runs the projection matmul
    return (st, out.current) if with_current else st


@register("dense_lif", "reference")
def _dense_lif_ref(p: dict, flat: Array, lif_cfg: LIFConfig, *, q,
                   qk_threshold, fmt, heads=None, kv_heads=None):
    cur = flat.astype(jnp.float32) @ p["w"].astype(jnp.float32)
    if "b" in p:
        cur = cur + p["b"].astype(jnp.float32)
    spk = lif_forward(cur, lif_cfg).astype(jnp.int8)
    m = flat.shape[0]
    if q is not None and heads is not None:
        # head-blocked mask; grouped KV (kv_heads < h) is masked via a
        # broadcast over the group axis — the [tokens, h*dh] expansion
        # exists only as the multiply's output, never as a replicated
        # pre-mask copy of the KV spikes
        h, dh = heads
        hkv = h if kv_heads is None else kv_heads
        g = h // hkv
        rs = q.to_dense(jnp.float32).reshape(m, -1)[:, :h * dh].reshape(
            m, h, dh).sum(axis=-1)
        # inference registration; +grad modes use the surrogate
        mask = (rs >= qk_threshold).astype(jnp.int8)  # neurallint: disable=NL-BARE-HEAVISIDE
        spk = (spk.reshape(m, hkv, 1, dh)
               * mask.reshape(m, hkv, g, 1)).reshape(m, h * dh)
    elif q is not None:
        rowsum = q.to_dense(jnp.float32).reshape(m, -1).sum(
            axis=-1, keepdims=True)
        # inference registration; +grad modes use the surrogate
        spk = spk * (rowsum >= qk_threshold).astype(jnp.int8)  # neurallint: disable=NL-BARE-HEAVISIDE
    elif heads is not None and kv_heads is not None and kv_heads != heads[0]:
        h, dh = heads
        g = h // kv_heads
        spk = jnp.broadcast_to(spk.reshape(m, kv_heads, 1, dh),
                               (m, kv_heads, g, dh)).reshape(m, h * dh)
    vld = block_count_map_2d(
        pad_to_blocks(spk, DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.n),
        DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.n)
    return _ref_wrap(spk, vld, fmt, DEFAULT_BLOCKS.m, DEFAULT_BLOCKS.n)


# =================================================================== w2ttfs
@register("w2ttfs_head", "fused")
def _w2ttfs_head_fused(spikes: Array, fc_w: Array, fc_b: Array, *, window):
    from ..kernels.w2ttfs_pool import w2ttfs_pool_fc

    return w2ttfs_pool_fc(spikes, fc_w, fc_b, window=window)


@register("w2ttfs_head", "reference")
def _w2ttfs_head_ref(spikes: Array, fc_w: Array, fc_b: Array, *, window):
    from ..kernels.w2ttfs_pool import w2ttfs_pool_fc_ref

    return w2ttfs_pool_fc_ref(spikes, fc_w, fc_b, window)


# ============================================================= gradient axis
# the "+grad" modes (surrogate-gradient custom_vjp over these forwards)
# register on import alongside the inference modes
from . import grad as _grad  # noqa: E402,F401
