"""Graceful kernel degradation: fused -> reference fallback per (op, mode).

A fused Pallas call can fail at trace/lower time on shapes or backends the
kernel was never exercised on (Mosaic lowering errors, interpret-mode
limitations, a backend without the primitive). The serving stack must not
crash on that: ``registry.lookup`` wraps every fused-mode implementation in
a guard that, on a runtime failure, DEMOTES the (op, mode) cell to its
reference implementation for the rest of the process and re-runs the call
on the reference impl — same signature, same numerics contract (the parity
suite holds the fused kernels bit-identical to the references), so callers
never observe the swap except through ``demotions()`` / engine ``stats()``.

Demotion is sticky per (op, mode): the broken kernel is not retried, and
the roofline autotuner is told (``AutoTuner.demote``) so "auto" policies
stop pricing plans for an implementation that cannot run.

Only genuine runtime failures demote: ``RuntimeError`` and its subclasses
(XLA/Mosaic raise ``XlaRuntimeError``; the fault-injection harness raises
``InjectedKernelFault``). Contract violations — ``ValueError`` /
``TypeError`` / ``AssertionError`` from shape or block checks — propagate
unchanged: the reference impl would reject those too, and masking them
would hide caller bugs.

The deliberate injection point for the chaos tests lives here as well:
``arm_kernel_fault(op, at_call)`` makes the Nth guarded fused call of
``op`` raise ``InjectedKernelFault`` — exercising the demotion machinery
deterministically (``serve.faults.FaultPlan.fail_kernel`` arms it).

Note on jit: the guard runs at Python dispatch/trace time. A failure
inside an ALREADY-COMPILED executable (e.g. an async device-side fault)
surfaces from ``block_until_ready`` in the engine, where the replica-level
health machinery handles it; this layer models the much more common
trace/compile-time failure class.
"""
from __future__ import annotations

import warnings
from typing import Callable

__all__ = [
    "InjectedKernelFault", "arm_kernel_fault", "armed_kernel_faults",
    "is_demoted", "demotions", "reset_demotions", "reset",
]


class InjectedKernelFault(RuntimeError):
    """A deliberately injected fused-kernel failure (fault harness)."""


# kinds of failure that trigger demotion (see module docstring)
FALLBACK_EXCEPTIONS = (RuntimeError,)

_DEMOTED: dict[tuple[str, str], str] = {}      # (op, mode) -> reason
_WRAPPED: dict[tuple[str, str], tuple] = {}    # (op, mode) -> (fn, wrapper)
_FAULTS: list[dict] = []                       # armed injections
_LOG: list[dict] = []                          # demotion event log


def arm_kernel_fault(op: str = "*", at_call: int = 0) -> None:
    """Arm one injected failure: the ``at_call``-th guarded fused call of
    ``op`` ("*" = any op, counted across all ops) raises
    ``InjectedKernelFault`` from inside the guard. Fires once."""
    _FAULTS.append({"op": op, "at_call": int(at_call), "n": 0,
                    "fired": False})


def armed_kernel_faults() -> list[dict]:
    return [dict(f) for f in _FAULTS]


def is_demoted(op: str, mode: str | None = None) -> bool:
    """True if ``op`` (optionally a specific mode) has been demoted to its
    reference implementation."""
    if mode is not None:
        return (op, mode) in _DEMOTED
    return any(o == op for o, _ in _DEMOTED)


def demotions() -> list[dict]:
    """The demotion log: one entry per (op, mode) that fell back."""
    return [dict(e) for e in _LOG]


def reset_demotions() -> None:
    """Forget every demotion (tests / after a deploy that fixed the
    kernel). Also clears the autotuner's demotion set."""
    _DEMOTED.clear()
    _LOG.clear()
    from .autotune import get_tuner

    get_tuner().clear_demotions()


def reset() -> None:
    """Full harness reset: demotions, armed faults, wrapper cache."""
    reset_demotions()
    _FAULTS.clear()
    _WRAPPED.clear()


def _reference_mode(mode: str) -> str:
    return mode.replace("fused", "reference")


def _maybe_inject(op: str) -> None:
    for f in _FAULTS:
        if f["fired"] or f["op"] not in ("*", op):
            continue
        if f["n"] < f["at_call"]:
            f["n"] += 1
            continue
        f["fired"] = True
        raise InjectedKernelFault(
            f"injected fused-kernel fault: op={op!r} call #{f['n']}")


def _demote(op: str, mode: str, err: BaseException) -> None:
    reason = f"{type(err).__name__}: {err}"
    _DEMOTED[(op, mode)] = reason
    _LOG.append({"op": op, "mode": mode, "fallback": _reference_mode(mode),
                 "reason": reason})
    warnings.warn(
        f"fused kernel {op!r} ({mode}) raised {reason!r}; demoted to "
        f"{_reference_mode(mode)!r} for the rest of the process "
        f"(repro.ops.fallback.reset_demotions() to re-arm)",
        RuntimeWarning, stacklevel=3)
    from .autotune import get_tuner

    get_tuner().demote(op)


def guarded(op: str, mode: str, fn: Callable) -> Callable:
    """The wrapper ``registry.lookup`` returns for fused-mode entries.
    Memoized per (op, mode) so repeated lookups (every dispatch) reuse one
    closure."""
    cached = _WRAPPED.get((op, mode))
    if cached is not None and cached[0] is fn:
        return cached[1]

    def call(*args, **kwargs):
        from .registry import lookup

        if (op, mode) in _DEMOTED:
            return lookup(op, _reference_mode(mode))(*args, **kwargs)
        try:
            _maybe_inject(op)
            return fn(*args, **kwargs)
        except FALLBACK_EXCEPTIONS as err:
            try:
                ref = lookup(op, _reference_mode(mode))
            except NotImplementedError:
                raise err from None
            _demote(op, mode, err)
            return ref(*args, **kwargs)

    call.__name__ = f"guarded_{op}_{mode.replace('+', '_')}"
    _WRAPPED[(op, mode)] = (fn, call)
    return call
