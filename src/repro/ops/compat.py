"""THE deprecation shim module for the pre-policy flag API.

Every legacy knob — ``use_event_kernels=``, ``spike_format=``, and
``pack_out=`` — funnels through here and ONLY here: the kwargs are still
accepted at every call site that took them before the ``ExecutionPolicy``
redesign, they emit a ``DeprecationWarning`` naming the replacement, and
the ``NL-LEGACY-FLAGS`` neurallint rule (tools/neurallint.py) fails the
build if any of those kwarg spellings appear as call sites outside this
module and the test suite. New code passes ``policy=`` (an
``ExecutionPolicy`` or preset name) instead.

Migration map (old flag combination -> policy):

    (no flags)                                   -> "reference"
    use_event_kernels=True                       -> "fused_dense"  [*]
    use_event_kernels=True,  spike_format="packed" -> "fused_packed"
    use_event_kernels=False, spike_format="packed" -> "reference_packed"
    pack_out=True  (kernel-level)                -> out_format="packed"

[*] SNNCNNConfig's historical default spike format was "packed", so its
legacy translation maps a bare event-kernel flag to "fused_packed".
"""
from __future__ import annotations

import warnings
from typing import Optional

from .policy import ExecutionPolicy, PolicyLike, as_policy

_SEEN: set = set()


def _warn(msg: str) -> None:
    """DeprecationWarning, de-duplicated per distinct message so config
    rebuilds inside jit tracing / dataclasses.replace loops do not spam."""
    if msg not in _SEEN:
        _SEEN.add(msg)
        warnings.warn(msg, DeprecationWarning, stacklevel=4)


def reset_warning_dedup() -> None:
    """Test hook: make the next legacy use warn again."""
    _SEEN.clear()


def legacy_flags_policy(owner: str,
                        policy: PolicyLike,
                        use_event_kernels: Optional[bool],
                        spike_format: Optional[str],
                        *, default_format: str = "dense",
                        warn: bool = True) -> Optional[ExecutionPolicy]:
    """Translate a config's legacy flag pair into an ExecutionPolicy.

    Returns None when NOTHING was specified (policy and both flags unset),
    so callers can distinguish "inherit/default" from an explicit choice.
    An explicit ``policy`` always wins; mixing it with legacy flags is an
    error (the flags would silently lose).
    """
    flags_set = use_event_kernels is not None or spike_format is not None
    if policy is not None:
        if flags_set:
            raise ValueError(
                f"{owner}: pass either policy= or the deprecated "
                f"use_event_kernels/spike_format flags, not both")
        return as_policy(policy)
    if not flags_set:
        return None
    if warn:
        named = [n for n, v in (("use_event_kernels", use_event_kernels),
                                ("spike_format", spike_format))
                 if v is not None]
        verb = "is" if len(named) == 1 else "are"
        _warn(f"{owner}: {' / '.join(named)} {verb} deprecated; pass "
              f"policy=\"reference\" | \"fused_dense\" | \"fused_packed\" "
              f"(repro.ops.ExecutionPolicy) instead")
    if spike_format is not None and spike_format not in ("dense", "packed"):
        raise ValueError(f"{owner}: unknown spike format {spike_format!r}")
    fmt = spike_format if spike_format is not None else default_format
    kernels = "fused" if use_event_kernels else "reference"
    return ExecutionPolicy(kernels, fmt)


def merge_engine_policy(model_policy: ExecutionPolicy,
                        engine_policy: Optional[ExecutionPolicy],
                        use_event_kernels: Optional[bool],
                        spike_format: Optional[str]) -> ExecutionPolicy:
    """Engine-over-model policy resolution, preserving the legacy per-flag
    override semantics: an explicit engine ``policy`` replaces the model's
    wholesale, while legacy flags ESCALATE only the axis they set (an
    engine that asked for event kernels but said nothing about the format
    keeps the model's format). Escalate-only matches the pre-policy engine
    exactly — it could switch fused kernels ON and the packed format ON
    but never turn either off, so a falsy legacy flag stays a no-op here
    too; downgrading a model's policy per engine requires the explicit
    ``policy`` form."""
    if engine_policy is not None:
        return engine_policy
    kernels = model_policy.kernels
    fmt = model_policy.format
    if use_event_kernels:
        kernels = "fused"
    if spike_format is not None and spike_format != "dense":
        fmt = spike_format
    return ExecutionPolicy(kernels, fmt)


def with_policy(cfg, policy: ExecutionPolicy):
    """Config copy with ``policy`` set and the legacy flag pair cleared —
    the ONLY sanctioned way to override a config that may still carry
    legacy flags (a plain replace would trip the policy-vs-flags mixing
    check)."""
    import dataclasses

    return dataclasses.replace(cfg, policy=policy, use_event_kernels=None,
                               spike_format=None)


def resolve_out_format(pack_out: Optional[bool], out_format: Optional[str],
                       *, owner: str) -> str:
    """Kernel-level shim: the old ``pack_out=`` boolean becomes
    ``out_format="packed" | "dense"``."""
    if pack_out is not None:
        if out_format is not None:
            raise ValueError(f"{owner}: pass either out_format= or the "
                             f"deprecated pack_out flag, not both")
        _warn(f"{owner}: pack_out is deprecated; pass "
              f"out_format=\"packed\" (or a packed ExecutionPolicy) instead")
        return "packed" if pack_out else "dense"
    if out_format is None:
        return "dense"
    assert out_format in ("dense", "packed"), out_format
    return out_format
