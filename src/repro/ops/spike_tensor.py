"""SpikeTensor: the polymorphic spike-map currency of ``repro.ops``.

NEURAL's hybrid data-event execution means the SAME logical tensor — a
binary spike map — can live in two physical formats:

  * ``dense``  — int8/float 0-1 entries, one unit per element;
  * ``packed`` — the event-compressed HBM format (32 spikes per int32 lane
    + the popcount-derived per-block ``vld_cnt`` map; previously the
    standalone ``core.events.PackedSpikes`` container).

Before this layer existed, every consumer threaded the format by hand
(spike-format strings, pack-output booleans, explicit ``vld_cnt``
arguments) and each model path forked on it. ``SpikeTensor`` makes the
format a property of the VALUE instead of the call site: one pytree carries
the payload, the format tag, the logical shape, and — for BOTH variants —
the block-count metadata (``vld_cnt``) that the event-driven kernels use to
skip silent tiles, so chaining layer L's output into layer L+1 never
recomputes routing metadata regardless of format.

Registered as a JAX pytree: jit/vmap/scan treat (data, vld_cnt) as leaves
and (fmt, shape, blocks) as static aux data, so tracing through ``ops.*``
preserves the format across transformations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from ..core.events import (DEFAULT_BLOCKS, LANE_BITS, PackedSpikes,
                           unpack_words)

Array = jax.Array

FORMATS = ("dense", "packed")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SpikeTensor:
    """A spike map in either physical format, always carrying its metadata.

    data    : ``dense`` — [..., M, K] spikes (any dtype; nonzero == event),
              at the LOGICAL (unpadded) shape.
              ``packed`` — int32 [..., Mp, Kp/32] bit-packed words, core
              dims padded to the (block_m, block_k) grid.
    vld_cnt : int32 [..., Mp/block_m, Kp/block_k] per-block event counts
              (PipeSDA FIFO-tail metadata) over the padded grid, or None
              when no kernel has produced one yet (dense tensors fresh from
              a non-event op). Packed tensors ALWAYS carry it — it is
              derived by popcount at pack time.
    fmt     : "dense" | "packed".
    shape   : the logical (pre-padding) shape; last two dims are (m, k).
    occ     : optional int32 [..., Mp/block_m, Kp/block_k] word-occupancy
              bitmaps (second-level event metadata from the pack pass) —
              carried so the ``skip="two_level"`` kernels never recompute
              them; None when no producer has emitted one.
    """
    data: Array
    vld_cnt: Optional[Array] = None
    fmt: str = "dense"
    shape: tuple = ()
    block_m: int = DEFAULT_BLOCKS.m
    block_k: int = DEFAULT_BLOCKS.k
    occ: Optional[Array] = None

    def __post_init__(self):
        assert self.fmt in FORMATS, self.fmt
        if not self.shape:
            assert self.fmt == "dense", "packed SpikeTensor needs its shape"
            object.__setattr__(self, "shape", tuple(self.data.shape))
        else:
            object.__setattr__(self, "shape", tuple(self.shape))

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        return ((self.data, self.vld_cnt, self.occ),
                (self.fmt, self.shape, self.block_m, self.block_k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, shape, bm, bk = aux
        data, vld, occ = children
        return cls(data, vld, fmt, shape, bm, bk, occ)

    # ------------------------------------------------------- constructors
    @classmethod
    def dense(cls, x: Array, vld_cnt: Optional[Array] = None, *,
              block_m: int = DEFAULT_BLOCKS.m,
              block_k: int = DEFAULT_BLOCKS.k) -> "SpikeTensor":
        return cls(x, vld_cnt, "dense", tuple(x.shape), block_m, block_k)

    @classmethod
    def from_packed(cls, ps: PackedSpikes) -> "SpikeTensor":
        return cls(ps.words, ps.vld_cnt, "packed", tuple(ps.shape),
                   ps.block_m, ps.block_k, ps.occ)

    @classmethod
    def wrap(cls, x: "Spikes") -> "SpikeTensor":
        """Coerce any spike operand (raw array / PackedSpikes / SpikeTensor)
        into the common currency — the adapter every ``ops.*`` entry point
        runs on its spike inputs."""
        if isinstance(x, SpikeTensor):
            return x
        if isinstance(x, PackedSpikes):
            return cls.from_packed(x)
        return cls.dense(x)

    # -------------------------------------------------------------- views
    @property
    def is_packed(self) -> bool:
        return self.fmt == "packed"

    @property
    def m(self) -> int:
        return self.shape[-2]

    @property
    def k(self) -> int:
        return self.shape[-1]

    @property
    def padded_shape(self) -> tuple:
        if self.is_packed:
            return (*self.shape[:-2], self.data.shape[-2],
                    self.data.shape[-1] * LANE_BITS)
        mp = -(-self.m // self.block_m) * self.block_m
        kp = -(-self.k // self.block_k) * self.block_k
        return (*self.shape[:-2], mp, kp)

    @property
    def hbm_bytes(self) -> int:
        """Bytes this tensor ships over HBM in ITS format (payload + any
        metadata map)."""
        vld = (4 * math.prod(self.vld_cnt.shape)
               if self.vld_cnt is not None else 0)
        if self.is_packed:
            return 4 * math.prod(self.data.shape) + vld
        return (math.prod(self.shape) * self.data.dtype.itemsize) + vld

    @property
    def dense_bytes(self) -> int:
        """Bytes of the padded int8 map the packed format replaces (the
        denominator of the compression ratio)."""
        return math.prod(self.padded_shape)

    def to_packed_spikes(self) -> PackedSpikes:
        """View a packed SpikeTensor as the kernel-level container."""
        assert self.is_packed, "dense SpikeTensor has no packed view"
        return PackedSpikes(self.data, self.vld_cnt, self.shape,
                            self.block_m, self.block_k, self.occ)

    def to_dense(self, dtype=jnp.int8) -> Array:
        """Materialize the dense spike map at the logical shape (pure-jnp;
        use ``ops.unpack`` to route through the Pallas unpack kernel)."""
        if not self.is_packed:
            return self.data.astype(dtype)
        dense = unpack_words(self.data, dtype)
        sl = tuple(slice(0, d) for d in self.shape[-2:])
        return dense[(..., *sl)]

    def count(self) -> Array:
        """Total event count (f32 scalar) — from the metadata map when
        present (no pass over the payload), else a dense reduction."""
        if self.vld_cnt is not None:
            return self.vld_cnt.sum().astype(jnp.float32)
        return (self.data != 0).astype(jnp.float32).sum()

    def __getitem__(self, idx) -> "SpikeTensor":
        """Index ONE leading (batch/time) dim; the 2-D core is preserved."""
        assert isinstance(idx, int), idx
        assert len(self.shape) > 2, "cannot index the core dims"
        return SpikeTensor(self.data[idx],
                           None if self.vld_cnt is None else self.vld_cnt[idx],
                           self.fmt, self.shape[1:], self.block_m,
                           self.block_k,
                           None if self.occ is None else self.occ[idx])


Spikes = Union[Array, PackedSpikes, SpikeTensor]
