"""``repro.ops`` — the format-dispatching execution layer.

NEURAL's core claim is ONE computing flow serving dense-data and
sparse-event execution. This package is that flow's software API:

  * ``SpikeTensor`` — the polymorphic spike-map currency (``dense`` |
    ``packed`` variants, always carrying ``vld_cnt`` block metadata);
  * ``ExecutionPolicy`` — one knob ("reference" | "fused_dense" |
    "fused_packed" | "auto") replacing the legacy per-call flag plumbing;
    "auto" defers kernel/skip/block-shape choice to the roofline
    autotuner (``repro.ops.autotune``) driven by measured sparsity;
  * entry points (``matmul``, ``lif``, ``fused_pe``, ``fused_pe_layer``,
    ``pool``, ``im2col``, ``qk_mask``, ``pack``, ``unpack``,
    ``attention``, ``dense_lif``, ``w2ttfs_head``) that dispatch on input
    format and policy via a registry the kernel families plug into;
  * ``repro.ops.compat`` — the ONLY home of the deprecated
    ``use_event_kernels`` / ``spike_format`` / ``pack_out`` kwargs.

See docs/ops_api.md for the full API and the old-flag -> policy migration
table.
"""
from ..core.events import DEFAULT_BLOCKS, Blocks
from .autotune import AutoTuner, KernelPlan, get_tuner
from .compat import (legacy_flags_policy, merge_engine_policy,
                     resolve_out_format, with_policy)
from .fallback import (InjectedKernelFault, arm_kernel_fault, demotions,
                       reset_demotions)
from .dispatch import (FusedOut, attention, conv_matmul_weights, dense_lif,
                       fused_pe, fused_pe_layer, im2col, lif, matmul, pack,
                       pool, qk_mask, unpack, w2ttfs_head)
from .policy import (AUTO, AUTO_PACKED, FUSED_DENSE, FUSED_PACKED, POLICIES,
                     REFERENCE, ExecutionPolicy, as_policy)
from .registry import implementations, lookup, record_dispatches, register
from .spike_tensor import SpikeTensor, Spikes

__all__ = [
    "DEFAULT_BLOCKS", "Blocks", "SpikeTensor", "Spikes",
    "ExecutionPolicy", "POLICIES", "REFERENCE", "FUSED_DENSE",
    "FUSED_PACKED", "AUTO", "AUTO_PACKED", "as_policy",
    "AutoTuner", "KernelPlan", "get_tuner",
    "register", "lookup", "implementations", "record_dispatches",
    "FusedOut", "matmul", "lif", "fused_pe", "fused_pe_layer", "pool",
    "im2col", "conv_matmul_weights", "qk_mask", "pack", "unpack",
    "attention", "dense_lif", "w2ttfs_head",
    "legacy_flags_policy", "merge_engine_policy", "resolve_out_format",
    "with_policy",
    "InjectedKernelFault", "arm_kernel_fault", "demotions",
    "reset_demotions",
]
