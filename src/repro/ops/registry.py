"""Implementation registry: kernel families plug into the ops dispatch.

The registry maps ``(op, mode)`` — an entry-point name and an
``ExecutionPolicy.kernels`` mode ("reference" | "fused") — to a callable.
Each kernel family (``kernels/spike_matmul``, ``fused_pe``, ``packed``,
``lif_update``, ``qk_attention``, ``w2ttfs_pool``, ``flash_attention``)
registers its implementations in ``repro.ops.impls``; the dispatch layer
(``repro.ops.dispatch``) normalizes operand formats per the policy and
looks the implementation up here.

Registrations are loaded lazily on first lookup so importing ``repro.ops``
(e.g. from a config module) never drags the Pallas kernel suite in at
import time — new backends register by importing this module and calling
``register`` before their ops are dispatched.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Iterator, Optional

_REGISTRY: dict[tuple[str, str], Callable] = {}
_LOADED = False
_DISPATCH_LOG: Optional[list[tuple[str, str]]] = None


def register(op: str, mode: str) -> Callable[[Callable], Callable]:
    """Decorator: ``@register("matmul", "fused")`` binds an implementation.
    Re-registering a key overrides it (last wins) — that is the extension
    point for alternative backends."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, mode)] = fn
        return fn

    return deco


def _ensure_loaded() -> None:
    global _LOADED
    if not _LOADED:
        _LOADED = True
        from . import impls  # noqa: F401  (registers the kernel families)


@contextlib.contextmanager
def record_dispatches() -> Iterator[list[tuple[str, str]]]:
    """Record every ``(op, mode)`` this registry resolves inside the block.

    The yielded list fills in dispatch order — the executed-mode audit
    trail for policy-regression tests (e.g. "a packed policy on a
    multi-head LM must never resolve a dense pack/unpack round-trip").
    Records at TRACE time: under ``jax.jit`` a cache hit replays without
    re-dispatching, so assert against a cold trace (fresh shapes or
    ``jax.clear_caches``)."""
    global _DISPATCH_LOG
    prev, _DISPATCH_LOG = _DISPATCH_LOG, []
    try:
        yield _DISPATCH_LOG
    finally:
        _DISPATCH_LOG = prev


def lookup(op: str, mode: str) -> Callable:
    _ensure_loaded()
    if _DISPATCH_LOG is not None:
        _DISPATCH_LOG.append((op, mode))
    try:
        fn = _REGISTRY[(op, mode)]
        if "fused" in mode:
            # fused entries go out behind the graceful-degradation guard:
            # a runtime failure demotes the (op, mode) cell to its
            # reference implementation instead of crashing the caller
            from . import fallback

            return fallback.guarded(op, mode, fn)
        return fn
    except KeyError:
        have = sorted(m for o, m in _REGISTRY if o == op)
        if have:
            raise NotImplementedError(
                f"op {op!r} has no {mode!r} implementation "
                f"(registered modes: {have})") from None
        raise NotImplementedError(f"unknown op {op!r}") from None


def implementations(op: Optional[str] = None) -> dict:
    """Introspection: the registered (op, mode) -> callable table."""
    _ensure_loaded()
    if op is None:
        return dict(_REGISTRY)
    return {k: v for k, v in _REGISTRY.items() if k[0] == op}
