"""Roofline-driven policy autotuner: the ``"auto"`` kernel mode.

``ExecutionPolicy(kernels="auto")`` defers the kernel choice to this
module: per (op, shape, format, sparsity bucket) the tuner enumerates the
concrete execution points — reference jnp, fused dense-skip, fused gated,
fused two-level, over the admissible block shapes — prices each with the
streaming cost model in ``repro.launch.roofline``, and caches the argmin
as a ``KernelPlan``. Dispatch then runs THAT concrete implementation, so
an auto policy's outputs are bit-identical to whichever fixed policy it
selects, and (within the model) never slower than the best fixed one.

Sparsity is read from the operand's ``vld_cnt``/``occ`` maps when they are
CONCRETE (outside jit). Under a jit trace the maps are tracers — no value
to branch on — so the tuner falls back to the EWMA sparsity hint fed
online by the serving ``Engine``'s per-tick spike stats
(``AutoTuner.observe``), and to the dense-safe default (sparsity 0 ->
dense streaming) when nothing has been observed yet. Plans are keyed on
the BUCKETED sparsity so serving reuses one compiled kernel per regime
instead of recompiling per tick.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np

from ..launch import roofline
from .spike_tensor import SpikeTensor

# sparsity buckets: fraction of ACTIVE blocks quantized to these edges
# (coarse on the dense end, fine on the sparse end where strategy flips)
_BUCKETS = (0.0, 0.05, 0.15, 0.3, 0.5, 0.7, 0.85, 0.95, 1.0)


def bucket(frac: float) -> float:
    """Quantize an active-block fraction to its plan-cache bucket edge."""
    frac = min(max(float(frac), 0.0), 1.0)
    return min(_BUCKETS, key=lambda b: abs(b - frac))


def _concrete(x) -> Optional[np.ndarray]:
    """The host value of an array, or None under a jit trace."""
    if x is None or isinstance(x, jax.core.Tracer):
        return None
    return np.asarray(x)


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """One resolved execution point for one (op, shape, sparsity) cell."""
    kernels: str                  # "reference" | "fused"
    skip: str                     # "dense" | "gated" | "two_level"
    block_m: int
    block_n: int
    block_k: int
    est_time_s: float
    est_hbm_bytes: float
    active_frac: float            # the bucketed sparsity it was priced at
    occ_frac: float


class AutoTuner:
    """Plan cache + online sparsity observer for the "auto" kernel mode."""

    def __init__(self, ewma: float = 0.2):
        self._plans: dict = {}
        self._ewma = ewma
        # EWMA of (active-block fraction, word-occupancy fraction) fed by
        # the serving engine; the traced-operand fallback
        self._hint: Optional[tuple] = None
        # ops whose fused kernels were demoted to reference at runtime
        # (repro.ops.fallback): "auto" must stop pricing a mode that
        # cannot run, so demoted ops always plan to reference
        self._demoted: set = set()

    # ------------------------------------------------------------ observe
    def observe(self, active_frac: float, occ_frac: float = 1.0) -> None:
        """Feed one measured sparsity sample (e.g. from the Engine's
        per-tick spike stats). EWMA-smoothed into the traced fallback."""
        a, o = float(active_frac), float(occ_frac)
        if self._hint is None:
            self._hint = (a, o)
        else:
            pa, po = self._hint
            w = self._ewma
            self._hint = (pa * (1 - w) + a * w, po * (1 - w) + o * w)

    def sparsity_of(self, st: SpikeTensor) -> tuple:
        """(active_frac, occ_frac) for an operand: measured from concrete
        metadata, else the observed hint, else dense (the safe default —
        "auto" degrades to the dense-streaming kernel, never worse)."""
        vld = _concrete(st.vld_cnt)
        if vld is None and not st.is_packed:
            # dense operands carry vld_cnt lazily; measure from the payload
            data = _concrete(st.data)
            if data is not None:
                from ..core.events import block_count_map_2d, pad_to_blocks
                x2 = pad_to_blocks(st.data.reshape(-1, st.k),
                                   st.block_m, st.block_k)
                vld = np.asarray(block_count_map_2d(
                    x2, st.block_m, st.block_k))
        if vld is None:
            return self._hint if self._hint is not None else (1.0, 1.0)
        active = float(np.mean(vld > 0)) if vld.size else 1.0
        occ = _concrete(st.occ)
        if occ is None:
            occ_frac = 1.0
        else:
            wpb = max(st.block_k // 32, 1)
            cols = sum(((occ.astype(np.uint32) >> c) & 1).mean()
                       for c in range(wpb)) / wpb
            # stripe occupancy WITHIN active blocks
            occ_frac = float(cols / active) if active > 0 else 1.0
        return active, min(occ_frac, 1.0)

    # --------------------------------------------------------------- plan
    def plan_matmul(self, m: int, k: int, n: int, *, fmt: str = "dense",
                    active_frac: float = 1.0, occ_frac: float = 1.0,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128, allow_reference: bool = True,
                    allow_wide_n: bool = True) -> KernelPlan:
        """Pick kernel + skip strategy + block shape for one accumulation
        sweep (spike_matmul, or fused_pe's matmul core). Cached by
        (shape, fmt, blocks, sparsity bucket)."""
        a, o = bucket(active_frac), bucket(occ_frac)
        key = ("matmul", m, k, n, fmt, block_m, block_n, block_k, a, o,
               allow_reference, allow_wide_n)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._enumerate(m, k, n, fmt=fmt, active_frac=a,
                                   occ_frac=o, block_m=block_m,
                                   block_n=block_n, block_k=block_k,
                                   allow_reference=allow_reference,
                                   allow_wide_n=allow_wide_n)
            self._plans[key] = plan
        return plan

    def plan_for(self, st: SpikeTensor, n: int, *, block_m: int,
                 block_n: int, block_k: int, allow_reference: bool = True,
                 allow_wide_n: bool = True) -> KernelPlan:
        """Plan from a live operand: sparsity from its metadata (or the
        observed hint), block_m/block_k pinned to the operand's own grid
        (its vld/occ maps are only valid there). ``allow_wide_n=False``
        pins block_n too — required when a packed residual/q operand's
        grid ties the output tiling."""
        active, occ = self.sparsity_of(st)
        return self.plan_matmul(
            st.m, st.k, n, fmt=st.fmt, active_frac=active, occ_frac=occ,
            block_m=st.block_m, block_n=block_n, block_k=st.block_k,
            allow_reference=allow_reference, allow_wide_n=allow_wide_n)

    def plan_grad_matmul(self, m: int, k: int, n: int, *,
                         fmt: str = "dense", active_frac: float = 1.0,
                         occ_frac: float = 1.0, block_m: int = 128,
                         block_n: int = 128, block_k: int = 128,
                         allow_reference: bool = True) -> KernelPlan:
        """Pick the BACKWARD execution point for one accumulation sweep:
        prices dx (dense streaming, surrogate fused, residual-cache read)
        plus dw (event-skipped on the forward operand's vld map) per skip
        strategy against the jnp autodiff backward, with the same
        ``spike_matmul_grad_traffic`` model the roofline report uses.
        The returned plan's ``skip`` gates the dw sweep only — dx has no
        spike operand to gate. Cached by ("matmul_grad", shape, fmt,
        blocks, sparsity bucket); sparsity comes from the measured
        per-step training feed (``observe``) when the operands are
        traced, exactly like the forward path."""
        a, o = bucket(active_frac), bucket(occ_frac)
        key = ("matmul_grad", m, k, n, fmt, block_m, block_n, block_k,
               a, o, allow_reference)
        plan = self._plans.get(key)
        if plan is not None:
            return plan
        packed = fmt == "packed"
        candidates = []

        def price(kernels, skip):
            t = roofline.spike_matmul_grad_traffic(
                m, k, n, block_m=block_m, block_n=block_n,
                block_k=block_k, active_frac=a, occ_frac=o,
                packed=packed, skip=skip, kernels=kernels)
            candidates.append(KernelPlan(
                kernels, skip, block_m, block_n, block_k,
                est_time_s=roofline.kernel_time_s(t),
                est_hbm_bytes=t["hbm_bytes"],
                active_frac=a, occ_frac=o))

        for skip in ("dense", "gated", "two_level"):
            price("fused", skip)
        if allow_reference:
            price("reference", "dense")
        plan = min(candidates, key=lambda p: p.est_time_s)
        self._plans[key] = plan
        return plan

    def plan_grad_for(self, st: SpikeTensor, n: int) -> KernelPlan:
        """Backward plan from a live forward operand: sparsity from its
        metadata (or the observed training-step hint), blocks pinned to
        the operand's own grid — the vld map the dw sweep gates on only
        exists there."""
        active, occ = self.sparsity_of(st)
        return self.plan_grad_matmul(
            st.m, st.k, n, fmt=st.fmt, active_frac=active, occ_frac=occ,
            block_m=st.block_m, block_k=st.block_k)

    def _enumerate(self, m, k, n, *, fmt, active_frac, occ_frac,
                   block_m, block_n, block_k, allow_reference,
                   allow_wide_n=True) -> KernelPlan:
        packed = fmt == "packed"
        candidates = []

        def price(kernels, skip, bm, bn, bk):
            t = roofline.spike_matmul_traffic(
                m, k, n, block_m=bm, block_n=bn, block_k=bk,
                active_frac=active_frac, occ_frac=occ_frac,
                packed=packed, skip=skip, kernels=kernels)
            candidates.append(KernelPlan(
                kernels, skip, bm, bn, bk,
                est_time_s=roofline.kernel_time_s(t),
                est_hbm_bytes=t["hbm_bytes"],
                active_frac=active_frac, occ_frac=occ_frac))

        # block_m/block_k stay on the operand's metadata grid; block_n is
        # free — try the requested tile and a double-wide one (fewer x
        # re-fetches per output row when n allows it)
        bn_cands = {block_n}
        if allow_wide_n and n % (2 * block_n) == 0:
            bn_cands.add(2 * block_n)
        for bn in sorted(bn_cands):
            for skip in ("dense", "gated", "two_level"):
                price("fused", skip, block_m, bn, block_k)
        if allow_reference:
            price("reference", "dense", block_m, block_n, block_k)
        return min(candidates, key=lambda p: p.est_time_s)

    # ----------------------------------------------------------- demotion
    def demote(self, op: str) -> None:
        """A fused kernel of ``op`` failed at runtime and fell back to
        reference (see ``repro.ops.fallback``): drop every cached plan so
        future "auto" resolutions re-price with the op excluded from the
        fused candidate set."""
        if op not in self._demoted:
            self._demoted.add(op)
            self._plans.clear()

    def is_demoted(self, op: str) -> bool:
        return op in self._demoted

    def clear_demotions(self) -> None:
        if self._demoted:
            self._demoted.clear()
            self._plans.clear()

    # ---------------------------------------------------------- reporting
    def snapshot(self) -> dict:
        """Cache + hint state for the serving stats() export."""
        return {
            "observed_active_frac": None if self._hint is None
            else self._hint[0],
            "observed_occ_frac": None if self._hint is None
            else self._hint[1],
            "demoted_ops": sorted(self._demoted),
            "plans": {
                "|".join(map(str, k)): {
                    "kernels": p.kernels, "skip": p.skip,
                    "blocks": [p.block_m, p.block_n, p.block_k],
                    "est_time_us": p.est_time_s * 1e6,
                    "est_hbm_bytes": p.est_hbm_bytes,
                }
                for k, p in self._plans.items()
            },
        }

    def reset(self) -> None:
        self._plans.clear()
        self._hint = None
        self._demoted.clear()


_TUNER: Optional[AutoTuner] = None


def get_tuner() -> AutoTuner:
    """The process-global tuner the "auto" policy and the serving engine
    share (one sparsity profile per deployment)."""
    global _TUNER
    if _TUNER is None:
        _TUNER = AutoTuner()
    return _TUNER
