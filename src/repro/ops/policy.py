"""ExecutionPolicy: ONE knob for how the hybrid data-event flow executes.

The paper's claim is a single computing flow that serves dense-data and
sparse-event execution; our reproduction previously encoded that choice as
three booleans threaded by hand through every layer (``use_event_kernels``,
``spike_format``, ``pack_out``). This module replaces them with a single
policy value every ``ops.*`` entry point and model config understands:

  * ``"reference"``    — pure-jnp oracle paths; no Pallas kernels. The
                         training / numerics-debugging mode.
  * ``"fused_dense"``  — the fused event-driven Pallas kernels with int8
                         spike maps between layers.
  * ``"fused_packed"`` — the fused kernels AND the bit-packed HBM
                         interchange: spike tensors ship 32-per-int32-lane
                         with popcount metadata (~8x fewer spike bytes).

A policy is two orthogonal axes — which KERNELS run and which FORMAT spike
tensors take in HBM — because the legacy flag space allowed the off-diagonal
combination (reference compute + packed per-slot state caching in serving);
the named presets above are the three supported diagonal points.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

KERNEL_MODES = ("reference", "fused")
FORMATS = ("dense", "packed")


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    kernels: str = "reference"      # "reference" | "fused"
    format: str = "dense"           # "dense" | "packed"

    def __post_init__(self):
        assert self.kernels in KERNEL_MODES, self.kernels
        assert self.format in FORMATS, self.format

    @property
    def fused(self) -> bool:
        """True when the event-driven Pallas kernels run (inference-only:
        they carry no surrogate gradient)."""
        return self.kernels == "fused"

    @property
    def packed(self) -> bool:
        """True when spike tensors cross HBM bit-packed."""
        return self.format == "packed"

    @property
    def name(self) -> str:
        if self.kernels == "reference":
            return ("reference" if self.format == "dense"
                    else "reference_packed")
        return f"fused_{self.format}"

    def __str__(self) -> str:
        return self.name


REFERENCE = ExecutionPolicy("reference", "dense")
FUSED_DENSE = ExecutionPolicy("fused", "dense")
FUSED_PACKED = ExecutionPolicy("fused", "packed")

POLICIES = {
    "reference": REFERENCE,
    "fused_dense": FUSED_DENSE,
    "fused_packed": FUSED_PACKED,
    # legacy off-diagonal point: jnp compute, packed spike-state caching
    "reference_packed": ExecutionPolicy("reference", "packed"),
}

PolicyLike = Union[ExecutionPolicy, str, None]


def as_policy(policy: PolicyLike,
              default: Optional[ExecutionPolicy] = None) -> ExecutionPolicy:
    """Normalize a policy spec (preset name, ExecutionPolicy, or None)."""
    if policy is None:
        return default if default is not None else REFERENCE
    if isinstance(policy, ExecutionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown execution policy {policy!r}; expected one of "
                f"{sorted(POLICIES)}") from None
    raise TypeError(f"policy must be an ExecutionPolicy, a preset name, or "
                    f"None — got {type(policy).__name__}")
