"""ExecutionPolicy: ONE knob for how the hybrid data-event flow executes.

The paper's claim is a single computing flow that serves dense-data and
sparse-event execution; our reproduction previously encoded that choice as
three booleans threaded by hand through every layer (``use_event_kernels``,
``spike_format``, ``pack_out``). This module replaces them with a single
policy value every ``ops.*`` entry point and model config understands:

  * ``"reference"``    — pure-jnp oracle paths; no Pallas kernels. The
                         training / numerics-debugging mode.
  * ``"fused_dense"``  — the fused event-driven Pallas kernels with int8
                         spike maps between layers.
  * ``"fused_packed"`` — the fused kernels AND the bit-packed HBM
                         interchange: spike tensors ship 32-per-int32-lane
                         with popcount metadata (~8x fewer spike bytes).
  * ``"auto"`` / ``"auto_packed"`` — defer the kernel choice (reference vs
                         fused, byte-skip strategy, block shape) to the
                         roofline autotuner in ``repro.ops.autotune``,
                         driven by the measured ``vld_cnt`` sparsity.

A policy is three orthogonal axes — which KERNELS run, which FORMAT spike
tensors take in HBM, and whether the graph is DIFFERENTIABLE (the legacy
flag space allowed the off-diagonal reference+packed combination used by
serving's per-slot state caching); the named presets above are the three
supported inference points.

The ``differentiable`` axis is the training story (paper §III.B, C1): a
differentiable policy routes every ``ops.*`` entry point through the
surrogate-gradient implementations registered in ``repro.ops.grad`` —
forward still runs THIS policy's kernels (reference jnp or the fused
Pallas passes, dense or packed), backward substitutes the registered
surrogate pseudo-derivative for every Heaviside and the standard
transposes for the matmuls. Request it with ``policy.for_training()`` (or
a ``"<preset>+grad"`` spelling such as ``"fused_dense+grad"``), so
"train on the fused kernel, deploy the same graph" is one axis flip.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

KERNEL_MODES = ("reference", "fused", "auto")
FORMATS = ("dense", "packed")
GRAD_SUFFIX = "+grad"


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    kernels: str = "reference"      # "reference" | "fused"
    format: str = "dense"           # "dense" | "packed"
    differentiable: bool = False    # surrogate-gradient custom_vjp graph

    def __post_init__(self):
        assert self.kernels in KERNEL_MODES, self.kernels
        assert self.format in FORMATS, self.format

    @property
    def fused(self) -> bool:
        """True when the event-driven Pallas kernels MAY run the forward
        ("auto" resolves to fused or reference per call via the roofline
        autotuner in ``repro.ops.autotune``)."""
        return self.kernels in ("fused", "auto")

    @property
    def auto(self) -> bool:
        """True when the kernel choice is deferred to the autotuner."""
        return self.kernels == "auto"

    @property
    def packed(self) -> bool:
        """True when spike tensors cross HBM bit-packed."""
        return self.format == "packed"

    @property
    def mode(self) -> str:
        """The ``(op, mode)`` registry key axis: the kernel mode, suffixed
        ``+grad`` when this policy asks for the differentiable graph."""
        return self.kernels + (GRAD_SUFFIX if self.differentiable else "")

    def for_training(self) -> "ExecutionPolicy":
        """The same execution point with the gradient axis ON: identical
        forward numerics, surrogate-gradient backward."""
        return dataclasses.replace(self, differentiable=True)

    def for_inference(self) -> "ExecutionPolicy":
        """The same execution point with the gradient axis OFF."""
        return dataclasses.replace(self, differentiable=False)

    @property
    def name(self) -> str:
        if self.kernels == "reference":
            base = ("reference" if self.format == "dense"
                    else "reference_packed")
        elif self.kernels == "auto":
            base = "auto" if self.format == "dense" else "auto_packed"
        else:
            base = f"fused_{self.format}"
        return base + (GRAD_SUFFIX if self.differentiable else "")

    def __str__(self) -> str:
        return self.name


REFERENCE = ExecutionPolicy("reference", "dense")
FUSED_DENSE = ExecutionPolicy("fused", "dense")
FUSED_PACKED = ExecutionPolicy("fused", "packed")

AUTO = ExecutionPolicy("auto", "dense")
AUTO_PACKED = ExecutionPolicy("auto", "packed")

POLICIES = {
    "reference": REFERENCE,
    "fused_dense": FUSED_DENSE,
    "fused_packed": FUSED_PACKED,
    # legacy off-diagonal point: jnp compute, packed spike-state caching
    "reference_packed": ExecutionPolicy("reference", "packed"),
    # roofline-autotuned: kernel + skip strategy + block shape resolved per
    # (op, shape, sparsity bucket) by repro.ops.autotune
    "auto": AUTO,
    "auto_packed": AUTO_PACKED,
}

PolicyLike = Union[ExecutionPolicy, str, None]


def as_policy(policy: PolicyLike,
              default: Optional[ExecutionPolicy] = None) -> ExecutionPolicy:
    """Normalize a policy spec (preset name, optionally ``+grad``-suffixed,
    an ExecutionPolicy, or None)."""
    if policy is None:
        return default if default is not None else REFERENCE
    if isinstance(policy, ExecutionPolicy):
        return policy
    if isinstance(policy, str):
        base, grad = policy, False
        if policy.endswith(GRAD_SUFFIX):
            base, grad = policy[:-len(GRAD_SUFFIX)], True
        try:
            pol = POLICIES[base]
        except KeyError:
            raise ValueError(
                f"unknown execution policy {policy!r}; expected one of "
                f"{sorted(POLICIES)} (optionally suffixed "
                f"'{GRAD_SUFFIX}')") from None
        return pol.for_training() if grad else pol
    raise TypeError(f"policy must be an ExecutionPolicy, a preset name, or "
                    f"None — got {type(policy).__name__}")
