"""The gradient axis of ``repro.ops``: surrogate-gradient implementations.

The paper's algorithm-level contribution (C1) trains single-timestep SNNs
with plain backprop by substituting a smooth pseudo-derivative for the
Heaviside (§III.B).  This module is that substitution expressed as
``(op, mode)`` registry entries, so the SAME policy-driven forward the
deployment stack runs is what the KD pipeline differentiates:

  * ``(op, "reference+grad")`` — the pure-jnp surrogate body, differentiable
    end to end through ``core.surrogate.spike`` (whose own ``custom_vjp``
    carries the registered pseudo-derivative).  This is the autodiff
    baseline every other mode is parity-tested against.
  * ``(op, "fused+grad")`` — a ``jax.custom_vjp`` whose FORWARD runs the
    fused Pallas kernel (dense or packed, per the policy's format) and
    whose BACKWARD is the vjp of the matching surrogate body: the surrogate
    pseudo-derivative replaces every Heaviside, and the matmuls transpose
    as usual.  Forward numerics are the deployment kernels'; gradients are
    the training graph's — "train what you serve" in one registry key.

Residual/recompute policy: the backward pass re-linearizes the pure-jnp
body from the saved INPUTS (``jax.vjp`` at cotangent time) instead of
saving kernel intermediates — the standard surrogate-training trade, and
the only correct option since the fused kernels never materialize their
membrane pre-activations in HBM.

Spike operands arrive as dense f32 arrays (the dispatch layer materializes
SpikeTensors before calling in); spike outputs leave dense f32 so autodiff
connectivity survives the op chain.  Packed-policy forwards round-trip
through the pack/unpack kernels inside the primal only.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.lif import LIFConfig
from ..core.surrogate import spike
from .registry import register

Array = jax.Array

GRAD_MODES = ("reference+grad", "fused+grad")


# --------------------------------------------------------------- machinery
def _surrogate_vjp(kernel_fwd, ref_fwd):
    """custom_vjp pair: primal = ``kernel_fwd`` (the policy's kernels),
    backward = vjp of ``ref_fwd`` (the pure-jnp surrogate body).  Both take
    ONE pytree of f32 arrays and must return structurally identical f32
    outputs (enforced by the grad-parity tests)."""

    @jax.custom_vjp
    def f(operands):
        return kernel_fwd(operands)

    def fwd(operands):
        return kernel_fwd(operands), operands

    def bwd(operands, g):
        _, vjp = jax.vjp(ref_fwd, operands)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def _f32(x: Optional[Array]) -> Optional[Array]:
    return None if x is None else x.astype(jnp.float32)


def _dense_operand(st) -> Array:
    """SpikeTensor -> dense float operand, preserving autodiff connectivity
    (a dense f32 payload passes through untouched)."""
    from .spike_tensor import SpikeTensor

    if isinstance(st, SpikeTensor):
        return st.to_dense(jnp.float32) if st.is_packed \
            else st.data.astype(jnp.float32)
    return st.astype(jnp.float32)


def _emitted_dense(st) -> Array:
    """A kernel-emitted SpikeTensor (either format) -> dense f32 primal."""
    return _f32(st.to_dense(jnp.float32) if st.is_packed else st.data)


def _lif_step(cur: Array, v_prev: Optional[Array], s_prev: Optional[Array],
              cfg: LIFConfig) -> tuple[Array, Array]:
    """The surrogate LIF body in the KERNEL's state convention (reset by
    ``s_prev`` on entry, reset by the emitted spike on exit — idempotent,
    so chaining with ``s_prev=0`` over already-reset state reproduces
    ``core.lif.lif_single_step`` exactly, gradient included)."""
    v = cur if v_prev is None else \
        cfg.tau * v_prev * (1.0 - (0.0 if s_prev is None else s_prev)) + cur
    s = spike(v - cfg.v_th, cfg.surrogate, cfg.alpha)
    v_next = v - cfg.v_th * s if cfg.soft_reset else v * (1.0 - s)
    return s, v_next


def _qk_rowmask(q: Array, threshold: float, mode: str, surrogate: str,
                alpha: float) -> Array:
    """Per-token write-back mask — ``core.qk_attention.qk_token_mask``
    (ONE definition of the row-sum semantics): the surrogate flows through
    the threshold Heaviside; ``mode="or"`` is the hardware atten_reg,
    forward-identical on integer spike counts with threshold 1 but with
    zero gradient into Q."""
    from ..core.qk_attention import qk_token_mask

    return qk_token_mask(q, mode, threshold, surrogate, alpha)


def _qk_headmask_apply(s: Array, q: Array, heads: tuple[int, int],
                       kv_heads: Optional[int], threshold: float,
                       surrogate: str, alpha: float) -> Array:
    """Head-blocked surrogate write-back mask: one row-sum Heaviside (with
    surrogate pseudo-derivative) per head over ``q``'s head slice, gating
    that head's ``dh`` columns of ``s``. With ``kv_heads < h`` the per-
    QUERY-head mask broadcasts over each KV group, so ``s`` arrives
    grouped ([m, kv_heads*dh]) and leaves expanded ([m, h*dh]) — the
    backward pass then sums each group's cotangents into the shared
    grouped columns, exactly the vjp of the fused path's replicated
    weight columns."""
    h, dh = heads
    m = s.shape[0]
    hkv = h if kv_heads is None else kv_heads
    g = h // hkv
    mask = _qk_rowmask(q.reshape(m, -1)[:, :h * dh].reshape(m, h, dh),
                       threshold, "threshold", surrogate, alpha)
    return (s.reshape(m, hkv, 1, dh)
            * mask.reshape(m, hkv, g, 1)).reshape(m, h * dh)


# ------------------------------------------------------------------- matmul
@functools.lru_cache(maxsize=None)
def _matmul_grad(kernels: str, block_m: int, block_n: int, block_k: int):
    # unlike the 2-D inference entry point, the differentiable matmul takes
    # leading batch/time dims (the training body feeds [T, B, N, K] token
    # stacks); the reference body contracts batched exactly like the jnp
    # graph it replaces, the kernel form flattens for the Pallas call
    def ref_fwd(ops):
        return ops["x"] @ ops["w"]

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.spike_matmul import spike_matmul

        x, w = ops["x"], ops["w"]
        out = spike_matmul(x.reshape(-1, x.shape[-1]), w, block_m=block_m,
                           block_n=block_n, block_k=block_k)
        return out.reshape(*x.shape[:-1], w.shape[-1])

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _matmul_impl(kernels):
    # ``skip`` is accepted for signature parity with the inference impls
    # and ignored: differentiable operands are dense f32 stacks (autodiff
    # connectivity), so the byte-skip metadata the gated kernels need does
    # not exist on this path.
    def impl(st, w, *, block_m, block_n, block_k, skip="dense"):
        f = _matmul_grad(kernels, block_m, block_n, block_k)
        return f({"x": _dense_operand(st), "w": _f32(w)})
    return impl


# ---------------------------------------------------------------------- lif
@functools.lru_cache(maxsize=None)
def _lif_grad(kernels: str, cfg: LIFConfig):
    def ref_fwd(ops):
        return _lif_step(ops["current"], ops["v_prev"], ops["s_prev"], cfg)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.lif_update import lif_update

        s, v = lif_update(ops["current"], ops["v_prev"], ops["s_prev"],
                          tau=cfg.tau, v_th=cfg.v_th,
                          soft_reset=cfg.soft_reset)
        return _f32(s), _f32(v)

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _lif_impl(kernels):
    def impl(current, v_prev, s_prev, cfg: LIFConfig):
        f = _lif_grad(kernels, cfg)
        return f({"current": _f32(current), "v_prev": _f32(v_prev),
                  "s_prev": _f32(s_prev)})
    return impl


# ----------------------------------------------------------------- fused_pe
def _pe_current(ops: dict) -> Array:
    cur = ops["x"] @ ops["w"]
    if ops.get("bias") is not None:
        cur = cur + ops["bias"].reshape(1, -1)
    if ops.get("residual") is not None:
        cur = cur + ops["residual"]
    return cur


@functools.lru_cache(maxsize=None)
def _fused_pe_grad(kernels: str, cfg: LIFConfig, qk_threshold: float,
                   fmt: str, block_m: int, block_n: int, block_k: int,
                   stateful: bool, heads: Optional[tuple[int, int]] = None):
    def ref_fwd(ops):
        s, v_next = _lif_step(_pe_current(ops),
                              ops.get("v_prev"), ops.get("s_prev"), cfg)
        if ops.get("q") is not None and heads is not None:
            s = _qk_headmask_apply(s, ops["q"], heads, None, qk_threshold,
                                   cfg.surrogate, cfg.alpha)
        elif ops.get("q") is not None:
            s = s * _qk_rowmask(ops["q"].reshape(s.shape[0], -1),
                                qk_threshold, "threshold", cfg.surrogate,
                                cfg.alpha)
        return (s, v_next) if stateful else (s,)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.fused_pe import fused_pe

        out = fused_pe(ops["x"], ops["w"], bias=ops.get("bias"),
                       residual=ops.get("residual"),
                       v_prev=ops.get("v_prev"), s_prev=ops.get("s_prev"),
                       q=ops.get("q"), tau=cfg.tau, v_th=cfg.v_th,
                       soft_reset=cfg.soft_reset, qk_threshold=qk_threshold,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       out_format=fmt, heads=heads)
        spk = out.spikes
        if fmt == "packed":
            from ..kernels.packed import unpack_spikes

            spk = unpack_spikes(spk)
        return (_f32(spk), _f32(out.v_next)) if stateful else (_f32(spk),)

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _fused_pe_impl(kernels):
    def impl(st, w, *, bias, residual, q, v_prev, s_prev, qk_threshold,
             lif_cfg, fmt, block_m, block_n, block_k, skip="dense",
             heads=None):
        from .dispatch import FusedOut
        from .spike_tensor import SpikeTensor

        stateful = v_prev is not None
        f = _fused_pe_grad(kernels, lif_cfg, qk_threshold, fmt,
                           block_m, block_n, block_k, stateful, heads)
        ops = {"x": _dense_operand(st), "w": _f32(w), "bias": _f32(bias)}
        if residual is not None:
            ops["residual"] = _dense_operand(residual)
        if q is not None:
            ops["q"] = _dense_operand(q)
        if stateful:
            ops["v_prev"] = _f32(v_prev)
            ops["s_prev"] = _f32(s_prev) if s_prev is not None \
                else jnp.zeros_like(ops["v_prev"])
        out = f(ops)
        spk = out[0]
        return FusedOut(SpikeTensor.dense(spk, block_m=block_m,
                                          block_k=block_n),
                        out[1] if stateful else None, None)
    return impl


# ----------------------------------------------------------- fused_pe_layer
@functools.lru_cache(maxsize=None)
def _fused_pe_layer_grad(kernels: str, cfg: LIFConfig, qk_threshold: float,
                         fmt: str, block_m: int, block_n: int, block_k: int,
                         t: int, heads: Optional[tuple[int, int]] = None):
    def ref_fwd(ops):
        x, w = ops["x"], ops["w"]
        spikes_ts = []
        v = s = None
        for ti in range(t):
            res_t = None if ops.get("residual") is None \
                else ops["residual"][ti]
            cur = _pe_current({"x": x[ti], "w": w, "bias": ops.get("bias"),
                               "residual": res_t})
            if t == 1:
                spk, _ = _lif_step(cur, None, None, cfg)
            else:
                # stateful form: the LIF carry holds the PRE-mask spikes;
                # the QK mask gates outside (the kernel layer's T>1 path)
                spk, v = _lif_step(cur, v, s, cfg)
                s = spk
            if ops.get("q") is not None and heads is not None:
                spk = _qk_headmask_apply(spk, ops["q"][ti], heads, None,
                                         qk_threshold, cfg.surrogate,
                                         cfg.alpha)
            elif ops.get("q") is not None:
                spk = spk * _qk_rowmask(
                    ops["q"][ti].reshape(spk.shape[0], -1), qk_threshold,
                    "threshold", cfg.surrogate, cfg.alpha)
            spikes_ts.append(spk)
        return jnp.stack(spikes_ts)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.fused_pe import fused_pe_layer
        from ..kernels.packed import unpack_spikes

        spikes, _ = fused_pe_layer(
            ops["x"], ops["w"], bias=ops.get("bias"),
            residual=ops.get("residual"), q=ops.get("q"),
            tau=cfg.tau, v_th=cfg.v_th, soft_reset=cfg.soft_reset,
            qk_threshold=qk_threshold, block_m=block_m, block_n=block_n,
            block_k=block_k, out_format=fmt, heads=heads)
        if fmt == "packed":
            spikes = unpack_spikes(spikes)
        return _f32(spikes)

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _fused_pe_layer_impl(kernels):
    def impl(st, w, *, bias, residual, q, qk_threshold, lif_cfg, fmt,
             block_m, block_n, block_k, skip="dense", heads=None):
        from .dispatch import FusedOut
        from .spike_tensor import SpikeTensor

        x = _dense_operand(st)
        f = _fused_pe_layer_grad(kernels, lif_cfg, qk_threshold, fmt,
                                 block_m, block_n, block_k, x.shape[0],
                                 heads)
        ops = {"x": x, "w": _f32(w), "bias": _f32(bias)}
        if residual is not None:
            ops["residual"] = _dense_operand(residual)
        if q is not None:
            ops["q"] = _dense_operand(q)
        spk = f(ops)
        return FusedOut(SpikeTensor.dense(spk, block_m=block_m,
                                          block_k=block_n), None, None)
    return impl


# ------------------------------------------------------------------ qk_mask
@functools.lru_cache(maxsize=None)
def _qk_mask_grad(kernels: str, threshold: float, mode: str, surrogate: str,
                  alpha: float):
    def ref_fwd(ops):
        return _qk_rowmask(ops["q"], threshold, mode, surrogate, alpha) \
            * ops["k"]

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.qk_attention import qk_attention_fused

        # "or" on non-negative integer spike counts == rowsum >= 1
        thr = 1.0 if mode == "or" else threshold
        return _f32(qk_attention_fused(ops["q"], ops["k"], threshold=thr))

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _qk_mask_impl(kernels):
    def impl(q, k, threshold, *, mode="threshold", surrogate="atan",
             alpha=2.0):
        f = _qk_mask_grad(kernels, threshold, mode, surrogate, alpha)
        return f({"q": _f32(q), "k": _f32(k)})
    return impl


# ---------------------------------------------------------------- dense_lif
@functools.lru_cache(maxsize=None)
def _dense_lif_grad(kernels: str, cfg: LIFConfig, qk_threshold: float,
                    fmt: str, has_bias: bool,
                    heads: Optional[tuple[int, int]] = None,
                    kv_heads: Optional[int] = None):
    def ref_fwd(ops):
        # grouped KV (kv_heads < h): the matmul stays on the UNEXPANDED
        # weight — the group expansion happens inside the mask broadcast,
        # so its backward sums group cotangents into the shared columns
        cur = ops["x"] @ ops["w"]
        if has_bias:
            cur = cur + ops["b"]
        s = spike(cur - cfg.v_th, cfg.surrogate, cfg.alpha)
        if ops.get("q") is not None and heads is not None:
            s = _qk_headmask_apply(s, ops["q"], heads, kv_heads,
                                   qk_threshold, cfg.surrogate, cfg.alpha)
        elif ops.get("q") is not None:
            s = s * _qk_rowmask(ops["q"].reshape(s.shape[0], -1),
                                qk_threshold, "threshold", cfg.surrogate,
                                cfg.alpha)
        elif heads is not None and kv_heads is not None \
                and kv_heads != heads[0]:
            h, dh = heads
            m, g = s.shape[0], heads[0] // kv_heads
            s = jnp.broadcast_to(s.reshape(m, kv_heads, 1, dh),
                                 (m, kv_heads, g, dh)).reshape(m, h * dh)
        return s

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from .impls import _dense_lif_fused

        p = {"w": ops["w"]}
        if has_bias:
            p["b"] = ops["b"]
        q = ops.get("q")
        from .spike_tensor import SpikeTensor

        st = _dense_lif_fused(p, ops["x"], cfg,
                              q=None if q is None else SpikeTensor.dense(q),
                              qk_threshold=qk_threshold, fmt=fmt,
                              heads=heads, kv_heads=kv_heads)
        return _emitted_dense(st)

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _dense_lif_impl(kernels):
    def impl(p, flat, cfg, *, q, qk_threshold, fmt, heads=None,
             kv_heads=None):
        from .spike_tensor import SpikeTensor

        f = _dense_lif_grad(kernels, cfg, qk_threshold, fmt, "b" in p,
                            heads, kv_heads)
        ops = {"x": _f32(flat), "w": _f32(p["w"])}
        if "b" in p:
            ops["b"] = _f32(p["b"])
        if q is not None:
            ops["q"] = _dense_operand(q)
        return SpikeTensor.dense(f(ops))
    return impl


# -------------------------------------------------------------- w2ttfs_head
@functools.lru_cache(maxsize=None)
def _w2ttfs_grad(kernels: str, window: int):
    from ..core.w2ttfs import w2ttfs_classifier

    def ref_fwd(ops):
        return w2ttfs_classifier(ops["spikes"], ops["fc_w"], ops["fc_b"],
                                 window)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.w2ttfs_pool import w2ttfs_pool_fc

        return _f32(w2ttfs_pool_fc(ops["spikes"], ops["fc_w"], ops["fc_b"],
                                   window=window))

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _w2ttfs_impl(kernels):
    def impl(spikes, fc_w, fc_b, *, window):
        f = _w2ttfs_grad(kernels, window)
        return f({"spikes": _f32(spikes), "fc_w": _f32(fc_w),
                  "fc_b": _f32(fc_b)})
    return impl


# ------------------------------------------- differentiable data movement
# im2col / max-pool are pure data movement with native vjps (slicing and
# reduce_window); the grad-mode registrations only differ from the
# inference ones by PRESERVING the float dtype (the int8 casts in the
# inference impls are exact on {0,1} values but sever autodiff).

def _im2col_diff(st, spatial, kh, kw, stride, *, t, fmt):
    from ..models import nn
    from .spike_tensor import SpikeTensor

    b, h, w_, c = spatial
    x = _dense_operand(st)[:, :b * h * w_].reshape(t * b, h, w_, c)
    pat = nn.im2col(x, kh, kw, stride)
    _, ho, wo, kdim = pat.shape
    return (SpikeTensor.dense(pat.reshape(t, b * ho * wo, kdim),
                              block_m=st.block_m, block_k=st.block_k),
            (ho, wo))


def _pool_diff(st, spatial, *, t, window, fmt):
    from ..models import nn
    from .spike_tensor import SpikeTensor

    b, h, w_, c = spatial
    x = _dense_operand(st)[:, :b * h * w_].reshape(t * b, h, w_, c)
    pooled = nn.max_pool(x, window)
    h2, w2 = pooled.shape[1], pooled.shape[2]
    return (SpikeTensor.dense(pooled.reshape(t, b * h2 * w2, c),
                              block_m=st.block_m, block_k=st.block_k),
            (h2, w2))


# ------------------------------------------------------------ registration
def _register_all() -> None:
    for kernels in ("reference", "fused"):
        mode = f"{kernels}+grad"
        register("matmul", mode)(_matmul_impl(kernels))
        register("lif", mode)(_lif_impl(kernels))
        register("fused_pe", mode)(_fused_pe_impl(kernels))
        register("fused_pe_layer", mode)(_fused_pe_layer_impl(kernels))
        register("qk_mask", mode)(_qk_mask_impl(kernels))
        register("dense_lif", mode)(_dense_lif_impl(kernels))
        register("w2ttfs_head", mode)(_w2ttfs_impl(kernels))
        register("im2col", mode)(_im2col_diff)
        register("pool", mode)(_pool_diff)


_register_all()
