"""The gradient axis of ``repro.ops``: surrogate-gradient implementations.

The paper's algorithm-level contribution (C1) trains single-timestep SNNs
with plain backprop by substituting a smooth pseudo-derivative for the
Heaviside (§III.B).  This module is that substitution expressed as
``(op, mode)`` registry entries, so the SAME policy-driven forward the
deployment stack runs is what the KD pipeline differentiates:

  * ``(op, "reference+grad")`` — the pure-jnp surrogate body, differentiable
    end to end through ``core.surrogate.spike`` (whose own ``custom_vjp``
    carries the registered pseudo-derivative).  This is the autodiff
    baseline every other mode is parity-tested against.
  * ``(op, "fused+grad")`` — a ``jax.custom_vjp`` whose FORWARD runs the
    fused Pallas kernel (dense or packed, per the policy's format) and
    whose BACKWARD consumes RESIDUALS CACHED BY THAT FORWARD: the kernel
    emits its post-bias/-residual membrane current (``emit_current``), so
    the vjp differentiates only the cheap elementwise tail (surrogate
    spike, reset, QK mask) from the cached current and then runs the two
    transposed contractions directly — ``dx = dv @ wᵀ`` and
    ``dw = xᵀ @ dv`` — with NO re-execution of the forward matmul.
    Forward numerics are the deployment kernels'; gradients are the
    training graph's — "train what you serve" in one registry key.

Residual/recompute policy (matmul-bearing ops — matmul, fused_pe,
fused_pe_layer, dense_lif): the forward saves its spike operand, weights,
and the kernel-emitted membrane current; the backward recomputes ONLY the
elementwise nonlinearity from that current.  Elementwise ops (lif,
qk_mask) and the tiny w2ttfs head keep the classic recompute-from-inputs
``jax.vjp`` — re-linearizing them costs about as much as reading a cache.

Backward executor: on TPU (or under ``force_pallas_backward``) the two
contractions run the dedicated event-skipped Pallas backward kernels
(``kernels.spike_matmul.backward``): ``dx`` fuses the surrogate pseudo-
derivative factor into the transpose sweep, and ``dw`` skips the same
silent (m, k) tiles the forward skipped — the spikes ARE the activations,
so the vld/occ metadata prices both directions.  On CPU the identical
contractions run as XLA transposes (the Pallas interpreter would lose the
throughput the residual caching just won); parity between the two
executors is pinned by tests/test_grad_backward.py.

Spike operands arrive as dense f32 arrays (the dispatch layer materializes
SpikeTensors before calling in); spike outputs leave dense f32 so autodiff
connectivity survives the op chain.  Packed-policy forwards round-trip
through the pack/unpack kernels inside the primal only.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.lif import LIFConfig
from ..core.surrogate import spike, surrogate_grad
from .registry import register

Array = jax.Array

GRAD_MODES = ("reference+grad", "fused+grad")


# --------------------------------------------------------------- machinery
def _surrogate_vjp(kernel_fwd, ref_fwd):
    """custom_vjp pair: primal = ``kernel_fwd`` (the policy's kernels),
    backward = vjp of ``ref_fwd`` (the pure-jnp surrogate body).  Both take
    ONE pytree of f32 arrays and must return structurally identical f32
    outputs (enforced by the grad-parity tests).  Retained for the
    elementwise ops whose re-linearization is as cheap as a cache read;
    the matmul-bearing ops use residual-cached vjps below."""

    @jax.custom_vjp
    def f(operands):
        return kernel_fwd(operands)

    def fwd(operands):
        return kernel_fwd(operands), operands

    def bwd(operands, g):
        _, vjp = jax.vjp(ref_fwd, operands)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


# ------------------------------------------------------- kernel executor
_FORCE_PALLAS_BWD = False


def _pallas_backward() -> bool:
    """Whether the transposed contractions run the event-skipped Pallas
    backward kernels.  Default: only on TPU — on CPU the kernels would run
    under the Pallas interpreter, and the jnp transposes compute the
    IDENTICAL contraction faster (parity pinned by the backward tests)."""
    return _FORCE_PALLAS_BWD or jax.default_backend() == "tpu"


# The TRAINING forward follows the same executor split: on TPU the primal
# inside each custom_vjp runs the real fused kernels; off-TPU it runs the
# identical math as plain jnp (bit-parity with the kernels is pinned by
# the kernel test suites), skipping the Pallas interpreter emulation AND
# its pad/vld bookkeeping.  Inference/serving dispatch is unaffected.
_pallas_training = _pallas_backward


@contextlib.contextmanager
def force_pallas_backward(enabled: bool = True):
    """Force the Pallas kernel executor (interpret mode off-TPU) for BOTH
    directions of the differentiable ops — the primal kernels and the
    event-skipped backward kernels — used by the parity tests to exercise
    the kernel path end to end on CPU.  The flag is read at TRACE time:
    build (or re-trace) the grad function inside this context for it to
    take effect."""
    global _FORCE_PALLAS_BWD
    prev = _FORCE_PALLAS_BWD
    _FORCE_PALLAS_BWD = enabled
    try:
        yield
    finally:
        _FORCE_PALLAS_BWD = prev


def _bwd_dx(g: Array, w: Array, v: Optional[Array] = None, *,
            surrogate: str = "atan", alpha: float = 2.0, v_th: float = 1.0,
            blocks: tuple[int, int, int] = (128, 128, 128)):
    """``dv = g ⊙ surr'(v - v_th)`` (identity when ``v`` is None) and
    ``dx = dv @ wᵀ`` — one Pallas pass with the surrogate factor fused
    in-kernel on the Pallas executor, the identical jnp contraction
    otherwise.  Returns ``(dx, dv)``; 2-D operands only."""
    if _pallas_backward():
        from ..kernels.spike_matmul import spike_matmul_dx

        bm, bn, bk = blocks
        return spike_matmul_dx(g, w, v, surrogate=surrogate, alpha=alpha,
                               v_th=v_th, block_m=bm, block_n=bn, block_k=bk)
    dv = g if v is None else g * surrogate_grad(v - v_th, surrogate,
                                                alpha).astype(g.dtype)
    return dv @ w.T, dv


def _bwd_dw(x: Array, dv: Array, *, skip: str = "dense",
            blocks: tuple[int, int, int] = (128, 128, 128)) -> Array:
    """``dw = xᵀ @ dv`` over the {0,1} spike operand ``x`` — event-skipped
    on the Pallas executor (the tiles silent on the way forward are silent
    here too; ``skip`` applies the same dense/gated/two_level ladder along
    the transposed axis), a jnp transpose otherwise."""
    if _pallas_backward():
        from ..kernels.spike_matmul import spike_matmul_dw

        bm, bn, bk = blocks
        return spike_matmul_dw(x, dv, skip=skip, block_m=bm, block_n=bn,
                               block_k=bk)
    return x.T @ dv


def _f32(x: Optional[Array]) -> Optional[Array]:
    return None if x is None else x.astype(jnp.float32)


def _dense_operand(st) -> Array:
    """SpikeTensor -> dense float operand, preserving autodiff connectivity
    (a dense f32 payload passes through untouched)."""
    from .spike_tensor import SpikeTensor

    if isinstance(st, SpikeTensor):
        return st.to_dense(jnp.float32) if st.is_packed \
            else st.data.astype(jnp.float32)
    return st.astype(jnp.float32)


def _emitted_dense(st) -> Array:
    """A kernel-emitted SpikeTensor (either format) -> dense f32 primal."""
    return _f32(st.to_dense(jnp.float32) if st.is_packed else st.data)


def _lif_step(cur: Array, v_prev: Optional[Array], s_prev: Optional[Array],
              cfg: LIFConfig) -> tuple[Array, Array]:
    """The surrogate LIF body in the KERNEL's state convention (reset by
    ``s_prev`` on entry, reset by the emitted spike on exit — idempotent,
    so chaining with ``s_prev=0`` over already-reset state reproduces
    ``core.lif.lif_single_step`` exactly, gradient included)."""
    v = cur if v_prev is None else \
        cfg.tau * v_prev * (1.0 - (0.0 if s_prev is None else s_prev)) + cur
    s = spike(v - cfg.v_th, cfg.surrogate, cfg.alpha)
    v_next = v - cfg.v_th * s if cfg.soft_reset else v * (1.0 - s)
    return s, v_next


def _qk_rowmask(q: Array, threshold: float, mode: str, surrogate: str,
                alpha: float) -> Array:
    """Per-token write-back mask — ``core.qk_attention.qk_token_mask``
    (ONE definition of the row-sum semantics): the surrogate flows through
    the threshold Heaviside; ``mode="or"`` is the hardware atten_reg,
    forward-identical on integer spike counts with threshold 1 but with
    zero gradient into Q."""
    from ..core.qk_attention import qk_token_mask

    return qk_token_mask(q, mode, threshold, surrogate, alpha)


def _qk_headmask_apply(s: Array, q: Array, heads: tuple[int, int],
                       kv_heads: Optional[int], threshold: float,
                       surrogate: str, alpha: float) -> Array:
    """Head-blocked surrogate write-back mask: one row-sum Heaviside (with
    surrogate pseudo-derivative) per head over ``q``'s head slice, gating
    that head's ``dh`` columns of ``s``. With ``kv_heads < h`` the per-
    QUERY-head mask broadcasts over each KV group, so ``s`` arrives
    grouped ([m, kv_heads*dh]) and leaves expanded ([m, h*dh]) — the
    backward pass then sums each group's cotangents into the shared
    grouped columns, exactly the vjp of the fused path's replicated
    weight columns."""
    h, dh = heads
    m = s.shape[0]
    hkv = h if kv_heads is None else kv_heads
    g = h // hkv
    mask = _qk_rowmask(q.reshape(m, -1)[:, :h * dh].reshape(m, h, dh),
                       threshold, "threshold", surrogate, alpha)
    return (s.reshape(m, hkv, 1, dh)
            * mask.reshape(m, hkv, g, 1)).reshape(m, h * dh)


# ------------------------------------------------------------------- matmul
@functools.lru_cache(maxsize=None)
def _matmul_grad(kernels: str, block_m: int, block_n: int, block_k: int,
                 skip: str = "dense"):
    # unlike the 2-D inference entry point, the differentiable matmul takes
    # leading batch/time dims (the training body feeds [T, B, N, K] token
    # stacks); the reference body contracts batched exactly like the jnp
    # graph it replaces, the kernel form flattens for the Pallas call
    def ref_fwd(ops):
        return ops["x"] @ ops["w"]

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        if not _pallas_training():
            return ref_fwd(ops)
        from ..kernels.spike_matmul import spike_matmul

        x, w = ops["x"], ops["w"]
        out = spike_matmul(x.reshape(-1, x.shape[-1]), w, block_m=block_m,
                           block_n=block_n, block_k=block_k, skip=skip)
        return out.reshape(*x.shape[:-1], w.shape[-1])

    blocks = (block_m, block_n, block_k)

    @jax.custom_vjp
    def f(operands):
        return kernel_fwd(operands)

    def fwd(operands):
        # residuals: the operands themselves — a linear op has no
        # intermediate to cache, but the backward below runs TWO transposed
        # contractions instead of re-linearizing the forward (three)
        return kernel_fwd(operands), (operands["x"], operands["w"])

    def bwd(res, g):
        x, w = res
        x2 = x.reshape(-1, x.shape[-1])
        g2 = g.reshape(-1, g.shape[-1])
        dx, _ = _bwd_dx(g2, w, blocks=blocks)
        dw = _bwd_dw(x2, g2, skip=skip, blocks=blocks)
        return ({"x": dx.reshape(x.shape), "w": dw.astype(w.dtype)},)

    f.defvjp(fwd, bwd)
    return f


def _matmul_impl(kernels):
    # ``skip`` threads through to BOTH directions on the fused path: the
    # forward's event-skipped streaming mode and the backward weight-grad
    # kernel's transposed gating (xᵀ@g skips the same silent tiles).
    def impl(st, w, *, block_m, block_n, block_k, skip="dense"):
        f = _matmul_grad(kernels, block_m, block_n, block_k, skip)
        return f({"x": _dense_operand(st), "w": _f32(w)})
    return impl


# ---------------------------------------------------------------------- lif
@functools.lru_cache(maxsize=None)
def _lif_grad(kernels: str, cfg: LIFConfig):
    def ref_fwd(ops):
        return _lif_step(ops["current"], ops["v_prev"], ops["s_prev"], cfg)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        from ..kernels.lif_update import lif_update, lif_update_ref

        # Purely elementwise — off-TPU the interpret emulation buys no
        # skip/format behaviour, only wall clock; same math either way.
        fn = lif_update if _pallas_training() else lif_update_ref
        s, v = fn(ops["current"], ops["v_prev"], ops["s_prev"],
                  tau=cfg.tau, v_th=cfg.v_th, soft_reset=cfg.soft_reset)
        return _f32(s), _f32(v)

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _lif_impl(kernels):
    def impl(current, v_prev, s_prev, cfg: LIFConfig):
        f = _lif_grad(kernels, cfg)
        return f({"current": _f32(current), "v_prev": _f32(v_prev),
                  "s_prev": _f32(s_prev)})
    return impl


# ----------------------------------------------------------------- fused_pe
def _pe_current(ops: dict) -> Array:
    cur = ops["x"] @ ops["w"]
    if ops.get("bias") is not None:
        cur = cur + ops["bias"].reshape(1, -1)
    if ops.get("residual") is not None:
        cur = cur + ops["residual"]
    return cur


@functools.lru_cache(maxsize=None)
def _fused_pe_grad(kernels: str, cfg: LIFConfig, qk_threshold: float,
                   fmt: str, block_m: int, block_n: int, block_k: int,
                   stateful: bool, heads: Optional[tuple[int, int]] = None,
                   skip: str = "dense"):
    def _mask(s, q):
        if q is not None and heads is not None:
            return _qk_headmask_apply(s, q, heads, None, qk_threshold,
                                      cfg.surrogate, cfg.alpha)
        if q is not None:
            return s * _qk_rowmask(q.reshape(s.shape[0], -1),
                                   qk_threshold, "threshold", cfg.surrogate,
                                   cfg.alpha)
        return s

    def ref_fwd(ops):
        s, v_next = _lif_step(_pe_current(ops),
                              ops.get("v_prev"), ops.get("s_prev"), cfg)
        s = _mask(s, ops.get("q"))
        return (s, v_next) if stateful else (s,)

    if kernels == "reference":
        return ref_fwd

    blocks = (block_m, block_n, block_k)

    def run_kernel(ops, emit_current):
        if not _pallas_training():
            # identical math as jnp (kernel bit-parity is test-pinned) —
            # the membrane current doubles as the backward's residual cache
            cur = _pe_current(ops)
            s, v_next = _lif_step(cur, ops.get("v_prev"),
                                  ops.get("s_prev"), cfg)
            s = _mask(s, ops.get("q"))
            primal = (s, v_next) if stateful else (s,)
            return primal, (cur if emit_current else None)
        from ..kernels.fused_pe import fused_pe

        out = fused_pe(ops["x"], ops["w"], bias=ops.get("bias"),
                       residual=ops.get("residual"),
                       v_prev=ops.get("v_prev"), s_prev=ops.get("s_prev"),
                       q=ops.get("q"), tau=cfg.tau, v_th=cfg.v_th,
                       soft_reset=cfg.soft_reset, qk_threshold=qk_threshold,
                       block_m=block_m, block_n=block_n, block_k=block_k,
                       out_format=fmt, skip=skip, heads=heads,
                       emit_current=emit_current)
        spk = out.spikes
        if fmt == "packed":
            from ..kernels.packed import unpack_spikes

            spk = unpack_spikes(spk)
        primal = (_f32(spk), _f32(out.v_next)) if stateful else (_f32(spk),)
        return primal, out.current

    @jax.custom_vjp
    def f(operands):
        return run_kernel(operands, False)[0]

    def fwd(operands):
        # the kernel writes its post-bias/-residual membrane current out
        # once (emit_current) — the backward differentiates the cheap
        # elementwise tail from THAT instead of re-running the event-gated
        # matmul from the inputs
        primal, cur = run_kernel(operands, True)
        return primal, (operands, cur)

    def bwd(res, g):
        ops, cur = res
        w, q = ops["w"], ops.get("q")
        grads = {}
        if not stateful and _pallas_backward():
            # fully-fused stateless backward: dv = g_eff ⊙ surr'(cur - v_th)
            # happens INSIDE the dx kernel's transpose sweep
            (gs,) = g
            if q is not None:
                # primal-spike RECONSTRUCTION, constant wrt cur — the
                # surrogate factor flows through the dx kernel instead
                s_raw = (cur >= cfg.v_th).astype(gs.dtype)  # neurallint: disable=NL-BARE-HEAVISIDE
                masked_cot, vjp_q = jax.vjp(lambda q_: _mask(s_raw, q_), q)
                del masked_cot
                (grads["q"],) = vjp_q(gs)
                mask = _mask(jnp.ones_like(gs), q)
                g_eff = gs * jax.lax.stop_gradient(mask)
            else:
                g_eff = gs
            dx, dcur = _bwd_dx(g_eff, w, cur, surrogate=cfg.surrogate,
                               alpha=cfg.alpha, v_th=cfg.v_th, blocks=blocks)
        else:
            # elementwise tail from the cached current: surrogate spike,
            # reset, QK mask — a VPU pass, no matmul
            diff = {"cur": cur}
            for key in ("v_prev", "s_prev", "q"):
                if ops.get(key) is not None:
                    diff[key] = ops[key]

            def post(d):
                s, v_next = _lif_step(d["cur"], d.get("v_prev"),
                                      d.get("s_prev"), cfg)
                s = _mask(s, d.get("q"))
                return (s, v_next) if stateful else (s,)

            _, vjp = jax.vjp(post, diff)
            (dd,) = vjp(g)
            dcur = dd["cur"]
            for key in ("v_prev", "s_prev", "q"):
                if key in dd:
                    grads[key] = dd[key]
            dx, _ = _bwd_dx(dcur, w, blocks=blocks)
        # the spike operand's silent tiles skip the weight-grad contraction
        grads["x"] = dx
        grads["w"] = _bwd_dw(ops["x"], dcur, skip=skip, blocks=blocks)
        if ops.get("bias") is not None:
            grads["bias"] = dcur.sum(axis=0).reshape(ops["bias"].shape)
        if ops.get("residual") is not None:
            grads["residual"] = dcur
        out = {k: grads.get(k) for k in ops}
        return (out,)

    f.defvjp(fwd, bwd)
    return f


def _fused_pe_impl(kernels):
    def impl(st, w, *, bias, residual, q, v_prev, s_prev, qk_threshold,
             lif_cfg, fmt, block_m, block_n, block_k, skip="dense",
             heads=None):
        from .dispatch import FusedOut
        from .spike_tensor import SpikeTensor

        stateful = v_prev is not None
        f = _fused_pe_grad(kernels, lif_cfg, qk_threshold, fmt,
                           block_m, block_n, block_k, stateful, heads, skip)
        ops = {"x": _dense_operand(st), "w": _f32(w), "bias": _f32(bias)}
        if residual is not None:
            ops["residual"] = _dense_operand(residual)
        if q is not None:
            ops["q"] = _dense_operand(q)
        if stateful:
            ops["v_prev"] = _f32(v_prev)
            ops["s_prev"] = _f32(s_prev) if s_prev is not None \
                else jnp.zeros_like(ops["v_prev"])
        out = f(ops)
        spk = out[0]
        return FusedOut(SpikeTensor.dense(spk, block_m=block_m,
                                          block_k=block_n),
                        out[1] if stateful else None, None)
    return impl


# ----------------------------------------------------------- fused_pe_layer
@functools.lru_cache(maxsize=None)
def _fused_pe_layer_grad(cfg: LIFConfig, qk_threshold: float,
                         t: int, heads: Optional[tuple[int, int]] = None):
    # reference body only: the fused path chains per-timestep residual-
    # cached ``_fused_pe_grad`` vjps instead of one recompute-everything
    # custom_vjp over the whole T loop (see ``_fused_pe_layer_impl``)
    def ref_fwd(ops):
        x, w = ops["x"], ops["w"]
        spikes_ts = []
        v = s = None
        for ti in range(t):
            res_t = None if ops.get("residual") is None \
                else ops["residual"][ti]
            cur = _pe_current({"x": x[ti], "w": w, "bias": ops.get("bias"),
                               "residual": res_t})
            if t == 1:
                spk, _ = _lif_step(cur, None, None, cfg)
            else:
                # stateful form: the LIF carry holds the PRE-mask spikes;
                # the QK mask gates outside (the kernel layer's T>1 path)
                spk, v = _lif_step(cur, v, s, cfg)
                s = spk
            if ops.get("q") is not None and heads is not None:
                spk = _qk_headmask_apply(spk, ops["q"][ti], heads, None,
                                         qk_threshold, cfg.surrogate,
                                         cfg.alpha)
            elif ops.get("q") is not None:
                spk = spk * _qk_rowmask(
                    ops["q"][ti].reshape(spk.shape[0], -1), qk_threshold,
                    "threshold", cfg.surrogate, cfg.alpha)
            spikes_ts.append(spk)
        return jnp.stack(spikes_ts)

    return ref_fwd


def _fused_pe_layer_impl(kernels):
    def impl(st, w, *, bias, residual, q, qk_threshold, lif_cfg, fmt,
             block_m, block_n, block_k, skip="dense", heads=None):
        from .dispatch import FusedOut
        from .spike_tensor import SpikeTensor

        x = _dense_operand(st)
        t = x.shape[0]
        w_, bias_ = _f32(w), _f32(bias)
        res = None if residual is None else _dense_operand(residual)
        q_ = None if q is None else _dense_operand(q)

        if kernels == "reference":
            f = _fused_pe_layer_grad(lif_cfg, qk_threshold, t, heads)
            ops = {"x": x, "w": w_, "bias": bias_}
            if res is not None:
                ops["residual"] = res
            if q_ is not None:
                ops["q"] = q_
            spk = f(ops)
            return FusedOut(SpikeTensor.dense(spk, block_m=block_m,
                                              block_k=block_n), None, None)

        # fused: per-timestep residual-cached custom_vjp chain.  T=1 runs
        # the fully-fused masked stateless kernel; T>1 runs the stateful
        # kernel per step with the QK mask applied OUTSIDE on the pre-mask
        # carry — exactly the kernel layer's own T>1 semantics.
        spikes_ts = []
        if t == 1:
            f = _fused_pe_grad(kernels, lif_cfg, qk_threshold, fmt,
                               block_m, block_n, block_k, False, heads, skip)
            ops = {"x": x[0], "w": w_, "bias": bias_}
            if res is not None:
                ops["residual"] = res[0]
            if q_ is not None:
                ops["q"] = q_[0]
            spikes_ts.append(f(ops)[0])
        else:
            f = _fused_pe_grad(kernels, lif_cfg, qk_threshold, fmt,
                               block_m, block_n, block_k, True, None, skip)
            m, n = x.shape[1], w_.shape[1]
            v = jnp.zeros((m, n), jnp.float32)
            s = jnp.zeros((m, n), jnp.float32)
            for ti in range(t):
                ops = {"x": x[ti], "w": w_, "bias": bias_,
                       "v_prev": v, "s_prev": s}
                if res is not None:
                    ops["residual"] = res[ti]
                spk, v = f(ops)
                s = spk                      # pre-mask carry
                if q_ is not None and heads is not None:
                    spk = _qk_headmask_apply(spk, q_[ti], heads, None,
                                             qk_threshold, lif_cfg.surrogate,
                                             lif_cfg.alpha)
                elif q_ is not None:
                    spk = spk * _qk_rowmask(
                        q_[ti].reshape(spk.shape[0], -1), qk_threshold,
                        "threshold", lif_cfg.surrogate, lif_cfg.alpha)
                spikes_ts.append(spk)
        spk_t = jnp.stack(spikes_ts)
        return FusedOut(SpikeTensor.dense(spk_t, block_m=block_m,
                                          block_k=block_n), None, None)
    return impl


# ------------------------------------------------------------------ qk_mask
@functools.lru_cache(maxsize=None)
def _qk_mask_grad(kernels: str, threshold: float, mode: str, surrogate: str,
                  alpha: float):
    def ref_fwd(ops):
        return _qk_rowmask(ops["q"], threshold, mode, surrogate, alpha) \
            * ops["k"]

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        if not _pallas_training():
            return ref_fwd(ops)
        from ..kernels.qk_attention import qk_attention_fused

        # "or" on non-negative integer spike counts == rowsum >= 1
        thr = 1.0 if mode == "or" else threshold
        return _f32(qk_attention_fused(ops["q"], ops["k"], threshold=thr))

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _qk_mask_impl(kernels):
    def impl(q, k, threshold, *, mode="threshold", surrogate="atan",
             alpha=2.0):
        f = _qk_mask_grad(kernels, threshold, mode, surrogate, alpha)
        return f({"q": _f32(q), "k": _f32(k)})
    return impl


# ---------------------------------------------------------------- dense_lif
@functools.lru_cache(maxsize=None)
def _dense_lif_grad(kernels: str, cfg: LIFConfig, qk_threshold: float,
                    fmt: str, has_bias: bool,
                    heads: Optional[tuple[int, int]] = None,
                    kv_heads: Optional[int] = None):
    grouped = (heads is not None and kv_heads is not None
               and kv_heads != heads[0])

    def _tail(cur, q):
        # everything after the membrane current: surrogate spike + the
        # head-blocked / grouped-KV mask chain — elementwise and cheap
        s = spike(cur - cfg.v_th, cfg.surrogate, cfg.alpha)
        if q is not None and heads is not None:
            s = _qk_headmask_apply(s, q, heads, kv_heads,
                                   qk_threshold, cfg.surrogate, cfg.alpha)
        elif q is not None:
            s = s * _qk_rowmask(q.reshape(s.shape[0], -1),
                                qk_threshold, "threshold", cfg.surrogate,
                                cfg.alpha)
        elif grouped:
            h, dh = heads
            m, g = s.shape[0], heads[0] // kv_heads
            s = jnp.broadcast_to(s.reshape(m, kv_heads, 1, dh),
                                 (m, kv_heads, g, dh)).reshape(m, h * dh)
        return s

    def ref_fwd(ops):
        # grouped KV (kv_heads < h): the matmul stays on the UNEXPANDED
        # weight — the group expansion happens inside the mask broadcast,
        # so its backward sums group cotangents into the shared columns
        cur = ops["x"] @ ops["w"]
        if has_bias:
            cur = cur + ops["b"]
        return _tail(cur, ops.get("q"))

    if kernels == "reference":
        return ref_fwd

    def run_kernel(ops, with_current):
        if not _pallas_training():
            # identical math as jnp; the cached current stays in the
            # GROUPED (unexpanded-weight) layout the vjp differentiates
            cur = ops["x"] @ ops["w"]
            if has_bias:
                cur = cur + ops["b"]
            return _tail(cur, ops.get("q")), (cur if with_current else None)
        from .impls import _dense_lif_fused
        from .spike_tensor import SpikeTensor

        p = {"w": ops["w"]}
        if has_bias:
            p["b"] = ops["b"]
        q = ops.get("q")
        out = _dense_lif_fused(p, ops["x"], cfg,
                               q=None if q is None else SpikeTensor.dense(q),
                               qk_threshold=qk_threshold, fmt=fmt,
                               heads=heads, kv_heads=kv_heads,
                               with_current=with_current)
        if not with_current:
            return _emitted_dense(out), None
        st, cur = out
        if grouped:
            # the kernel ran on group-EXPANDED weights, so its cached
            # current replicates each kv group's columns exactly — slice
            # one replica back to the grouped layout the vjp needs
            h, dh = heads
            m = cur.shape[0]
            cur = cur.reshape(m, kv_heads, h // kv_heads, dh)[:, :, 0, :]
            cur = cur.reshape(m, kv_heads * dh)
        return _emitted_dense(st), cur

    @jax.custom_vjp
    def f(operands):
        return run_kernel(operands, False)[0]

    def fwd(operands):
        primal, cur = run_kernel(operands, True)
        return primal, (operands, cur)

    def bwd(res, g):
        ops, cur = res
        diff = {"cur": cur}
        if ops.get("q") is not None:
            diff["q"] = ops["q"]

        _, vjp = jax.vjp(lambda d: _tail(d["cur"], d.get("q")), diff)
        (dd,) = vjp(g)
        dcur = dd["cur"]
        grads = {"x": dcur @ ops["w"].T, "w": ops["x"].T @ dcur}
        if has_bias:
            grads["b"] = dcur.sum(axis=0).reshape(ops["b"].shape)
        if "q" in dd:
            grads["q"] = dd["q"]
        return ({k: grads.get(k) for k in ops},)

    f.defvjp(fwd, bwd)
    return f


def _dense_lif_impl(kernels):
    def impl(p, flat, cfg, *, q, qk_threshold, fmt, heads=None,
             kv_heads=None):
        from .spike_tensor import SpikeTensor

        f = _dense_lif_grad(kernels, cfg, qk_threshold, fmt, "b" in p,
                            heads, kv_heads)
        ops = {"x": _f32(flat), "w": _f32(p["w"])}
        if "b" in p:
            ops["b"] = _f32(p["b"])
        if q is not None:
            ops["q"] = _dense_operand(q)
        return SpikeTensor.dense(f(ops))
    return impl


# -------------------------------------------------------------- w2ttfs_head
@functools.lru_cache(maxsize=None)
def _w2ttfs_grad(kernels: str, window: int):
    from ..core.w2ttfs import w2ttfs_classifier

    def ref_fwd(ops):
        return w2ttfs_classifier(ops["spikes"], ops["fc_w"], ops["fc_b"],
                                 window)

    if kernels == "reference":
        return ref_fwd

    def kernel_fwd(ops):
        if not _pallas_training():
            return ref_fwd(ops)
        from ..kernels.w2ttfs_pool import w2ttfs_pool_fc

        return _f32(w2ttfs_pool_fc(ops["spikes"], ops["fc_w"], ops["fc_b"],
                                   window=window))

    return _surrogate_vjp(kernel_fwd, ref_fwd)


def _w2ttfs_impl(kernels):
    def impl(spikes, fc_w, fc_b, *, window):
        f = _w2ttfs_grad(kernels, window)
        return f({"spikes": _f32(spikes), "fc_w": _f32(fc_w),
                  "fc_b": _f32(fc_b)})
    return impl


# ------------------------------------------- differentiable data movement
# im2col / max-pool are pure data movement with native vjps (slicing and
# reduce_window); the grad-mode registrations only differ from the
# inference ones by PRESERVING the float dtype (the int8 casts in the
# inference impls are exact on {0,1} values but sever autodiff).

def _im2col_diff(st, spatial, kh, kw, stride, *, t, fmt):
    from ..models import nn
    from .spike_tensor import SpikeTensor

    b, h, w_, c = spatial
    x = _dense_operand(st)[:, :b * h * w_].reshape(t * b, h, w_, c)
    pat = nn.im2col(x, kh, kw, stride)
    _, ho, wo, kdim = pat.shape
    return (SpikeTensor.dense(pat.reshape(t, b * ho * wo, kdim),
                              block_m=st.block_m, block_k=st.block_k),
            (ho, wo))


def _pool_diff(st, spatial, *, t, window, fmt):
    from ..models import nn
    from .spike_tensor import SpikeTensor

    b, h, w_, c = spatial
    x = _dense_operand(st)[:, :b * h * w_].reshape(t * b, h, w_, c)
    pooled = nn.max_pool(x, window)
    h2, w2 = pooled.shape[1], pooled.shape[2]
    return (SpikeTensor.dense(pooled.reshape(t, b * h2 * w2, c),
                              block_m=st.block_m, block_k=st.block_k),
            (h2, w2))


# ------------------------------------------------------------ registration
def _register_all() -> None:
    for kernels in ("reference", "fused"):
        mode = f"{kernels}+grad"
        register("matmul", mode)(_matmul_impl(kernels))
        register("lif", mode)(_lif_impl(kernels))
        register("fused_pe", mode)(_fused_pe_impl(kernels))
        register("fused_pe_layer", mode)(_fused_pe_layer_impl(kernels))
        register("qk_mask", mode)(_qk_mask_impl(kernels))
        register("dense_lif", mode)(_dense_lif_impl(kernels))
        register("w2ttfs_head", mode)(_w2ttfs_impl(kernels))
        register("im2col", mode)(_im2col_diff)
        register("pool", mode)(_pool_diff)


_register_all()
