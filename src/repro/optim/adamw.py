"""AdamW with decoupled weight decay (functional, pytree-native).

Moments are kept in f32 regardless of param dtype (mixed-precision-safe);
with the ZeRO-1 sharding specs from ``models.sharding.zero1_specs`` GSPMD
shards the moments over 'data' and emits reduce-scatter/all-gather around
the update — optimizer-state sharding without any code change here.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr: float | Array = 1e-3, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 grad_scale: Optional[Array] = None
                 ) -> tuple[Any, AdamWState]:
    """One AdamW step. ``grad_scale`` divides grads (loss-scaling support)."""
    step = state.step + 1
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g / grad_scale
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * g * g
        mhat = m_new / b1t
        vhat = v_new / b2t
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
