"""Gradient compression for the DP all-reduce (distributed-opt trick).

int8 symmetric quantization with ERROR FEEDBACK: the quantization residual
is carried into the next step's gradient so the compression bias vanishes
in expectation (1-bit-Adam / EF-SGD family).

Usage is shard_map-based because the compression must happen BEFORE the
cross-replica reduction: per-replica grads are quantized to int8, psum'd in
int32 (4x less DP traffic than f32, 2x less than bf16), then dequantized.
The elastic FIFO analogy from the paper (C3) is deliberate: gradients become
low-precision "events" whose magnitude is restored downstream.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def compress_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def error_feedback_init(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_one(g: Array, err: Array, axis: str) -> tuple[Array, Array]:
    """Quantize (g + carried error), psum int8 payload, return mean grad and
    the new local error. Runs INSIDE shard_map over the DP axis."""
    g = g.astype(jnp.float32) + err
    q, scale = compress_int8(g)
    deq_local = decompress_int8(q, scale)
    new_err = g - deq_local                       # residual stays local
    # reduce int32 accumulators + scales; dequantize per-replica contribution
    total = jax.lax.psum(deq_local, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total / n, new_err


def compressed_psum_grads(grads: Any, err: Any, axis: str = "data"
                          ) -> tuple[Any, Any]:
    """Apply int8+EF compression to every leaf, reducing over ``axis``.
    Call inside shard_map; see train.trainer.make_compressed_train_step."""
    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = tree.flatten_up_to(err)
    out = [_compress_one(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (tree.unflatten([o[0] for o in out]),
            tree.unflatten([o[1] for o in out]))
