"""LR schedules as pure fns of the step counter (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_lr(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def linear_warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cosine = cosine_lr(base_lr, max(total_steps - warmup_steps, 1), final_frac)
    def fn(step):
        warm = base_lr * (step.astype(jnp.float32) + 1.0) / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cosine(step - warmup_steps))
    return fn
