"""SGD with momentum — the optimizer the paper trains with (§V.A: SGD,
momentum 0.9, for the KD CNN pipeline)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any


def sgd_init(params: Any) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32),
                    momentum=jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def sgd_update(grads: Any, state: SGDState, params: Any, *,
               lr: float | jax.Array = 0.1, momentum: float = 0.9,
               weight_decay: float = 0.0, nesterov: bool = False
               ) -> tuple[Any, SGDState]:
    def upd(p, g, buf):
        g = g.astype(jnp.float32)
        if weight_decay:
            g = g + weight_decay * p.astype(jnp.float32)
        buf_new = momentum * buf + g
        step_dir = g + momentum * buf_new if nesterov else buf_new
        return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), buf_new

    pairs = jax.tree_util.tree_map(upd, params, grads, state.momentum)
    new_p = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_b = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(step=state.step + 1, momentum=new_b)
