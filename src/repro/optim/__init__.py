from .adamw import adamw_init, adamw_update
from .sgd import sgd_init, sgd_update
from .schedules import constant_lr, cosine_lr, linear_warmup_cosine
from .compression import (compress_int8, decompress_int8,
                          compressed_psum_grads, error_feedback_init)
from .clip import global_norm, clip_by_global_norm
