"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel lives in its own subpackage with the mandated trio:
  <name>/<name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  <name>/ops.py    — jit'd public wrapper (padding, count-map plumbing,
                     interpret=True on CPU so tests validate the kernel body)
  <name>/ref.py    — pure-jnp oracle the tests assert against

Kernels (mapped from the paper's FPGA units in DESIGN.md §6):
  fused_pe        — the PE's WHOLE dataflow in one pass (Fig 3 + Fig 5):
                    event-skipped spike matmul + bias/residual + LIF update
                    + QK write-back mask + on-the-fly emission of the next
                    layer's vld_cnt metadata (see docs/fused_pe_dataflow.md)
  spike_matmul    — event-driven matmul: int8 OR bit-packed spike
                    activations, per-block vld_cnt skip (@pl.when) =
                    PipeSDA + PE event FIFO (C3)
  packed          — event compression: pack/unpack 32 spikes per int32
                    lane with popcount-derived vld_cnt in the same pass
                    (the PackedSpikes HBM interchange format,
                    docs/event_compression.md)
  qk_attention    — fused on-the-fly QKFormer token attention in the
                    write-back path (C4)
  w2ttfs_pool     — window spike-count + unit-scale FC head = WTFC core (C2)
  lif_update      — fused LIF membrane update/threshold/reset (C3 neuron)
  flash_attention — VMEM-resident causal softmax attention (forward):
                    built because §Perf cell F measured the jnp-level flash
                    path spilling f32 score tiles to HBM (~20 s/step of the
                    qwen1.5-32b prefill_32k memory term)

Models and serving reach these through ``repro.ops`` — the
format-dispatching layer (SpikeTensor + ExecutionPolicy, docs/ops_api.md)
each family registers its fused/reference implementations into. Direct
kernel imports remain supported for tests and benchmarks.
"""
