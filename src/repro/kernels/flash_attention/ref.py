"""Oracle: naive masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        scale: float = 1.0) -> jax.Array:
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
