"""Public wrapper: [B,S,H,D] GQA-aware dispatch to the flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..contract import KernelContract, declare
from .flash_attention import flash_attention_pallas

Array = jax.Array

CONTRACT = declare(KernelContract(
    family="flash_attention", ops=("attention",), formats=("dense",),
    # streaming softmax tiles: one [q_block, D] q tile, one [kv_block, D]
    # k + v tile pair, the f32 accumulator and the m/l running stats rows
    # (512-blocks, D bounded by the corpus' widest head dim)
    vmem_bytes=lambda bm, bn, bk, packed: (512 * 128 * 4 * 4
                                           + 2 * 512 * 4)))


@functools.partial(jax.jit, static_argnames=("q_block", "kv_block", "causal",
                                             "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, q_block: int = 512,
                    kv_block: int = 512, causal: bool = True,
                    interpret: bool | None = None) -> Array:
    """q: [B,S,H,D]; k,v: [B,S,Hkv,D] (GQA: Hkv divides H). -> [B,S,H,D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    scale = d ** -0.5
    qb = min(q_block, s)
    kb = min(kv_block, s)
    pad = (-s) % max(qb, kb)
    if pad:
        # pad tail is masked out by causality (pad k_pos > every real q_pos)
        assert causal, "non-causal flash requires block-divisible seq"
        zq = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, zq) for t in (q, k, v))
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, q.shape[1], d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    out = flash_attention_pallas(qf, kf, vf, q_block=qb, kv_block=kb,
                                 causal=causal, scale=scale,
                                 interpret=interpret)
    out = out.reshape(b, h, q.shape[1], d).transpose(0, 2, 1, 3)
    return out[:, :s]
