"""Pallas flash attention (forward) — the lever §Perf cell F identified.

The jnp-level chunked attention has the flash ALGORITHM but not the VMEM
RESIDENCY: XLA materializes each [q_block, kv_block] f32 score tile to HBM
3-4x per step (measured ~20 s of qwen1.5-32b prefill_32k's 39.6 s memory
term). This kernel keeps the running (m, l, acc) state and every score tile
in VMEM/registers: HBM traffic is exactly one read of Q/K/V and one write
of O.

Layout: [BH, S, D] (batch*heads flattened into the leading grid axis).
Grid: (BH, S/q_block); the kv sweep is a fori_loop INSIDE the kernel over
the full-seq K/V blocks resident in VMEM (S*D*2B <= 8 MiB for S=32k,
D=128 — fits the v5e VMEM budget alongside the q/o tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, scale: float,
            causal: bool):
    qb, d = q_ref.shape[-2], q_ref.shape[-1]
    s_len = k_ref.shape[-2]
    nkb = s_len // kv_block
    qi = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32) * scale           # [qb, d]
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kv_block), 0)

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(i * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * kv_block, kv_block), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            k_pos = i * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    # causal: kv blocks strictly above the diagonal contribute nothing —
    # stop the sweep at the q block's diagonal (the classic flash skip)
    n_iter = jnp.minimum(nkb, (qi + 1) * qb // kv_block + 1) if causal \
        else nkb
    m0 = jnp.full((qb,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    acc0 = jnp.zeros((qb, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("q_block", "kv_block", "causal", "scale",
                                    "interpret"))
def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           q_block: int = 512, kv_block: int = 512,
                           causal: bool = True, scale: float = 1.0,
                           interpret: bool = False) -> Array:
    """q,k,v: [BH, S, D] -> out [BH, S, D] (q's dtype)."""
    bh, s, d = q.shape
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    kern = functools.partial(_kernel, kv_block=kv_block, scale=scale,
                             causal=causal)
    return pl.pallas_call(
        kern,
        grid=(bh, s // q_block),
        in_specs=[pl.BlockSpec((1, q_block, d), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
