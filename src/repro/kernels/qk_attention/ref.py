"""Pure-jnp oracle: QKFormer token attention (inference form, no surrogate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qk_attention_ref(q: jax.Array, k: jax.Array,
                     threshold: float = 1.0) -> jax.Array:
    rowsum = q.astype(jnp.float32).sum(axis=-1, keepdims=True)
    mask = (rowsum >= threshold).astype(k.dtype)
    return mask * k
