"""Public wrapper for the fused QKFormer write-back attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..contract import KernelContract, declare
from .qk_attention import qk_attention_pallas

Array = jax.Array

CONTRACT = declare(KernelContract(
    family="qk_attention", ops=("qk_mask",), grad=True, emits_spikes=True,
    # [block_n, D] q + k tiles (int8) + rowsum column + masked-out tile,
    # D bounded by the corpus' widest head dim (128)
    vmem_bytes=lambda bm, bn, bk, packed: 256 * 128 * 3 + 256 * 4))


@functools.partial(jax.jit, static_argnames=("block_n", "threshold",
                                             "interpret"))
def qk_attention_fused(q: Array, k: Array, *, block_n: int = 256,
                       threshold: float = 1.0,
                       interpret: bool | None = None) -> Array:
    """Batched fused QKTA. q,k: [..., N, D] spikes -> masked K [..., N, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = q.shape
    n, d = shape[-2], shape[-1]
    bn = min(block_n, n)
    pad = (-n) % bn
    q2 = q.reshape(-1, n, d)
    k2 = k.reshape(-1, n, d)
    if pad:
        q2 = jnp.pad(q2, ((0, 0), (0, pad), (0, 0)))
        k2 = jnp.pad(k2, ((0, 0), (0, pad), (0, 0)))
    fn = functools.partial(qk_attention_pallas, block_n=bn,
                           threshold=threshold, interpret=interpret)
    out = jax.vmap(fn)(q2, k2)[:, :n, :]
    return out.reshape(shape).astype(k.dtype)
