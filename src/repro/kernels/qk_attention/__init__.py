from .ops import qk_attention_fused
from .ref import qk_attention_ref
