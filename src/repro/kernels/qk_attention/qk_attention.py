"""Fused on-the-fly QKFormer token attention (paper C4, Fig 5).

NEURAL folds the QK token attention into the PE->Spiking-Buffer write-back
path: as K spikes are produced, the attention register (built from Q's row
sums) gates them — no score matrix, no dedicated attention unit. The TPU
analogue is ONE kernel that, per (token-block, channel-block):

  1. reduces the Q spike block along channels (Row Summation, Fig 5 (2)) —
     accumulated across channel blocks in a VMEM scratch accumulator,
  2. thresholds it into the token mask (atten_reg),
  3. applies the mask to the K block as it is written back (Fig 5 (4)).

One HBM pass over Q and K, O(N*D) work, fp32 score accumulation in VMEM.
Grid: (tokens/bn) outer x (channels/bd) inner; the channel axis must be the
inner (fastest) axis so the row-sum accumulator for a token block is
complete before the mask is applied on the LAST channel step — the mask is
therefore applied in the same kernel invocation sweep (write-back fusion),
with K blocks revisited in the second sweep of the d-grid.

To keep a single pass (the hardware really does one), we instead compute the
FULL row sum per token block by reading Q[block, :] with a wide BlockSpec
(tokens x D fits VMEM for D <= 8192 at bn=256) — matching the atten_reg,
which also sees all channels of a token before K write-back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(q_ref, k_ref, o_ref, *, threshold: float):
    q = q_ref[...].astype(jnp.float32)            # [bn, D] spike block
    rowsum = q.sum(axis=1, keepdims=True)         # Row Summation (Fig 5 (2))
    mask = (rowsum >= threshold).astype(jnp.float32)   # atten_reg
    o_ref[...] = (mask * k_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)           # QK token mask (Fig 5 (4))


@functools.partial(jax.jit, static_argnames=("block_n", "threshold",
                                             "interpret"))
def qk_attention_pallas(q: Array, k: Array, *, block_n: int = 256,
                        threshold: float = 1.0,
                        interpret: bool = False) -> Array:
    """q, k: [N, D] binary spikes -> masked K [N, D] (k's dtype)."""
    n, d = q.shape
    assert k.shape == (n, d) and n % block_n == 0
    return pl.pallas_call(
        functools.partial(_kernel, threshold=threshold),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_n, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), k.dtype),
        interpret=interpret,
    )(q, k)
