"""Shared in-kernel tile accumulation for the sparsity-adaptive kernels.

Both vld-gated kernels (``spike_matmul`` and ``fused_pe``) land on the same
inner step: accumulate one (block_m x block_k) x-tile against one
(block_k x block_n) w-tile into a f32 accumulator — either the whole tile
in one MXU issue, or (two-level compression, ExSpike's irregular-sparsity
layer) stripe-by-stripe, where a "stripe" is one packed int32 word-column =
32 dense k-columns, and silent stripes are elided via the ``occ`` bitmap
from ``core.events.word_occupancy_map``.

The stripe loop is a PYTHON loop over the tile's word-columns (block_k/32
iterations, unrolled at trace time) with a ``pl.when`` per stripe, so the
skip is a predicated branch — cheap on silent stripes, and the sub-dots
stay MXU-shaped at (block_m, 32) @ (32, block_n).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.events import LANE_BITS, unpack_words


def accum_tile(o_ref, x_ref, w_ref, *, packed_in: bool,
               occ_bits=None) -> None:
    """o_ref += x_tile @ w_tile.

    ``x_ref``: (block_m, block_k) dense spikes or (block_m, block_k/32)
    int32 words when ``packed_in``. ``w_ref``: (block_k, block_n).
    ``occ_bits``: optional int32 scalar — the word-occupancy bitmap for THIS
    tile; when given, only occupied 32-column stripes touch the MXU.
    """
    if occ_bits is None:
        if packed_in:                  # decompress the K-tile in VMEM
            x = unpack_words(x_ref[...], jnp.float32)
        else:
            x = x_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
        return

    if packed_in:
        wpb = x_ref.shape[-1]
    else:
        assert x_ref.shape[-1] % LANE_BITS == 0, x_ref.shape
        wpb = x_ref.shape[-1] // LANE_BITS
    assert wpb <= LANE_BITS, (wpb, "occ bitmap covers <= 32 word-columns")

    for c in range(wpb):
        # arithmetic >> keeps bit 31 extractable (the &1 masks the sign fill)
        @pl.when(jnp.bitwise_and(jnp.right_shift(occ_bits, c), 1) != 0)
        def _stripe(c=c):
            if packed_in:
                xs = unpack_words(x_ref[:, c:c + 1], jnp.float32)
            else:
                xs = x_ref[:, c * LANE_BITS:(c + 1) * LANE_BITS]
                xs = xs.astype(jnp.float32)
            ws = w_ref[c * LANE_BITS:(c + 1) * LANE_BITS, :]
            o_ref[...] += jnp.dot(xs, ws.astype(jnp.float32),
                                  preferred_element_type=jnp.float32)


def accum_tile_t(o_ref, x_ref, g_ref, *, packed_in: bool,
                 occ_bits=None) -> None:
    """o_ref += x_tileᵀ @ g_tile — the weight-gradient contraction.

    ``x_ref``: (block_m, block_k) dense spikes or (block_m, block_k/32)
    int32 words when ``packed_in``. ``g_ref``: (block_m, block_n) f32
    cotangent. ``o_ref``: (block_k, block_n). ``occ_bits``: optional
    word-occupancy bitmap for THIS x-tile; a silent 32-column k-stripe of
    x contributes nothing to output ROWS [c*32, (c+1)*32), so the stripe's
    (32, block_m) @ (block_m, block_n) sub-dot is elided entirely.
    """
    g = g_ref[...].astype(jnp.float32)
    if occ_bits is None:
        if packed_in:
            x = unpack_words(x_ref[...], jnp.float32)
        else:
            x = x_ref[...].astype(jnp.float32)
        o_ref[...] += jnp.dot(x.T, g, preferred_element_type=jnp.float32)
        return

    if packed_in:
        wpb = x_ref.shape[-1]
    else:
        assert x_ref.shape[-1] % LANE_BITS == 0, x_ref.shape
        wpb = x_ref.shape[-1] // LANE_BITS
    assert wpb <= LANE_BITS, (wpb, "occ bitmap covers <= 32 word-columns")

    for c in range(wpb):
        @pl.when(jnp.bitwise_and(jnp.right_shift(occ_bits, c), 1) != 0)
        def _stripe(c=c):
            if packed_in:
                xs = unpack_words(x_ref[:, c:c + 1], jnp.float32)
            else:
                xs = x_ref[:, c * LANE_BITS:(c + 1) * LANE_BITS]
                xs = xs.astype(jnp.float32)
            o_ref[c * LANE_BITS:(c + 1) * LANE_BITS, :] += jnp.dot(
                xs.T, g, preferred_element_type=jnp.float32)
