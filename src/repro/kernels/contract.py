"""Kernel-contract declarations: what each family PROMISES the registry.

The sparsity/event machinery lives or dies on metadata contracts —
``vld_cnt`` block maps, ``occ`` word-occupancy bitmaps, packed pad lanes,
head lane masks — being honored at every ``(op, mode)`` boundary. Runtime
asserts (``check_block_contract``, the packed-pad-lane integrity guard)
catch violations *on the shapes that happen to run*; the static pass in
``repro.analysis.contracts`` proves them over the whole registry before
anything runs on hardware. This module is the declaration side of that
pass: each kernel family publishes ONE ``KernelContract`` stating which
registry ops it backs, which policy axes those ops support, and a static
VMEM-residency model derived from its BlockSpecs.

Declarations are plain data — this module imports nothing from the ops or
analysis layers, so a family's ``ops.py`` can declare at import time
without cycles. ``kernel_contracts()`` is the aggregation point the
verifier (and ``tools/neurallint.py``) walks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

#: spikes per packed int32 word (mirrors core.events.LANE_BITS without the
#: import — contract declarations must stay dependency-free)
LANE_BITS = 32

#: the seven kernel families; ``kernel_contracts`` imports each family's
#: ``ops`` module so a missing declaration is a hard error, not a silent
#: coverage gap
FAMILIES = ("spike_matmul", "lif_update", "fused_pe", "packed",
            "qk_attention", "flash_attention", "w2ttfs_pool")


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """One family's registry contract.

    family       : kernel-family package name under ``repro.kernels``.
    ops          : registry op names the family backs (the keys its
                   ``repro.ops.impls`` registrations use).
    modes        : base kernel modes registered per op ("reference"/"fused";
                   the "+grad" variants are derived from ``grad``).
    formats      : spike-map formats the ops accept/emit.
    skips        : byte-skip strategies the matmul-sweep ops accept.
    grad         : True when the family participates in the "+grad" axis
                   (every op must then also resolve "<mode>+grad").
    grad_ops     : when only a subset of ``ops`` participates in "+grad",
                   name them here (overrides ``grad`` per op; e.g. the
                   packed family's im2col/pool differentiate but
                   pack/unpack are inference-only format conversions).
    emits_spikes : True when outputs are SpikeTensors — the metadata-
                   propagation contract (vld_cnt present + shape-consistent
                   on every packed output) applies.
    head_blocked : True when the op takes ``heads=(h, dh)`` (the verifier
                   sweeps multi-head configs through it).
    vmem_bytes   : static VMEM-residency model derived from the kernel's
                   BlockSpecs: worst-case bytes resident per grid step for
                   a given tiling. Signature ``(block_m, block_n, block_k,
                   packed) -> int``; None for families whose working set is
                   not block-tiled (checked against the corpus shapes
                   instead).
    """
    family: str
    ops: tuple
    modes: tuple = ("reference", "fused")
    formats: tuple = ("dense", "packed")
    skips: tuple = ("dense",)
    grad: bool = False
    grad_ops: Optional[tuple] = None
    emits_spikes: bool = False
    head_blocked: bool = False
    vmem_bytes: Optional[Callable[[int, int, int, bool], int]] = None

    def gradient_ops(self) -> tuple:
        """The ops that must resolve both ``+grad`` registry modes."""
        if self.grad_ops is not None:
            return self.grad_ops
        return self.ops if self.grad else ()


_CONTRACTS: dict[str, KernelContract] = {}


def declare(contract: KernelContract) -> KernelContract:
    """Register a family's contract (called at family-ops import time)."""
    _CONTRACTS[contract.family] = contract
    return contract


def kernel_contracts() -> dict[str, KernelContract]:
    """All declared contracts, forcing every family's declaration in.

    Importing each family's ``ops`` module here (not at module import) keeps
    ``repro.kernels.contract`` importable without dragging Pallas in, while
    guaranteeing the verifier sees a contract for every family — an
    undeclared family raises instead of shrinking the sweep.
    """
    import importlib

    for fam in FAMILIES:
        importlib.import_module(f"repro.kernels.{fam}.ops")
        if fam not in _CONTRACTS:
            raise RuntimeError(
                f"kernel family {fam!r} declares no KernelContract — every "
                f"family must declare() one in its ops module so the static "
                f"verifier covers it")
    return dict(_CONTRACTS)


# ---------------------------------------------------------- VMEM tile models
def matmul_vmem(block_m: int, block_n: int, block_k: int,
                packed: bool) -> int:
    """Spike-matmul sweep residency: one x tile (packed words + the int8
    unpack scratch, or the int8 tile directly), one f32 w tile, one f32
    accumulator tile, plus the scalar-prefetched metadata row.  The family
    budget is the max over its forward and BACKWARD sweeps — the dx
    backward holds all-f32 tiles (cotangent + w + dx accumulator) plus the
    cached-current tile its fused surrogate factor re-reads."""
    if packed:
        x = block_m * (block_k // LANE_BITS) * 4 + block_m * block_k
    else:
        x = block_m * block_k
    meta = 4 * (block_k // 8 + 2)            # vld row + nact/kmap scalars
    fwd = x + block_k * block_n * 4 + block_m * block_n * 4 + meta
    bwd = (block_m * block_n * 4              # incoming cotangent tile
           + block_k * block_n * 4            # w tile (transposed read)
           + block_m * block_k * 4            # dx accumulator
           + block_m * block_n * 4            # cached membrane current
           + meta)
    return max(fwd, bwd)


def fused_pe_vmem(block_m: int, block_n: int, block_k: int,
                  packed: bool) -> int:
    """Fused PE adds to the matmul sweep: bias row, residual tile, LIF
    state tiles (v f32 + s int8), the Q tile for the write-back mask, the
    emitted spike tile (packed: words + vld row), and the f32 membrane-
    current tile the training forward writes back (``emit_current`` — the
    residual cache the event-skipped backward differentiates from)."""
    extra = (block_n * 4                      # bias
             + block_m * block_n * 4          # residual
             + block_m * block_n * 5          # v_prev f32 + s_prev int8
             + block_m * 128                  # q row block (lane-padded)
             + block_m * block_n              # emitted int8 spike tile
             + block_m * block_n * 4)         # emit_current f32 tile
    if packed:
        extra += block_m * (block_n // LANE_BITS) * 4 + 4 * (block_n // 8)
    return matmul_vmem(block_m, block_n, block_k, packed) + extra


def pack_vmem(block_m: int, block_n: int, block_k: int, packed: bool) -> int:
    """Pack/unpack trio: one int8 tile in, words + vld/occ rows out."""
    return (block_m * block_k
            + block_m * (block_k // LANE_BITS) * 4
            + 2 * 4 * (block_k // 8))
