"""Oracle for the fused PE kernel: the COMPOSED unfused reference chain.

This is, by construction, the exact pipeline the fused kernel replaces:
``spike_matmul_ref`` -> (+bias/residual) -> ``lif_update_ref`` ->
``qk_attention_ref`` -> ``block_count_map_2d`` — each stage the oracle of
one of the four kernels the fusion eliminates the HBM round-trips between.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.events import block_count_map_2d, pad_to_blocks
from ..lif_update.ref import lif_update_ref
from ..qk_attention.ref import qk_attention_ref
from ..spike_matmul.ref import spike_matmul_ref

Array = jax.Array


def fused_pe_ref(x: Array, w: Array, *,
                 bias: Array | None = None,
                 residual: Array | None = None,
                 v_prev: Array | None = None,
                 s_prev: Array | None = None,
                 q: Array | None = None,
                 tau: float = 0.5, v_th: float = 1.0,
                 soft_reset: bool = False, qk_threshold: float = 1.0,
                 block_m: int = 128, block_n: int = 128,
                 heads: tuple[int, int] | None = None
                 ) -> tuple[Array, Optional[Array], Array]:
    """Returns (spikes int8, v_next f32 | None, vld_next int32).

    v_next is None when no state was passed (deployed T=1 form), matching
    the kernel's stateless mode which skips the HBM write entirely.
    ``heads=(h, dh)`` applies the QK mask per head block: one row sum (and
    one threshold decision) per head over Q's head slice, gating only that
    head's dh output columns.
    """
    cur = spike_matmul_ref(x, w)
    if bias is not None:
        cur = cur + bias.reshape(1, -1).astype(jnp.float32)
    if residual is not None:
        cur = cur + residual.astype(jnp.float32)
    stateless = v_prev is None
    vp = jnp.zeros_like(cur) if stateless else v_prev
    sp = jnp.zeros_like(cur) if s_prev is None else s_prev
    spk, v_next = lif_update_ref(cur, vp, sp, tau=tau, v_th=v_th,
                                 soft_reset=soft_reset)
    if q is not None and heads is not None:
        h, dh = heads
        assert spk.shape[-1] == h * dh, (spk.shape, heads)
        rs = q[..., :h * dh].astype(jnp.float32).reshape(
            *q.shape[:-1], h, dh).sum(axis=-1)
        mask = (rs >= qk_threshold).astype(spk.dtype)
        spk = (spk.reshape(*spk.shape[:-1], h, dh)
               * mask[..., None]).reshape(spk.shape)
    elif q is not None:
        spk = qk_attention_ref(q, spk, threshold=qk_threshold)
    vld_next = block_count_map_2d(pad_to_blocks(spk, block_m, block_n),
                                  block_m, block_n)
    return spk, (None if stateless else v_next), vld_next
