from .fused_pe import fused_pe_pallas
from .ops import FusedPEOut, fused_pe, fused_pe_layer
from .ref import fused_pe_ref

__all__ = ["FusedPEOut", "fused_pe", "fused_pe_layer", "fused_pe_pallas",
           "fused_pe_ref"]
