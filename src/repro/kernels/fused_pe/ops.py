"""Public wrappers for the fused PE kernel: padding, metadata plumbing, and
the multi-timestep scan used by the deployed models.

``fused_pe``      — one fused layer over 2-D operands (pad + dispatch).
``fused_pe_layer``— [T, M, K] spike trains: T=1 runs the stateless deployed
                    form; T>1 scans the stateful kernel carrying (v, s).

Spike operands (``x``, ``q``, ``residual``) may be dense arrays OR
``PackedSpikes`` (the bit-packed HBM interchange format), and
``out_format="packed"`` makes the emitted spike map leave packed too — a
chained stack of layers then never materializes an unpacked spike tensor
in HBM: each PackedSpikes output carries both the 32x-compressed words and
the ``vld_cnt`` routing metadata the next kernel's block skip consumes.
(The pre-policy ``pack_out`` boolean is still accepted through the
``repro.ops.compat`` deprecation shim; prefer ``out_format`` or, one level
up, a packed ``ExecutionPolicy`` on ``repro.ops.fused_pe``.)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from ...core.events import (LANE_BITS, PackedSpikes, pad_to_blocks,
                            vld_or_compute, word_occupancy_map_dense)
from ..contract import KernelContract, declare, fused_pe_vmem
from ..spike_matmul.ops import check_block_contract, check_skip
from .fused_pe import fused_pe_pallas

Array = jax.Array
Spikes = Union[Array, PackedSpikes]

CONTRACT = declare(KernelContract(
    family="fused_pe", ops=("fused_pe", "fused_pe_layer", "dense_lif"),
    skips=("dense", "gated", "two_level"), grad=True,
    grad_ops=("fused_pe", "fused_pe_layer", "dense_lif"),
    emits_spikes=True,
    head_blocked=True, vmem_bytes=fused_pe_vmem))


def _out_format(pack_out: Optional[bool], out_format: Optional[str],
                owner: str) -> str:
    from ...ops.compat import resolve_out_format

    return resolve_out_format(pack_out, out_format, owner=owner)


class FusedPEOut(NamedTuple):
    """One fused layer's outputs.

    spikes   : [M, N] int8 emitted (post-QK-mask) spike map — or, with
               ``pack_out``, a PackedSpikes whose vld_cnt IS vld_next
    v_next   : [M, N] f32 or None — membrane state (stateful calls only)
    vld_next : [M/bm, N/bn] int32 or None — the EMITTED spikes' block count
               map over the PADDED grid; feed it to the next fused_pe /
               spike_matmul call (same block sizes) as ``vld_cnt`` to skip
               that layer's metadata pass.
    current  : [M, N] f32 or None — the post-bias/-residual membrane
               current, emitted only with ``emit_current`` (the residual
               cache the event-skipped backward differentiates from).
    """
    spikes: Spikes
    v_next: Optional[Array]
    vld_next: Optional[Array]
    current: Optional[Array] = None


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_pe(x: Spikes, w: Array, *,
             bias: Array | None = None,
             residual: Spikes | None = None,
             v_prev: Array | None = None,
             s_prev: Array | None = None,
             q: Spikes | None = None,
             vld_cnt: Array | None = None,
             tau: float = 0.5, v_th: float = 1.0, soft_reset: bool = False,
             qk_threshold: float = 1.0,
             block_m: int = 128, block_n: int = 128, block_k: int = 128,
             emit_vld: bool = True, emit_current: bool = False,
             out_format: str | None = None,
             pack_out: bool | None = None, skip: str = "dense",
             heads: tuple[int, int] | None = None,
             interpret: bool | None = None) -> FusedPEOut:
    """One fused PE layer: spikes/v_next/vld_next = PE(x, w, ...).

    x: [M, K] spikes (any dtype; nonzero == event), dense activations, or a
    PackedSpikes. w: [K, N]. Optional bias [N], residual [M, N] (added to
    the membrane current; a PackedSpikes residual is a binary shortcut
    unpacked in VMEM), LIF state (v_prev [M, N] f32 + s_prev [M, N]), and Q
    spikes [M, Dq] (dense or packed — packed row sums are popcounts) for
    the QKFormer write-back mask. ``vld_cnt`` is the [M/bm, K/bk] input
    metadata — pass a previous layer's ``vld_next`` to chain the on-the-fly
    dataflow; leave None to compute it here (a PackedSpikes x already
    carries it). ``out_format="packed"`` emits the spike map bit-packed
    (the deprecated boolean form routes through ``repro.ops.compat``).
    ``skip`` selects the byte-skip strategy ("dense" | "gated" |
    "two_level" — see ``repro.kernels.spike_matmul.ops.SKIP_MODES``).
    ``heads=(h, dh)`` computes the QK mask per head block instead of per
    whole row (multi-head Fig-5 fusion; requires ``w.shape[1] == h*dh``).
    ``emit_current`` returns the post-bias/-residual membrane current in
    ``FusedPEOut.current`` — the backward's residual cache.
    """
    fmt = _out_format(pack_out, out_format, "fused_pe")
    return _fused_pe(x, w, bias=bias, residual=residual, v_prev=v_prev,
                     s_prev=s_prev, q=q, vld_cnt=vld_cnt, tau=tau, v_th=v_th,
                     soft_reset=soft_reset, qk_threshold=qk_threshold,
                     block_m=block_m, block_n=block_n, block_k=block_k,
                     emit_vld=emit_vld, emit_current=emit_current,
                     out_format=fmt, skip=skip,
                     heads=heads, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tau", "v_th", "soft_reset",
                                             "qk_threshold", "block_m",
                                             "block_n", "block_k",
                                             "emit_vld", "emit_current",
                                             "out_format",
                                             "skip", "heads", "interpret"))
def _fused_pe(x: Spikes, w: Array, *,
              bias: Array | None = None,
              residual: Spikes | None = None,
              v_prev: Array | None = None,
              s_prev: Array | None = None,
              q: Spikes | None = None,
              vld_cnt: Array | None = None,
              tau: float = 0.5, v_th: float = 1.0, soft_reset: bool = False,
              qk_threshold: float = 1.0,
              block_m: int = 128, block_n: int = 128, block_k: int = 128,
              emit_vld: bool = True, emit_current: bool = False,
              out_format: str = "dense",
              skip: str = "dense",
              heads: tuple[int, int] | None = None,
              interpret: bool | None = None) -> FusedPEOut:
    """Jitted core of ``fused_pe`` (all shims resolved: ``out_format`` is a
    plain static string here)."""
    check_skip(skip)
    pack_out = out_format == "packed"
    if interpret is None:
        interpret = not _on_tpu()
    packed_in = isinstance(x, PackedSpikes)
    occ = None
    if packed_in:
        check_block_contract(x, block_m, block_k, "fused_pe x")
        assert len(x.shape) == 2, "fused_pe takes a 2-D packed operand"
        m0, k0 = x.shape
        if skip == "two_level":
            x = x.with_occ()
            occ = x.occ
        xi = x.words
        vld = x.vld_cnt if vld_cnt is None else vld_cnt.astype(jnp.int32)
        kp = xi.shape[1] * LANE_BITS
    else:
        m0, k0 = x.shape
        xi = pad_to_blocks(x.astype(jnp.int8) if x.dtype == jnp.bool_ else x,
                           block_m, block_k)
        vld = vld_or_compute(xi, vld_cnt, block_m, block_k)
        if skip == "two_level":
            occ = word_occupancy_map_dense(xi, block_m, block_k)
        kp = xi.shape[1]
    n0 = w.shape[1]
    wp = pad_to_blocks(w, block_k, block_n)
    if wp.shape[0] < kp:
        wp = jnp.pad(wp, ((0, kp - wp.shape[0]), (0, 0)))

    def pad_mn(t, dtype=None):
        t = pad_to_blocks(t, block_m, block_n)
        return t if dtype is None else t.astype(dtype)

    bp = None
    if bias is not None:
        bp = jnp.pad(bias.reshape(1, n0).astype(jnp.float32),
                     ((0, 0), (0, (-n0) % block_n)))
    packed_res = isinstance(residual, PackedSpikes)
    if packed_res:
        check_block_contract(residual, block_m, block_n, "fused_pe residual")
        assert tuple(residual.shape) == (m0, n0), (residual.shape, m0, n0)
        rp = residual.words
    else:
        rp = pad_mn(residual, jnp.float32) if residual is not None else None
    vp = pad_mn(v_prev, jnp.float32) if v_prev is not None else None
    sp = pad_mn(s_prev, jnp.int8) if s_prev is not None else None
    packed_q = isinstance(q, PackedSpikes)
    if packed_q:
        if q.block_m != block_m:
            raise ValueError(
                f"fused_pe q was packed on block_m={q.block_m} but the "
                f"kernel is tiling on block_m={block_m}; its row blocks "
                f"must match the output tiling.")
        assert q.shape[-2] == m0, (q.shape, m0)
        qp = q.words
    elif q is not None:
        # pad Q rows to the M grid and channels to the lane width; zero
        # padding never changes a row sum
        qp = pad_to_blocks(q.astype(jnp.int8), block_m, 128)
    else:
        qp = None

    spikes, v_next, vld_next, current = fused_pe_pallas(
        xi, wp, vld, bp, rp, vp, sp, qp, occ,
        tau=tau, v_th=v_th, soft_reset=soft_reset, qk_threshold=qk_threshold,
        block_m=block_m, block_n=block_n, block_k=block_k,
        emit_vld=emit_vld or pack_out, emit_current=emit_current,
        m_valid=m0, n_valid=n0,
        packed_in=packed_in, packed_q=packed_q, packed_residual=packed_res,
        packed_out=pack_out, skip=skip, heads=heads, interpret=interpret)
    if pack_out:
        spikes = PackedSpikes(spikes, vld_next, (m0, n0), block_m, block_n)
    else:
        spikes = spikes[:m0, :n0]
    if v_next is not None:
        v_next = v_next[:m0, :n0]
    if current is not None:
        current = current[:m0, :n0]
    return FusedPEOut(spikes, v_next, vld_next, current)


def _stack_packed(pss: list[PackedSpikes]) -> PackedSpikes:
    first = pss[0]
    return PackedSpikes(jnp.stack([p.words for p in pss]),
                        jnp.stack([p.vld_cnt for p in pss]),
                        (len(pss), *first.shape),
                        first.block_m, first.block_k)


def fused_pe_layer(spk: Spikes, w: Array, *,
                   bias: Array | None = None,
                   residual: Spikes | None = None,
                   q: Spikes | None = None,
                   vld_cnt: Array | None = None,
                   tau: float = 0.5, v_th: float = 1.0,
                   soft_reset: bool = False, qk_threshold: float = 1.0,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, out_format: str | None = None,
                   pack_out: bool | None = None, skip: str = "dense",
                   heads: tuple[int, int] | None = None,
                   interpret: bool | None = None
                   ) -> tuple[Spikes, Optional[Array]]:
    """Multi-timestep fused layer over [T, M, K] inputs (dense or packed).

    T=1 (the paper's deployed mode) is a single stateless kernel call —
    no membrane state read or written. T>1 scans the stateful kernel over
    time carrying (v, s), matching ``lif_multistep``'s semantics with
    v[0] = 0, s[0] = 0.

    ``residual`` / ``q`` / ``vld_cnt`` are per-timestep ([T, ...]) or None.
    ``heads=(h, dh)`` makes the QK mask head-blocked (see ``fused_pe``);
    for T>1 the outside-mask path reduces Q per head slice the same way.
    ``out_format="packed"`` returns the emitted spikes as a [T, ...]
    PackedSpikes; for T>1 the stateful scan needs the dense per-step spikes
    for the reset carry, so the pack happens on write-out of each step's
    EMITTED map. Returns (spikes [T, M, N] int8 | PackedSpikes,
    vld_next [T, M/bm, N/bn] int32).
    """
    fmt = _out_format(pack_out, out_format, "fused_pe_layer")
    packed_out = fmt == "packed"
    t, m, _ = spk.shape
    n = w.shape[1]
    kw = dict(bias=bias, tau=tau, v_th=v_th, soft_reset=soft_reset,
              qk_threshold=qk_threshold, block_m=block_m, block_n=block_n,
              block_k=block_k, skip=skip, interpret=interpret)

    if t == 1:
        out = fused_pe(spk[0], w, residual=None if residual is None
                       else residual[0], q=None if q is None else q[0],
                       vld_cnt=None if vld_cnt is None else vld_cnt[0],
                       out_format=fmt, heads=heads, **kw)
        if packed_out:
            return _stack_packed([out.spikes]), out.vld_next[None]
        return out.spikes[None], out.vld_next[None]

    def step(carry, spk_t, res_t, q_t, vld_t):
        v, s = carry
        # T>1 with a QK mask: the LIF state must carry the layer's OWN
        # (pre-mask) spikes — run the stateful kernel unmasked and gate
        # outside; vld_next is then computed on the masked map instead of
        # in-kernel. The deployed T=1 path above keeps the full fusion.
        out = fused_pe(spk_t, w, residual=res_t, vld_cnt=vld_t,
                       v_prev=v, s_prev=s, emit_vld=q_t is None, **kw)
        emitted, vld_next = out.spikes, out.vld_next
        if q_t is not None:
            if isinstance(q_t, PackedSpikes):
                from ..packed import unpack_spikes
                q_t = unpack_spikes(q_t)
            if heads is None:
                rowsum = q_t.astype(jnp.float32).sum(axis=-1, keepdims=True)
                emitted = emitted * (rowsum >= qk_threshold).astype(
                    emitted.dtype)
            else:
                hq, dh = heads
                rs = q_t[:, :hq * dh].astype(jnp.float32).reshape(
                    -1, hq, dh).sum(axis=-1)
                mask = (rs >= qk_threshold).astype(emitted.dtype)
                emitted = (emitted.reshape(-1, hq, dh)
                           * mask[:, :, None]).reshape(emitted.shape)
            vld_next = vld_or_compute(
                pad_to_blocks(emitted, block_m, block_n), None,
                block_m, block_n)
        return (out.v_next, out.spikes), emitted, vld_next

    # Python loop over the (small, static) T axis — optional operands may be
    # None, which lax.scan xs cannot carry
    spikes_ts, vld_ts = [], []
    carry = (jnp.zeros((m, n), jnp.float32), jnp.zeros((m, n), jnp.int8))
    for ti in range(t):
        carry, spk_t, vld_t = step(
            carry, spk[ti],
            None if residual is None else residual[ti],
            None if q is None else q[ti],
            None if vld_cnt is None else vld_cnt[ti])
        spikes_ts.append(spk_t)
        vld_ts.append(vld_t)
    if packed_out:
        from ..packed import pack_spikes
        packed = [pack_spikes(s, block_m=block_m, block_k=block_n)
                  for s in spikes_ts]
        return _stack_packed(packed), jnp.stack(vld_ts)
    return jnp.stack(spikes_ts), jnp.stack(vld_ts)
