"""Fused PE dataflow kernel (paper Fig 3 + Fig 5 in ONE Pallas pass).

NEURAL's central claim is that a PE executes the whole per-layer dataflow —
event-gated MAC accumulation, LIF membrane update, and the QKFormer token
attention — "on the fly ... within the baseline computing flow without
requiring dedicated hardware units". Our previous reproduction ran that
chain as four separate kernels with full HBM round-trips between stages:

    spike_matmul -> [f32 pre-act HBM] -> lif_update -> [int8 spikes HBM]
                 -> qk_attention      -> [spikes HBM] -> block_count_map_2d

This kernel is the TPU realization of the paper's fusion: per output tile,

  1. accumulate the event-skipped spike matmul over the K grid axis using
     the scalar-prefetched ``vld_cnt`` map (PipeSDA metadata, paper C3) —
     ``@pl.when(vld_cnt > 0)`` skips silent blocks exactly as
     ``spike_matmul`` does (Fig 3 (2)/(3): SDU FIFO + MAC gating);
  2. on the LAST K step, add bias / residual current and apply the LIF
     membrane update in-register (Fig 3 (4): tau decay, threshold,
     hard/soft reset) — the f32 pre-activation NEVER touches HBM;
  3. optionally gate the emitted spikes with the QK token mask computed
     from Q's row sums (Fig 5 (2) atten_reg -> (4) write-back fusion);
  4. emit the NEXT layer's ``vld_cnt`` block-count map as a second output,
     so layer L produces layer L+1's PipeSDA routing metadata on the fly
     instead of a separate reduction pass re-reading the spikes from HBM.

Event COMPRESSION (the ``packed_*`` static flags): every spike operand can
arrive bit-packed — 32 spikes per int32 lane, the ``PackedSpikes`` HBM
format — and the emitted spike map can leave bit-packed. Packed K-tiles /
residual tiles are unpacked in VMEM right before use; a packed Q tile's row
sum is a popcount (no unpack at all); the packed output is built from the
in-register spike tile during write-back. With ``packed_in + packed_out``
a chained layer moves ~1/8th the spike bytes over HBM in each direction
while producing bit-identical spikes.

Inputs (optional operands selected by static flags):
  x        [M, K]  int8 spikes (or dense activations; only zero-blocks skip)
           packed_in:  [M, K/32] int32 words
  w        [K, N]  weights
  bias     [1, N]  f32  (with_bias)    — F&Q-folded BN bias
  residual [M, N]  f32  (with_residual)— shortcut membrane current (MS-ResNet)
           packed_residual: [M, N/32] int32 words (binary spike shortcut)
  v_prev   [M, N]  f32  (with_state)   — membrane state for T>1
  s_prev   [M, N]  int8 (with_state)   — previous-step spikes for hard reset
  q        [M, Dq] int8 (apply_qk)     — Q spikes; row-sum -> token mask
           packed_q: [M, Dq/32] int32 words; row-sum == popcount row-sum

Outputs:
  spikes   [M, N]        int8; packed_out: [M, N/32] int32 words
  v_next   [M, N]        f32   (with_state only — T=1 deployed mode skips
                                the write entirely: s = H(I - v_th))
  vld_next [M/bm, N/bn]  int32 (emit_vld) — per-tile nonzero count of the
                                EMITTED (post-mask) spikes

Grid is (M/bm, N/bn, K/bk) with K innermost; an f32 VMEM scratch tile is
the accumulator (it persists across the sequential K sweep). ``m_valid`` /
``n_valid`` mask padded rows/cols out of the spike map and the emitted
count map, so padding stays inert for ANY bias/threshold values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.events import (LANE_BITS, compact_kmap, head_lane_masks,
                            pack_words, unpack_words)
from ..gating import accum_tile

Array = jax.Array


def _make_kernel(*, tau: float, v_th: float, soft_reset: bool,
                 qk_threshold: float, with_bias: bool, with_residual: bool,
                 with_state: bool, apply_qk: bool, emit_vld: bool,
                 emit_current: bool,
                 m_valid: int, n_valid: int, block_m: int, block_n: int,
                 packed_in: bool, packed_q: bool, packed_residual: bool,
                 packed_out: bool, skip: str = "dense",
                 heads: tuple[int, int] | None = None):
    def kernel(*allrefs):
        # scalar-prefetch block: vld map (dense) or the compacted routing
        # tables (gated / two_level) from core.events.compact_kmap
        occ_ref = None
        if skip == "dense":
            vld_ref, *refs = allrefs
        elif skip == "gated":
            nact_ref, kmap_ref, *refs = allrefs
        else:
            nact_ref, kmap_ref, occ_ref, *refs = allrefs
        it = iter(refs)
        x_ref = next(it)
        w_ref = next(it)
        b_ref = next(it) if with_bias else None
        r_ref = next(it) if with_residual else None
        v_ref = next(it) if with_state else None
        s_ref = next(it) if with_state else None
        q_ref = next(it) if apply_qk else None
        spike_ref = next(it)
        vout_ref = next(it) if with_state else None
        cnt_ref = next(it) if emit_vld else None
        cur_ref = next(it) if emit_current else None
        acc_ref = next(it)

        i = pl.program_id(0)
        j = pl.program_id(1)
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        if skip == "dense":
            # event skip: silent block -> no MXU (bytes still stream)
            gate = vld_ref[i, k] > 0
        else:
            # steps past nact[i] revisit the last active block index, so
            # the BlockSpec never changes -> no DMA; this skips the MXU
            gate = k < nact_ref[i]

        @pl.when(gate)
        def _accum():
            occ_bits = (occ_ref[i, kmap_ref[i, k]]
                        if skip == "two_level" else None)
            accum_tile(acc_ref, x_ref, w_ref, packed_in=packed_in,
                       occ_bits=occ_bits)

        @pl.when(k == pl.num_programs(2) - 1)
        def _writeback():
            cur = acc_ref[...]
            if with_bias:
                cur = cur + b_ref[...].astype(jnp.float32)
            if with_residual:
                if packed_residual:  # binary spike shortcut, stored packed
                    cur = cur + unpack_words(r_ref[...], jnp.float32)
                else:
                    cur = cur + r_ref[...].astype(jnp.float32)
            if emit_current:
                # residual cache for the backward: the post-bias/-residual
                # membrane current leaves ONCE, instead of the vjp
                # re-running the whole event-gated matmul from its inputs
                cur_ref[...] = cur
            if with_state:
                v_prev = v_ref[...].astype(jnp.float32)
                s_prev = s_ref[...].astype(jnp.float32)
                v = tau * v_prev * (1.0 - s_prev) + cur
            else:                    # deployed T=1: v[0]=0 -> v = I
                v = cur
            spk = (v >= v_th).astype(jnp.float32)
            if with_state:
                if soft_reset:
                    vout_ref[...] = v - v_th * spk
                else:
                    vout_ref[...] = v * (1.0 - spk)
            if apply_qk and heads is None:
                # Fig 5: atten_reg gates the write-back (whole-row mask)
                if packed_q:         # row sum of packed spikes == popcount
                    rowsum = jnp.sum(
                        jax.lax.population_count(q_ref[...]), axis=1,
                        keepdims=True).astype(jnp.float32)
                else:
                    rowsum = q_ref[...].astype(jnp.float32).sum(
                        axis=1, keepdims=True)
                spk = spk * (rowsum >= qk_threshold).astype(jnp.float32)
            elif apply_qk:
                # head-blocked Fig 5: one atten_reg per head — per-head row
                # sums over Q's head slice gate only that head's output
                # columns. Static per-head slices / lane masks keep this on
                # the VPU (no gathers); pad columns map to no head.
                hq, dh = heads
                if packed_q:
                    words = q_ref[...]
                    sels = head_lane_masks(hq, dh,
                                           words.shape[1] * LANE_BITS)
                cols = (jax.lax.broadcasted_iota(
                    jnp.int32, (block_m, block_n), 1) + j * block_n)
                head_of_col = cols // dh
                gate = jnp.zeros((block_m, block_n), jnp.float32)
                for hh in range(hq):
                    if packed_q:     # per-head popcount over the word lanes
                        rs = jnp.sum(jax.lax.population_count(
                            words & sels[hh][None, :]), axis=1,
                            keepdims=True).astype(jnp.float32)
                    else:
                        rs = q_ref[:, hh * dh:(hh + 1) * dh].astype(
                            jnp.float32).sum(axis=1, keepdims=True)
                    gate = gate + ((rs >= qk_threshold)
                                   & (head_of_col == hh)
                                   ).astype(jnp.float32)
                spk = spk * gate
            if m_valid % block_m or n_valid % block_n:
                rows = (jax.lax.broadcasted_iota(
                    jnp.int32, (block_m, block_n), 0) + i * block_m)
                cols = (jax.lax.broadcasted_iota(
                    jnp.int32, (block_m, block_n), 1) + j * block_n)
                spk = spk * ((rows < m_valid) & (cols < n_valid)
                             ).astype(jnp.float32)
            if packed_out:           # compress in-register before the write
                spike_ref[...] = pack_words(spk)
            else:
                spike_ref[...] = spk.astype(spike_ref.dtype)
            if emit_vld:             # on-the-fly next-layer PipeSDA metadata
                cnt_ref[0, 0] = jnp.sum(spk).astype(jnp.int32)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("tau", "v_th", "soft_reset",
                                    "qk_threshold", "block_m", "block_n",
                                    "block_k", "emit_vld", "emit_current",
                                    "m_valid",
                                    "n_valid", "packed_in", "packed_q",
                                    "packed_residual", "packed_out",
                                    "skip", "heads", "interpret"))
def fused_pe_pallas(x: Array, w: Array, vld_cnt: Array,
                    bias: Array | None = None,
                    residual: Array | None = None,
                    v_prev: Array | None = None,
                    s_prev: Array | None = None,
                    q: Array | None = None,
                    occ: Array | None = None, *,
                    tau: float = 0.5, v_th: float = 1.0,
                    soft_reset: bool = False, qk_threshold: float = 1.0,
                    block_m: int = 128, block_n: int = 128,
                    block_k: int = 128, emit_vld: bool = True,
                    emit_current: bool = False,
                    m_valid: int | None = None, n_valid: int | None = None,
                    packed_in: bool = False, packed_q: bool = False,
                    packed_residual: bool = False, packed_out: bool = False,
                    skip: str = "dense",
                    heads: tuple[int, int] | None = None,
                    interpret: bool = False):
    """Block-aligned core. All shapes must already be padded to the blocks;
    use ``repro.kernels.fused_pe.ops.fused_pe`` for the padding wrapper.
    ``m_valid``/``n_valid`` are the pre-padding extents: spikes and counts
    in the padded margin are forced to zero (bias alone could otherwise
    fire pad rows). The ``packed_*`` flags select the bit-packed layout for
    the corresponding spike operand / output (int32 words along the packed
    axis, 32 spikes per lane).

    ``skip`` selects the byte-skip strategy: ``"dense"`` streams every tile
    and gates the MXU on ``vld_cnt``; ``"gated"`` walks the compacted
    non-silent block list (silent x/w tiles never DMA'd); ``"two_level"``
    additionally elides silent 32-column stripes inside active tiles via
    the ``occ`` word-occupancy bitmap (required for that mode).

    ``heads=(h, dh)`` makes the QK write-back HEAD-BLOCKED: Q and the
    output are treated as ``h`` head blocks of width ``dh`` each, the row
    sum / threshold mask is computed per head (packed Q: per-head
    popcounts through static lane masks), and each head's mask gates only
    its own output columns — the multi-head form of the Fig-5 fusion.
    Requires ``n_valid == h * dh`` (the output must be exactly the
    head-concatenated map). ``None`` keeps the whole-row mask.

    ``emit_current`` additionally emits the post-bias/-residual membrane
    current as an f32 [M, N] output — the residual cache the event-skipped
    backward differentiates from instead of recomputing the matmul.

    Returns (spikes, v_next | None, vld_next | None, current | None).
    """
    m = x.shape[0]
    k = x.shape[1] * LANE_BITS if packed_in else x.shape[1]
    k2, n = w.shape
    assert k == k2 and m % block_m == 0 and k % block_k == 0 \
        and n % block_n == 0, (x.shape, w.shape, block_m, block_n, block_k)
    if packed_in or packed_out or packed_residual:
        assert block_k % LANE_BITS == 0 and block_n % LANE_BITS == 0
    with_state = v_prev is not None
    assert (s_prev is not None) == with_state
    assert skip in ("dense", "gated", "two_level"), skip
    if heads is not None:
        assert q is not None, "heads=(h, dh) requires the q operand"
        assert heads[0] * heads[1] == (n_valid or n), \
            (heads, n_valid or n)   # output == head-concatenated map
    grid = (m // block_m, n // block_n, k // block_k)

    kern = _make_kernel(
        tau=tau, v_th=v_th, soft_reset=soft_reset, qk_threshold=qk_threshold,
        with_bias=bias is not None, with_residual=residual is not None,
        with_state=with_state, apply_qk=q is not None, emit_vld=emit_vld,
        emit_current=emit_current,
        m_valid=m_valid or m, n_valid=n_valid or n,
        block_m=block_m, block_n=block_n, packed_in=packed_in,
        packed_q=packed_q, packed_residual=packed_residual,
        packed_out=packed_out, skip=skip, heads=heads)

    # scalar-prefetch set: vld map (dense) or the compacted routing tables
    # (gated / two_level); index maps receive the refs as trailing args
    if skip == "dense":
        scalars = (vld_cnt,)

        def x_idx(i, j, kk, *refs):
            return (i, kk)

        def w_idx(i, j, kk, *refs):
            return (kk, j)
    else:
        nact, kmap = compact_kmap(vld_cnt)
        if skip == "two_level":
            assert occ is not None, "two_level gating needs the occ bitmap"
            scalars = (nact, kmap, occ)
        else:
            scalars = (nact, kmap)

        def x_idx(i, j, s, nact_ref, kmap_ref, *rest):
            return (i, kmap_ref[i, s])

        def w_idx(i, j, s, nact_ref, kmap_ref, *rest):
            return (kmap_ref[i, s], j)

    x_bk = block_k // LANE_BITS if packed_in else block_k
    in_specs = [
        pl.BlockSpec((block_m, x_bk), x_idx),
        pl.BlockSpec((block_k, block_n), w_idx),
    ]
    operands = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, kk, *refs: (0, j)))
        operands.append(bias.reshape(1, n))
    if residual is not None:
        r_bn = block_n // LANE_BITS if packed_residual else block_n
        in_specs.append(pl.BlockSpec((block_m, r_bn),
                                     lambda i, j, kk, *refs: (i, j)))
        operands.append(residual)
    if with_state:
        in_specs += [pl.BlockSpec((block_m, block_n),
                                  lambda i, j, kk, *refs: (i, j))] * 2
        operands += [v_prev, s_prev]
    if q is not None:
        dq = q.shape[1]
        in_specs.append(pl.BlockSpec((block_m, dq),
                                     lambda i, j, kk, *refs: (i, 0)))
        operands.append(q)

    if packed_out:
        out_shape = [jax.ShapeDtypeStruct((m, n // LANE_BITS), jnp.int32)]
        out_specs = [pl.BlockSpec((block_m, block_n // LANE_BITS),
                                  lambda i, j, kk, *refs: (i, j))]
    else:
        out_shape = [jax.ShapeDtypeStruct((m, n), jnp.int8)]
        out_specs = [pl.BlockSpec((block_m, block_n),
                                  lambda i, j, kk, *refs: (i, j))]
    if with_state:
        out_shape.append(jax.ShapeDtypeStruct((m, n), jnp.float32))
        out_specs.append(pl.BlockSpec((block_m, block_n),
                                      lambda i, j, kk, *refs: (i, j)))
    if emit_vld:
        out_shape.append(jax.ShapeDtypeStruct(
            (m // block_m, n // block_n), jnp.int32))
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda i, j, kk, *refs: (i, j)))
    if emit_current:
        out_shape.append(jax.ShapeDtypeStruct((m, n), jnp.float32))
        out_specs.append(pl.BlockSpec((block_m, block_n),
                                      lambda i, j, kk, *refs: (i, j)))

    outs = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(scalars),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(*scalars, *operands)

    outs = list(outs)
    spikes = outs.pop(0)
    v_next = outs.pop(0) if with_state else None
    vld_next = outs.pop(0) if emit_vld else None
    current = outs.pop(0) if emit_current else None
    return spikes, v_next, vld_next, current
