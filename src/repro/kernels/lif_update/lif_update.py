"""Fused LIF membrane update (paper Fig 3 (4): the PE's LIF unit).

One elementwise pass computing

    v      = tau * v_prev * (1 - s_prev) + I        (hard reset)
    spike  = v >= v_th
    v_next = v * (1 - spike)            [or v - v_th*spike  (soft reset)]

Unfused, this chain costs 3 HBM round-trips over [B, D]-sized tensors (the
op is purely memory-bound); fused it reads (I, v_prev, s_prev) once and
writes (spike, v_next) once — the minimum traffic. Spikes are emitted as
int8 events (the 8-32x activation-compression that makes event-driven
execution pay on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(i_ref, v_ref, s_ref, spike_ref, vout_ref, *,
            tau: float, v_th: float, soft_reset: bool):
    cur = i_ref[...].astype(jnp.float32)
    v_prev = v_ref[...].astype(jnp.float32)
    s_prev = s_ref[...].astype(jnp.float32)
    v = tau * v_prev * (1.0 - s_prev) + cur
    spk = (v >= v_th)
    spike_ref[...] = spk.astype(spike_ref.dtype)
    if soft_reset:
        v_next = v - v_th * spk.astype(jnp.float32)
    else:
        v_next = v * (1.0 - spk.astype(jnp.float32))
    vout_ref[...] = v_next.astype(vout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tau", "v_th", "soft_reset",
                                             "block", "interpret"))
def lif_update_pallas(current: Array, v_prev: Array, s_prev: Array, *,
                      tau: float = 0.5, v_th: float = 1.0,
                      soft_reset: bool = False, block: int = 1024,
                      interpret: bool = False) -> tuple[Array, Array]:
    """All inputs [M, D] (flatten first). Returns (spikes int8, v_next f32).

    M need not be a multiple of ``block``: inputs are zero-padded to the
    block grid and outputs sliced back (padded rows are inert — zero current
    against zero state never fires for v_th > 0).
    """
    from ...core.events import pad_to_blocks

    m, d = current.shape
    cur = pad_to_blocks(current, block, 1)
    vp = pad_to_blocks(v_prev, block, 1)
    sp = pad_to_blocks(s_prev, block, 1)
    mp = cur.shape[0]
    kern = functools.partial(_kernel, tau=tau, v_th=v_th,
                             soft_reset=soft_reset)
    spk, vn = pl.pallas_call(
        kern,
        grid=(mp // block,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((mp, d), jnp.int8),
                   jax.ShapeDtypeStruct((mp, d), jnp.float32)],
        interpret=interpret,
    )(cur, vp, sp)
    return spk[:m], vn[:m]
